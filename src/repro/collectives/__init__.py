from repro.collectives.api import (allreduce, allreduce_inside,
                                   reduce_to_root, select_algorithm)
from repro.collectives.overlap import (bucket_algorithm_plan,
                                       bucketed_allreduce)
from repro.collectives import shardmap_impl

__all__ = ["allreduce", "allreduce_inside", "reduce_to_root",
           "select_algorithm", "bucket_algorithm_plan",
           "bucketed_allreduce", "shardmap_impl"]
