from repro.collectives.api import (allgather, allgather_inside,
                                   allgather_multi_inside, allreduce,
                                   allreduce_inside,
                                   allreduce_multi_inside,
                                   all_to_all, all_to_all_inside,
                                   all_to_all_multi_inside, broadcast,
                                   broadcast_inside, get_engine,
                                   plan_collective,
                                   reduce_scatter, reduce_scatter_inside,
                                   reduce_scatter_multi_inside,
                                   reduce_to_root, select_algorithm,
                                   set_engine)
from repro.collectives.engine import (CollectiveEngine, Decision, fit_fabric,
                                      measure_ppermute)
from repro.collectives.overlap import (bucket_algorithm_plan,
                                       bucketed_allreduce)
from repro.collectives.planner import CollectivePlan, PlanStep
from repro.collectives import shardmap_impl

__all__ = ["allreduce", "allreduce_inside", "allreduce_multi_inside",
           "reduce_scatter", "reduce_scatter_inside",
           "reduce_scatter_multi_inside",
           "allgather", "allgather_inside", "allgather_multi_inside",
           "all_to_all", "all_to_all_inside", "all_to_all_multi_inside",
           "broadcast", "broadcast_inside", "reduce_to_root",
           "select_algorithm", "get_engine", "set_engine",
           "plan_collective", "CollectivePlan", "PlanStep",
           "CollectiveEngine", "Decision", "fit_fabric",
           "measure_ppermute", "bucket_algorithm_plan",
           "bucketed_allreduce", "shardmap_impl"]
