"""Topology-aware collective planner: joint multi-axis plans.

The model layer already prices the paper's 2D results (xy-reduce,
snake-reduce, the 2D lower bound -- ``core/patterns.py`` Sec. 7) but the
runtime used to dispatch one axis at a time.  This module closes that
gap: ``plan_collective`` takes an *axis-size tuple* (the folded m x n
topology, e.g. ``("pod", "data") -> (2, 16)``) and jointly scores every
implemented multi-axis composition under Eq. (1):

* ``sequential``   -- per-axis AllReduce, innermost axis first (the old
  ``overlap.bucketed_allreduce`` loop).  Moves the full vector across
  every axis.
* ``hierarchical`` -- reduce-scatter(inner) -> allreduce(outer, on 1/P
  of the bytes) -> allgather(inner).  Bandwidth-optimal composition:
  the expensive outer (cross-pod) phase only ever sees ``B / P_inner``
  bytes.
* ``2d_xy``        -- the paper's X-Y Reduce over the folded m x n grid
  (best 1D pattern per dimension) plus a 2D broadcast (flooding where
  the fabric multicasts, per-axis doubling on ICI).
* ``2d_snake``     -- Snake Reduce: one pipelined chain over the
  boustrophedon order of the grid, plus the same 2D broadcast.
* ``flat``         -- the best 1D algorithm over the axes folded into a
  single logical axis (row-major), the ``psum((a, b))`` shape.
* ``latency``      -- the small-B latency regime: one single-shot
  program over the folded axis (depth 1, a single launch -- the
  ``t_oneshot_*`` closed forms).  Pays extra wire volume for minimal
  launch/depth overhead, so the model selects it exactly below the
  crossover where decode-sized payloads live.

Every multi-phase shape additionally grows a ``<shape>_pipelined``
candidate: the payload is sliced into ``n_chunks`` pieces and the
phases run as a wavefront, so a chunk's slow outer (cross-pod) phase
overlaps the next chunk's fast inner phase.  Phases are grouped into
*link classes* (the axes whose wires they occupy); only phases on
disjoint classes overlap, so the closed form is

    T_pipe(C) = sum_i t_i(B/C) + (C - 1) * max_class sum_cls t_i(B/C)

with the chunk count C chosen by the model from
:data:`PIPELINE_CHUNK_CANDIDATES`.  Per-phase launch overhead is inside
every chunk-sized ``t_i``, so small payloads fall back to the
serialized shapes on their own; ``cost_terms`` report the chosen
``n_chunks`` and the modeled ``overlap_saved`` vs the base shape.

Per-axis candidates inside each shape are priced through the engine's
``select`` (so their decisions share the persistent cache), the joint
winner is validated against the paper's 2D lower bound
(``t_lower_bound_2d``, Lemma 7.2), and the result is a
``CollectivePlan`` whose ``cost_terms`` expose the modeled per-axis
wire bytes -- the quantity that makes "hierarchical moves strictly
fewer cross-pod bytes" an assertable fact rather than folklore.

``reduce_scatter`` / ``allgather`` plans use the ``cascade`` shape
(per-axis halves, chunk-transposed so the output layout matches
``lax.psum_scatter(..., tiled=True)`` over the folded axes) and the
``flat`` shape; their lower bound takes the max over link classes of
Lemma 7.2's volume branch at the ``B * (p_ax-1)/P`` bytes that must
cross each axis's links (a bound that stays valid when phases on
disjoint axes overlap).

``all_to_all`` plans (the EP dispatch traffic class) use
``hierarchical`` (2-phase intra-pod/inter-pod: innermost axis first,
aggregating cross-pod traffic before it hits the slow links),
``sequential`` (outermost-first), and ``flat`` (single-shot over the
folded axis); every candidate validates against the per-axis injection
bound (``core.lowerbound.t_all_to_all_lower_bound`` maxed over link
classes -- again overlap-proof).

Plans are positional (axis *sizes*, not names) so the engine can cache
them under the topology signature ``(op, axis_sizes, bytes, fabric)``
and rebind mesh axis names on retrieval.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import patterns as pat
from repro.core.lowerbound import t_all_to_all_lower_bound
from repro.core.model import Fabric, ceil_div, slowest_fabric
from repro.core.selector import t_broadcast_2d_fabric

#: shapes a multi-axis allreduce plan may take
ALLREDUCE_SHAPES = ("sequential", "hierarchical", "2d_xy", "2d_snake",
                    "flat", "latency", "sequential_pipelined",
                    "hierarchical_pipelined")
#: shapes a multi-axis reduce_scatter / allgather plan may take
#: ("latency" is offered for allgather only: the latency regime has no
#: single-program reduce_scatter primitive distinct from the cascade)
SHARDED_SHAPES = ("cascade", "flat", "latency", "cascade_pipelined")
#: shapes a multi-axis all_to_all plan may take
ALL_TO_ALL_SHAPES = ("hierarchical", "sequential", "flat", "latency",
                     "hierarchical_pipelined", "sequential_pipelined")

#: chunk counts a ``*_pipelined`` candidate considers; the model keeps
#: the argmin (more chunks amortize the slow phase better, but every
#: chunk pays the full per-phase launch/depth overhead, so tiny payloads
#: price out of pipelining on their own)
PIPELINE_CHUNK_CANDIDATES = (2, 4, 8)

#: the engine's select() viewed from the planner:
#: (op, nbytes, p, topo=None, fabric=None) -- ``fabric`` carries the
#: axis-local constants of the axis the candidate actually traverses
SelectFn = Callable[..., Any]

AxisFabrics = Tuple[Fabric, ...]

#: per-phase ``(modeled time, link-class axis indices)`` -- the link
#: class identifies which axes' wires a phase occupies, so the pipelined
#: pricer knows which phases can genuinely overlap (disjoint classes)
#: and which serialize on shared links (same class)
PhaseList = List[Tuple[float, Tuple[int, ...]]]


def base_shape(shape: str) -> str:
    """``"hierarchical_pipelined" -> "hierarchical"``; serialized shapes
    map to themselves."""
    suffix = "_pipelined"
    return shape[:-len(suffix)] if shape.endswith(suffix) else shape


def _axis_fabrics(sizes: Sequence[int], fabric: Fabric,
                  axis_fabrics: Optional[Sequence[Optional[Fabric]]]
                  ) -> AxisFabrics:
    """Positional per-axis fabrics, defaulting every axis to ``fabric``
    (the uniform fast path hands back the same object everywhere)."""
    if axis_fabrics is None:
        return tuple(fabric for _ in sizes)
    if len(axis_fabrics) != len(sizes):
        raise ValueError(f"axis_fabrics {len(axis_fabrics)} entries for "
                         f"{len(sizes)} axes")
    return tuple(f if f is not None else fabric for f in axis_fabrics)


def _lb_fabric(fabrics: Sequence[Fabric]) -> Fabric:
    """A fabric no slower than any of ``fabrics`` on every constant, so
    Lemma 7.2 instantiated with it lower-bounds every candidate priced
    with the real per-axis constants.  Uniform input returns the shared
    object (bit-for-bit the single-fabric bound)."""
    f0 = fabrics[0]
    if all(f == f0 for f in fabrics[1:]):
        return f0
    return Fabric(name="lb",
                  t_r=min(f.t_r for f in fabrics),
                  store_cost=min(f.store_cost for f in fabrics),
                  link_bw=max(f.link_bw for f in fabrics),
                  multicast=any(f.multicast for f in fabrics))


@dataclasses.dataclass(frozen=True)
class PlanStep:
    """One executable phase of a plan.

    ``axes`` holds *indices* into the plan's axis tuple in positional
    (unbound) records and axis *names* once the engine binds a mesh.
    ``nbytes`` is the vector size entering the phase (the size its
    algorithm was priced at).
    """

    kind: str                   # reduce_scatter | allreduce | allgather
                                # | xy_allreduce | snake_allreduce
    axes: Tuple[Any, ...]
    algorithm: str
    nbytes: int


@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    """A scored, executable multi-axis collective plan.

    ``predictions`` maps every candidate shape to its Eq.-(1) estimate;
    ``cost_terms`` maps every candidate shape to
    ``{"predicted": cycles, "axis_bytes": {axis: modeled wire bytes}}``
    where ``axis_bytes[ax]`` sums, over the shape's phases on that axis,
    ``phase_bytes * (p - 1) / p`` (doubled for allreduce phases, which
    run both a reduce-scatter-like and an allgather-like half).
    ``lower_bound`` is the overlap-aware bound the chosen plan was
    validated against.  ``n_chunks`` is how many payload slices the
    engine pipelines the phases over (1 for serialized shapes);
    ``*_pipelined`` entries in ``cost_terms`` additionally carry
    ``n_chunks`` and ``overlap_saved`` (modeled cycles recovered vs the
    phase-sequential base shape -- negative when pipelining would
    lose).
    """

    op: str
    axes: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]
    nbytes: int
    shape: str
    steps: Tuple[PlanStep, ...]
    predicted: float
    predictions: Dict[str, float]
    cost_terms: Dict[str, Dict[str, Any]]
    lower_bound: float
    n_chunks: int = 1

    def describe(self) -> str:
        """Compact human-readable plan shape, e.g.
        ``hierarchical(rs:ring->ar:ring->ag:ring)``."""
        if not self.steps:
            return "identity"
        inner = "->".join(
            f"{_KIND_ABBREV.get(s.kind, s.kind)}:{s.algorithm}"
            for s in self.steps)
        if self.n_chunks > 1:
            return f"{self.shape}({inner})[chunks={self.n_chunks}]"
        return f"{self.shape}({inner})"


_KIND_ABBREV = {"reduce_scatter": "rs", "allreduce": "ar",
                "allgather": "ag", "xy_allreduce": "xy",
                "snake_allreduce": "snake", "all_to_all": "a2a"}


def _elements(nbytes: int, element_bytes: int) -> int:
    return max(1, nbytes // element_bytes)


def _effective(sizes: Sequence[int]) -> List[Tuple[int, int]]:
    """(axis index, size) for axes that actually move data."""
    return [(i, p) for i, p in enumerate(sizes) if p > 1]


def _fold_2d(sizes: Sequence[int]) -> Tuple[int, int]:
    """Fold an axis-size tuple into the m x n grid the 2D lemmas use:
    outer axes collapse into m, the innermost effective axis is n."""
    eff = _effective(sizes)
    if not eff:
        return (1, 1)
    if len(eff) == 1:
        return (1, eff[0][1])
    n = eff[-1][1]
    m = 1
    for _, p in eff[:-1]:
        m *= p
    return (m, n)


def _class_bound_fabric(ax_fab: Fabric, eff_fabs: Sequence[Fabric]
                        ) -> Fabric:
    """Constants for a per-link-class bound term: the class's own
    bandwidth (its wire volume cannot ride any other class's links) but
    latency constants no slower than any effective axis's, so the term
    stays below every candidate regardless of which axis's launch
    constants a phase happens to pay.  Uniform input returns the shared
    object (bit-for-bit the single-fabric term)."""
    if all(f == ax_fab for f in eff_fabs):
        return ax_fab
    return Fabric(name="lb_class",
                  t_r=min(f.t_r for f in eff_fabs),
                  store_cost=min(f.store_cost for f in eff_fabs),
                  link_bw=ax_fab.link_bw,
                  multicast=any(f.multicast for f in eff_fabs))


def lower_bound_multi(op: str, sizes: Sequence[int], nbytes: int,
                      fabric: Fabric, element_bytes: int,
                      axis_fabrics: Optional[Sequence[Fabric]] = None
                      ) -> float:
    """Overlap-aware lower bound for the folded topology and the op's
    minimal per-link-class volume.

    AllReduce carries full Lemma 7.2: the root must absorb the whole
    B-vector after it crossed the grid, so both the volume and the
    ``M + N - 1`` traversal branches bind -- a store-bandwidth argument
    that survives arbitrary phase overlap.  The other ops admit
    genuinely concurrent per-axis phases (disjoint link classes), so a
    serialized sum of per-axis terms is *not* a valid bound for them;
    instead each axis's links are bounded independently and the max
    taken:

    * ``all_to_all`` -- every device's ``B * (p_ax-1)/p_ax`` bytes
      destined for other ``ax``-slices must cross ``ax`` links
      (pre-aggregation cannot shrink a personalized exchange), so each
      axis carries the 1D injection bound at the full B.
    * ``reduce_scatter`` / ``allgather`` -- of the ``B``-sized result,
      the outputs owned by one ``ax``-slice need contributions from the
      other ``p_ax - 1`` slices; maximally pre-reduced that is still
      ``B * (p_ax-1) / P`` bytes into (out of) each device over ``ax``
      links -- Lemma 7.2's volume branch at that volume.

    Each per-class term is instantiated with that axis's bandwidth but
    latency constants no slower than any effective axis's
    (:func:`_class_bound_fabric`), so it stays below every
    per-axis-priced candidate, serialized or pipelined."""
    fabs = _axis_fabrics(tuple(sizes), fabric, axis_fabrics)
    m, n = _fold_2d(sizes)
    if m * n <= 1:
        return 0.0
    eff = _effective(sizes)
    eff_fabs = [fabs[i] for i, _ in eff]
    lbf = _lb_fabric(eff_fabs or [fabric])
    b = _elements(nbytes, element_bytes)
    if op == "all_to_all":
        return max(
            t_all_to_all_lower_bound(p_ax, b,
                                     _class_bound_fabric(fabs[i],
                                                         eff_fabs))
            for i, p_ax in eff)
    if op in ("reduce_scatter", "allgather"):
        p = m * n
        return max(
            pat.t_lower_bound_2d(
                1, 1, max(1, math.ceil(b * (p_ax - 1) / p)),
                _class_bound_fabric(fabs[i], eff_fabs))
            for i, p_ax in eff)
    return pat.t_lower_bound_2d(m, n, b, lbf)


def _best_reduce_pattern(p: int, b: int, fabric: Fabric
                         ) -> Tuple[str, float]:
    preds = {name: fn(p, b, fabric)
             for name, fn in pat.REDUCE_PATTERNS.items()
             if name != "tree" or (p & (p - 1)) == 0}
    name = min(preds, key=preds.get)
    return name, preds[name]


def _wire_bytes(nbytes: float, p: int, allreduce: bool = False) -> float:
    """Modeled per-device wire bytes of one phase over a P-way axis."""
    if p <= 1:
        return 0.0
    return (2.0 if allreduce else 1.0) * nbytes * (p - 1) / p


def _merge_bytes(into: Dict[int, float], frm: Dict[int, float]) -> None:
    for k, v in frm.items():
        into[k] = into.get(k, 0.0) + v


# ---------------------------------------------------------------------- #
# shape scoring
# ---------------------------------------------------------------------- #
ScoredShape = Tuple[float, List[PlanStep], Dict[int, float], PhaseList]


def _score_sequential(op_steps_kind: str, sizes: Sequence[int],
                      nbytes: int, select: SelectFn, fabs: AxisFabrics
                      ) -> ScoredShape:
    """Per-axis allreduce, innermost first (the legacy loop); each axis
    priced with its own fabric constants."""
    t = 0.0
    steps: List[PlanStep] = []
    axis_bytes: Dict[int, float] = {}
    phases: PhaseList = []
    for i in reversed(range(len(sizes))):
        p = sizes[i]
        if p <= 1:
            continue
        d = select("allreduce", nbytes, p, fabric=fabs[i])
        t += d.predicted
        steps.append(PlanStep("allreduce", (i,), d.algorithm, nbytes))
        axis_bytes[i] = _wire_bytes(nbytes, p, allreduce=True)
        phases.append((d.predicted, (i,)))
    return t, steps, axis_bytes, phases


def _score_cascade(op: str, sizes: Sequence[int], nbytes: int,
                   select: SelectFn, fabs: AxisFabrics
                   ) -> ScoredShape:
    """Per-axis reduce_scatter (innermost first) or allgather (outermost
    first); each phase shrinks/grows the live vector by its axis size."""
    t = 0.0
    steps: List[PlanStep] = []
    axis_bytes: Dict[int, float] = {}
    phases: PhaseList = []
    eff = _effective(sizes)
    order = list(reversed(eff)) if op == "reduce_scatter" else list(eff)
    if op == "allgather":
        # allgather phases grow from the shard: replay the shrink to
        # find per-phase entry sizes, then price in gather order
        cur = nbytes
        entry = {}
        for i, p in reversed(eff):
            entry[i] = cur
            cur = ceil_div(cur, p)
    for i, p in order:
        if op == "reduce_scatter":
            phase_bytes = nbytes
            nbytes = ceil_div(nbytes, p)
        else:
            phase_bytes = entry[i]
        d = select(op, phase_bytes, p, fabric=fabs[i])
        t += d.predicted
        steps.append(PlanStep(op, (i,), d.algorithm, phase_bytes))
        axis_bytes[i] = _wire_bytes(phase_bytes, p)
        phases.append((d.predicted, (i,)))
    return t, steps, axis_bytes, phases


def _score_flat(op: str, sizes: Sequence[int], nbytes: int,
                select: SelectFn, fabs: AxisFabrics
                ) -> ScoredShape:
    """Best 1D algorithm over the row-major-folded logical axis.  The
    decision is cached under the full topology signature, not the folded
    P, so a 16-way axis and a folded 2x8 never share entries.  The
    folded schedule may route any hop over any member axis, so it is
    priced with the slowest member fabric (conservative, and exactly
    why flat loses to hierarchical when pod links are slow)."""
    p = 1
    for s in sizes:
        p *= s
    eff_idx = tuple(i for i, _ in _effective(sizes))
    eff_fabs = [fabs[i] for i in eff_idx]
    slow = slowest_fabric(*(eff_fabs or [fabs[0]]))
    d = select(op, nbytes, p, topo=tuple(sizes), fabric=slow)
    kind = op if op != "allreduce" else "allreduce"
    steps = [PlanStep(kind, tuple(range(len(sizes))), d.algorithm, nbytes)]
    # conservative attribution: the folded schedule may route any hop
    # over any axis, so every axis is charged the full folded traffic
    axis_bytes = {i: _wire_bytes(nbytes, p, allreduce=op == "allreduce")
                  for i, s in enumerate(sizes) if s > 1}
    # one phase occupying every effective axis's links: nothing to
    # overlap, so flat never grows a pipelined variant
    return d.predicted, steps, axis_bytes, [(d.predicted, eff_idx)]


_ONESHOT_FORMS = {"allreduce": pat.t_oneshot_allreduce,
                  "allgather": pat.t_oneshot_allgather,
                  "all_to_all": pat.t_oneshot_all_to_all}


def _score_latency(op: str, sizes: Sequence[int], nbytes: int,
                   element_bytes: int, fabs: AxisFabrics
                   ) -> ScoredShape:
    """The small-B latency regime: one single-shot program over all
    effective axes folded into one logical axis -- depth 1, a single
    launch, no store-and-forward staging.  Priced by the ``t_oneshot_*``
    closed forms (``core/patterns.py``) at the slowest member fabric
    (the folded exchange may route any hop over any axis).  Pays more
    wire volume than the bandwidth-optimal shapes (no reuse of
    forwarded data), so it only wins below the crossover where
    per-phase launch/depth overhead dominates -- exactly the decode
    regime.  The engine dispatches it as one fused XLA collective over
    the joint axis tuple (``_allreduce_inside`` et al., algorithm
    ``"oneshot"``)."""
    eff = _effective(sizes)
    p = 1
    for _, s in eff:
        p *= s
    eff_idx = tuple(i for i, _ in eff)
    slow = slowest_fabric(*(fabs[i] for i in eff_idx))
    b = _elements(nbytes, element_bytes)
    t = _ONESHOT_FORMS[op](p, b, slow)
    steps = [PlanStep(op, tuple(range(len(sizes))), "oneshot", nbytes)]
    if op == "allreduce":
        # all-broadcast + local K-way reduce: every device unicasts its
        # full vector to the p-1 others (no multicast reuse)
        per_axis = float(nbytes) * (p - 1)
    else:
        # allgather (nbytes = global result) / all_to_all (nbytes =
        # per-device shard): each device injects its (p-1)/p share once
        per_axis = _wire_bytes(float(nbytes), p)
    axis_bytes = {i: per_axis for i in eff_idx}
    # one phase occupying every effective axis's links: nothing to
    # overlap, so latency never grows a pipelined variant
    return t, steps, axis_bytes, [(t, eff_idx)]


def _score_hierarchical(sizes: Sequence[int], nbytes: int,
                        fabric: Fabric, element_bytes: int,
                        select: SelectFn, fabs: AxisFabrics
                        ) -> ScoredShape:
    """RS(inner) -> AR(outer, 1/P_inner bytes) -> AG(inner).  The RS and
    AG phases share the inner axis's links (one link class), the middle
    allreduce rides the outer axes -- the disjoint class a pipelined
    variant overlaps against."""
    eff = _effective(sizes)
    inner_i, inner_p = eff[-1]
    rs = select("reduce_scatter", nbytes, inner_p, fabric=fabs[inner_i])
    ag = select("allgather", nbytes, inner_p, fabric=fabs[inner_i])
    shard_nbytes = ceil_div(nbytes, inner_p)
    outer = [(i, p) for i, p in eff[:-1]]
    h_steps = [PlanStep("reduce_scatter", (inner_i,), rs.algorithm,
                        nbytes)]
    h_bytes: Dict[int, float] = {
        inner_i: _wire_bytes(nbytes, inner_p) * 2.0}
    if len(outer) == 1:
        oi, op_ = outer[0]
        ar = select("allreduce", shard_nbytes, op_, fabric=fabs[oi])
        h_steps.append(PlanStep("allreduce", (oi,), ar.algorithm,
                                shard_nbytes))
        t_mid = ar.predicted
        h_bytes[oi] = _wire_bytes(shard_nbytes, op_, allreduce=True)
    else:
        sub_sizes = tuple(sizes[i] if (i, sizes[i]) in outer else 1
                          for i in range(len(sizes)))
        sub = _plan_allreduce(sub_sizes, shard_nbytes, fabric,
                              element_bytes, select,
                              axis_fabrics=fabs)
        h_steps.append(PlanStep("allreduce",
                                tuple(i for i, _ in outer),
                                sub["shape"], shard_nbytes))
        t_mid = sub["predicted"]
        _merge_bytes(h_bytes,
                     {int(k): v for k, v in
                      sub["cost_terms"][sub["shape"]]
                      ["axis_bytes"].items()})
    h_steps.append(PlanStep("allgather", (inner_i,), ag.algorithm,
                            nbytes))
    phases: PhaseList = [(rs.predicted, (inner_i,)),
                         (t_mid, tuple(i for i, _ in outer)),
                         (ag.predicted, (inner_i,))]
    return (rs.predicted + t_mid + ag.predicted, h_steps, h_bytes,
            phases)


def _add_pipelined(shapes: Dict[str, Tuple[float, List[PlanStep],
                                           Dict[int, float]]],
                   extras: Dict[str, Dict[str, Any]], base: str,
                   nbytes: int, element_bytes: int,
                   score_chunk: Callable[[int], ScoredShape]) -> None:
    """Price the chunk-pipelined variant of an already-scored
    multi-phase shape and add it as the ``<base>_pipelined`` candidate.

    The payload is sliced into ``C`` chunks and chunk ``k``'s phase
    ``r`` runs while chunk ``k+1`` is still in phase ``r-1``, so phases
    on *disjoint* link classes overlap across chunks; phases sharing a
    link class (e.g. the hierarchical RS and AG, both on the inner
    axis) still serialize on those wires.  Steady state is therefore
    paced by the most-loaded link class, and the closed form is

        T_pipe(C) = sum_i t_i(B/C) + (C - 1) * max_class sum_cls t_i(B/C)

    (ramp: every phase once at chunk size, then C-1 more chunks behind
    the bottleneck class).  Per-phase launch/depth overhead is inside
    every ``t_i(B/C)`` -- charged per chunk -- so small payloads price
    pipelining out on their own.  The model keeps the argmin C over
    :data:`PIPELINE_CHUNK_CANDIDATES`; chunk bytes round up to whole
    elements so the C chunks never total less than the real payload."""
    base_t = shapes[base][0]
    best: Optional[Tuple[float, int, List[PlanStep],
                         Dict[int, float]]] = None
    for c in PIPELINE_CHUNK_CANDIDATES:
        cb = ceil_div(ceil_div(nbytes, c), element_bytes) * element_bytes
        t_sum, steps, ab, phases = score_chunk(cb)
        classes: Dict[Tuple[int, ...], float] = {}
        for t_i, cls_axes in phases:
            key = tuple(sorted(cls_axes))
            classes[key] = classes.get(key, 0.0) + t_i
        if len(classes) < 2:
            return      # everything rides one link class: no overlap
        t_pipe = t_sum + (c - 1) * max(classes.values())
        if best is None or t_pipe < best[0]:
            best = (t_pipe, c, steps, ab)
    if best is None:
        return
    t_pipe, c, steps, ab = best
    name = f"{base}_pipelined"
    # total wire bytes = per-chunk bytes x chunk count (slightly above
    # the serialized shape's when chunking pads the last chunk)
    shapes[name] = (t_pipe, steps, {i: v * c for i, v in ab.items()})
    extras[name] = {"n_chunks": c, "overlap_saved": base_t - t_pipe}


def _plan_allreduce(sizes: Tuple[int, ...], nbytes: int, fabric: Fabric,
                    element_bytes: int, select: SelectFn,
                    force_shape: Optional[str] = None,
                    axis_fabrics: Optional[Sequence[Fabric]] = None
                    ) -> Dict[str, Any]:
    b = _elements(nbytes, element_bytes)
    eff = _effective(sizes)
    fabs = _axis_fabrics(sizes, fabric, axis_fabrics)
    shapes: Dict[str, Tuple[float, List[PlanStep], Dict[int, float]]] = {}
    extras: Dict[str, Dict[str, Any]] = {}

    t, steps, ab, _ = _score_sequential("allreduce", sizes, nbytes,
                                        select, fabs)
    shapes["sequential"] = (t, steps, ab)

    if len(eff) >= 2:
        shapes["latency"] = _score_latency("allreduce", sizes, nbytes,
                                           element_bytes, fabs)[:3]
        f_t, f_steps, f_ab, _ = _score_flat("allreduce", sizes, nbytes,
                                            select, fabs)
        shapes["flat"] = (f_t, f_steps, f_ab)
        h_t, h_steps, h_ab, _ = _score_hierarchical(
            sizes, nbytes, fabric, element_bytes, select, fabs)
        shapes["hierarchical"] = (h_t, h_steps, h_ab)
        _add_pipelined(shapes, extras, "sequential", nbytes,
                       element_bytes,
                       lambda cb: _score_sequential("allreduce", sizes,
                                                    cb, select, fabs))
        _add_pipelined(shapes, extras, "hierarchical", nbytes,
                       element_bytes,
                       lambda cb: _score_hierarchical(sizes, cb, fabric,
                                                      element_bytes,
                                                      select, fabs))

    if len(eff) == 2:
        (mi, m), (ni, n) = eff
        fm, fn_ = fabs[mi], fabs[ni]
        bc = t_broadcast_2d_fabric(m, n, b, fabric, fabric_m=fm,
                                   fabric_n=fn_)
        pm, tm = _best_reduce_pattern(m, b, fm)
        pn, tn = _best_reduce_pattern(n, b, fn_)
        xy_bytes = {mi: _wire_bytes(nbytes, m) * 2.0,
                    ni: _wire_bytes(nbytes, n) * 2.0}
        shapes["2d_xy"] = (
            tm + tn + bc,
            [PlanStep("xy_allreduce", (mi, ni), f"{pm}x{pn}", nbytes)],
            xy_bytes)
        snake_bytes = {mi: _wire_bytes(nbytes, m) * 2.0,
                       ni: _wire_bytes(nbytes, n) * 2.0}
        # one boustrophedon chain crosses both link classes
        snake_fab = slowest_fabric(fm, fn_)
        shapes["2d_snake"] = (
            pat.t_snake_reduce(m, n, b, snake_fab) + bc,
            [PlanStep("snake_allreduce", (mi, ni), "snake", nbytes)],
            snake_bytes)

    return _finish("allreduce", sizes, nbytes, fabric, element_bytes,
                   shapes, force_shape, fabs, extras)


def _score_a2a_phases(nbytes: int, select: SelectFn, fabs: AxisFabrics,
                      order: Sequence[Tuple[int, int]]
                      ) -> ScoredShape:
    """One full-B all-to-all per axis, in ``order``: each phase settles
    that axis's destination sub-index (the data stays B bytes per device
    throughout -- AllToAll conserves volume)."""
    t = 0.0
    steps: List[PlanStep] = []
    axis_bytes: Dict[int, float] = {}
    phases: PhaseList = []
    for i, p in order:
        d = select("all_to_all", nbytes, p, fabric=fabs[i])
        t += d.predicted
        steps.append(PlanStep("all_to_all", (i,), d.algorithm, nbytes))
        axis_bytes[i] = _wire_bytes(nbytes, p)
        phases.append((d.predicted, (i,)))
    return t, steps, axis_bytes, phases


def _plan_all_to_all(sizes: Tuple[int, ...], nbytes: int, fabric: Fabric,
                     element_bytes: int, select: SelectFn,
                     force_shape: Optional[str] = None,
                     axis_fabrics: Optional[Sequence[Fabric]] = None
                     ) -> Dict[str, Any]:
    """AllToAll joint plans.

    * ``hierarchical`` -- the 2-phase intra-pod/inter-pod decomposition
      (generalized to k phases): exchange along the innermost axis
      first, aggregating each pod's cross-pod traffic into contiguous
      per-pod stripes, then along the outer axes.  Cross-pod wire bytes
      drop to B*(M-1)/M per device -- the quantity the flat single-shot
      is (conservatively) charged on every link class it folds.
    * ``sequential`` -- the same per-axis factorization in the naive
      outermost-first order.  AllToAll conserves bytes, so its model
      price equals hierarchical's; ties resolve to ``hierarchical``
      (inserted first), which is also the order that aggregates
      cross-pod messages before they hit the slow links.
    * ``flat``       -- one single-shot exchange over the row-major
      folded axis (depth P-1), priced at the slowest member fabric with
      every axis charged the full folded traffic.
    """
    eff = _effective(sizes)
    fabs = _axis_fabrics(sizes, fabric, axis_fabrics)
    shapes: Dict[str, Tuple[float, List[PlanStep], Dict[int, float]]] = {}
    extras: Dict[str, Dict[str, Any]] = {}
    if len(eff) < 2:
        shapes["sequential"] = _score_a2a_phases(nbytes, select, fabs,
                                                 list(eff))[:3]
    else:
        shapes["hierarchical"] = _score_a2a_phases(
            nbytes, select, fabs, list(reversed(eff)))[:3]
        shapes["sequential"] = _score_a2a_phases(nbytes, select, fabs,
                                                 list(eff))[:3]
        shapes["latency"] = _score_latency("all_to_all", sizes, nbytes,
                                           element_bytes, fabs)[:3]
        shapes["flat"] = _score_flat("all_to_all", sizes, nbytes, select,
                                     fabs)[:3]
        _add_pipelined(shapes, extras, "hierarchical", nbytes,
                       element_bytes,
                       lambda cb: _score_a2a_phases(cb, select, fabs,
                                                    list(reversed(eff))))
        _add_pipelined(shapes, extras, "sequential", nbytes,
                       element_bytes,
                       lambda cb: _score_a2a_phases(cb, select, fabs,
                                                    list(eff)))
    return _finish("all_to_all", sizes, nbytes, fabric, element_bytes,
                   shapes, force_shape, fabs, extras)


def _plan_sharded(op: str, sizes: Tuple[int, ...], nbytes: int,
                  fabric: Fabric, element_bytes: int, select: SelectFn,
                  force_shape: Optional[str] = None,
                  axis_fabrics: Optional[Sequence[Fabric]] = None
                  ) -> Dict[str, Any]:
    eff = _effective(sizes)
    fabs = _axis_fabrics(sizes, fabric, axis_fabrics)
    shapes: Dict[str, Tuple[float, List[PlanStep], Dict[int, float]]] = {}
    extras: Dict[str, Dict[str, Any]] = {}
    shapes["cascade"] = _score_cascade(op, sizes, nbytes, select,
                                       fabs)[:3]
    if len(eff) >= 2:
        if op == "allgather":
            shapes["latency"] = _score_latency(op, sizes, nbytes,
                                               element_bytes, fabs)[:3]
        shapes["flat"] = _score_flat(op, sizes, nbytes, select, fabs)[:3]
        _add_pipelined(shapes, extras, "cascade", nbytes, element_bytes,
                       lambda cb: _score_cascade(op, sizes, cb, select,
                                                 fabs))
    return _finish(op, sizes, nbytes, fabric, element_bytes, shapes,
                   force_shape, fabs, extras)


def _finish(op: str, sizes: Tuple[int, ...], nbytes: int, fabric: Fabric,
            element_bytes: int,
            shapes: Dict[str, Tuple[float, List[PlanStep],
                                    Dict[int, float]]],
            force_shape: Optional[str] = None,
            axis_fabrics: Optional[Sequence[Fabric]] = None,
            extras: Optional[Dict[str, Dict[str, Any]]] = None
            ) -> Dict[str, Any]:
    extras = extras or {}
    if not any(p > 1 for p in sizes):
        return {"op": op, "sizes": list(sizes), "nbytes": nbytes,
                "shape": "identity", "steps": [], "predicted": 0.0,
                "predictions": {}, "cost_terms": {}, "lower_bound": 0.0,
                "n_chunks": 1}
    lb = lower_bound_multi(op, sizes, nbytes, fabric, element_bytes,
                           axis_fabrics)
    predictions = {name: t for name, (t, _, _) in shapes.items()}
    for name, t in predictions.items():
        if t < lb - 1e-6:
            raise RuntimeError(
                f"model inconsistency: {op} shape {name!r} predicts "
                f"{t:.3f} cycles, below the lower bound {lb:.3f} "
                f"for topology {tuple(sizes)} at {nbytes} bytes")
    if force_shape is not None:
        if force_shape not in shapes:
            raise ValueError(
                f"shape {force_shape!r} is not a candidate for {op} "
                f"over {tuple(sizes)}; have {sorted(shapes)}")
        best = force_shape
    else:
        best = min(predictions, key=predictions.get)
    t_best, steps, _ = shapes[best]
    cost_terms = {
        name: dict({"predicted": t,
                    "axis_bytes": {str(i): v for i, v in ab.items()}},
                   **extras.get(name, {}))
        for name, (t, _, ab) in shapes.items()}
    return {"op": op, "sizes": list(sizes), "nbytes": nbytes,
            "shape": best,
            "steps": [{"kind": s.kind, "axes": list(s.axes),
                       "algorithm": s.algorithm, "nbytes": s.nbytes}
                      for s in steps],
            "predicted": t_best, "predictions": predictions,
            "cost_terms": cost_terms, "lower_bound": lb,
            "n_chunks": int(extras.get(best, {}).get("n_chunks", 1))}


# ---------------------------------------------------------------------- #
# public entry points
# ---------------------------------------------------------------------- #
def plan_collective(op: str, sizes: Sequence[int], nbytes: int,
                    fabric: Fabric, element_bytes: int,
                    select: SelectFn,
                    force_shape: Optional[str] = None,
                    axis_fabrics: Optional[Sequence[Fabric]] = None
                    ) -> Dict[str, Any]:
    """Produce the positional (unbound) plan record for a topology.

    ``select(op, nbytes, p, topo=None, fabric=None)`` prices one
    per-axis candidate with that axis's constants; the engine passes its
    cached ``Decision``-returning ``select`` so every per-axis
    sub-decision lands in the persistent cache.  ``axis_fabrics`` gives
    each positional axis its own :class:`Fabric` (heterogeneous
    topology); ``None`` prices every axis with ``fabric`` -- the
    uniform fast path, bit-for-bit the single-fabric planner.
    ``force_shape`` overrides the argmin with a named candidate (still
    scored and lower-bound-validated alongside the others).
    """
    sizes = tuple(int(s) for s in sizes)
    if op == "allreduce":
        return _plan_allreduce(sizes, nbytes, fabric, element_bytes,
                               select, force_shape, axis_fabrics)
    if op in ("reduce_scatter", "allgather"):
        return _plan_sharded(op, sizes, nbytes, fabric, element_bytes,
                             select, force_shape, axis_fabrics)
    if op == "all_to_all":
        return _plan_all_to_all(sizes, nbytes, fabric, element_bytes,
                                select, force_shape, axis_fabrics)
    raise ValueError(f"no multi-axis planner for op {op!r}")


def bind_plan(record: Dict[str, Any], op: str,
              axes: Sequence[str]) -> CollectivePlan:
    """Rebind a positional plan record to concrete mesh axis names."""
    axes = tuple(axes)
    sizes = tuple(int(s) for s in record["sizes"])
    steps = tuple(
        PlanStep(kind=s["kind"],
                 axes=tuple(axes[int(i)] for i in s["axes"]),
                 algorithm=s["algorithm"], nbytes=int(s["nbytes"]))
        for s in record["steps"])
    cost_terms = {}
    for shape, entry in record["cost_terms"].items():
        bound = {"predicted": float(entry["predicted"]),
                 "axis_bytes": {axes[int(i)]: float(v)
                                for i, v in entry["axis_bytes"].items()}}
        for k, v in entry.items():
            if k not in bound:
                bound[k] = v       # pipelined extras: n_chunks, ...
        cost_terms[shape] = bound
    return CollectivePlan(
        op=op, axes=axes, axis_sizes=sizes, nbytes=int(record["nbytes"]),
        shape=record["shape"], steps=steps,
        predicted=float(record["predicted"]),
        predictions={k: float(v)
                     for k, v in record["predictions"].items()},
        cost_terms=cost_terms, lower_bound=float(record["lower_bound"]),
        n_chunks=int(record.get("n_chunks", 1)))


__all__ = ["CollectivePlan", "PlanStep", "plan_collective", "bind_plan",
           "lower_bound_multi", "base_shape", "ALLREDUCE_SHAPES",
           "SHARDED_SHAPES", "ALL_TO_ALL_SHAPES",
           "PIPELINE_CHUNK_CANDIDATES"]
