"""CollectiveEngine: one dispatch layer for all collective traffic.

The paper's contribution is *model-driven selection*: evaluate every
implemented algorithm under the spatial performance model (Eq. 1) and
run the winner.  The engine makes that a subsystem instead of a per-call
computation:

* **Ops** -- ``allreduce``, ``reduce``, ``reduce_scatter``,
  ``allgather``, ``broadcast``, each with fixed-pattern backends and an
  Auto-Gen (DP tree) backend, selected by ``algorithm="auto"``.
* **Decision cache** -- selections are memoized by
  ``(op, P, bytes, fabric)`` and persisted as JSON under the same
  ``REPRO_CACHE_DIR`` the Auto-Gen npz tables use, so the DP and the
  model sweep run once per shape across traces *and* processes.
* **Tree cache** -- extracted Auto-Gen round schedules are memoized by
  ``(P, elements)`` so an explicit ``algorithm="autogen"`` trace never
  re-runs the DP either.
* **Calibration** -- ``calibrate()`` refits the Fabric constants from
  measured ppermute timings (``measure_ppermute``), so selection tracks
  the actual backend instead of the baked-in ICI constants.  With a
  mesh (or per-axis measurement dicts) it fits one Fabric *per mesh
  axis* on a shared time base, producing a heterogeneous
  ``FabricTopology`` -- pod links slower than intra-pod ICI -- that the
  planner prices per axis and the v3 cache persists.

Dispatch flow::

    user op (allreduce/reduce_scatter/...)          [api.py wrappers]
        -> engine.<op>_inside(x, axis, algorithm)   [inside shard_map]
            -> select(op, nbytes, P)                [decision cache]
                -> selector.predict_collective      [model, Eq. 1]
                -> autogen DP (tree cache, npz)     [only if needed]
            -> shardmap_impl backend                [rounds of ppermutes]
"""

from __future__ import annotations

import atexit
import dataclasses
import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.autogen import autogen_tree, cache_dir, compute_tables
from repro.core.model import (Fabric, FabricTopology, TPU_V5E_AXIS,
                              as_topology, ceil_div)
from repro.core import patterns as pat
from repro.core import selector
from repro.obs import trace as obs_trace
from repro.collectives import planner
from repro.collectives import shardmap_impl as impl

#: one model "element" on the TPU fabric (512-byte flit group)
ICI_ELEMENT_BYTES = 512

#: bump when the cost model changes (patterns/selector/planner) so
#: persisted decisions computed under the old model stop being served.
#: v2: chunk-pipelined plan candidates + overlap-aware lower bounds.
#: v3: per-launch overhead terms (Fabric.t_launch) + the one-shot
#: latency-regime candidates ("oneshot" algorithm, "latency" plan shape).
MODEL_VERSION = 3

#: persisted-file layout version.  v2 keys decisions by the full
#: topology signature (``op|t=2x8|B=...``) instead of the bare axis size
#: (``op|p=16|B=...``) and adds the ``plans`` section; v3 namespaces the
#: file by the full *fabric topology* (per-axis constants in the tag,
#: ``|f=`` key suffixes for non-default axis fabrics) and persists the
#: topology itself in a ``topology`` section so per-axis calibrations
#: survive the process.  v1/v2 files are migrated on load (v1 keys are
#: 1D signatures by construction; v2 keys are already topology
#: signatures, and a uniform topology's tag equals the v2 tag).
SCHEMA_VERSION = 3

Rounds = Tuple[Tuple[Tuple[int, int], ...], ...]


def _topo_key(op: str, topo: Sequence[int], nbytes: int) -> str:
    return f"{op}|t={'x'.join(str(int(s)) for s in topo)}|B={nbytes}"


def _freeze_rounds(rounds: Sequence[Sequence[Tuple[int, int]]]) -> Rounds:
    return tuple(tuple((int(s), int(d)) for s, d in r) for r in rounds)


@dataclasses.dataclass(frozen=True)
class Decision:
    """One cached selection: what to run for (op, P, bytes)."""

    op: str
    p: int
    nbytes: int
    algorithm: str
    predicted: float
    predictions: Dict[str, float]
    rounds: Optional[Rounds] = None   # Auto-Gen schedule, when selected


def _fit_line(measurements: Sequence[Tuple[int, float]],
              element_bytes: int) -> Tuple[float, float]:
    """Least-squares ``seconds = alpha + beta * B`` over one axis's
    neighbor-ppermute timings; returns the raw ``(alpha, beta)`` --
    callers decide how to treat a degenerate (non-positive) slope."""
    if len(measurements) < 2:
        raise ValueError("need >= 2 (nbytes, seconds) points to calibrate")
    els = np.array([max(1, nb // element_bytes) for nb, _ in measurements],
                   dtype=np.float64)
    secs = np.array([t for _, t in measurements], dtype=np.float64)
    beta, alpha = np.polyfit(els, secs, 1)
    return float(alpha), float(beta)


def fit_fabric(measurements: Sequence[Tuple[int, float]],
               base: Fabric = TPU_V5E_AXIS, name: Optional[str] = None,
               element_bytes: int = ICI_ELEMENT_BYTES,
               ref_cycle: Optional[float] = None) -> Fabric:
    """Fit Fabric constants from measured one-hop ppermute timings.

    ``measurements`` is a sequence of ``(nbytes, seconds)`` for a single
    neighbor ppermute.  Under the model a hop costs
    ``(2*t_r + B / link_bw) * cycle`` seconds with B in elements, so a
    least-squares line ``seconds = alpha + beta * B`` recovers the
    constants.  With ``ref_cycle=None`` the axis defines its own time
    base (``cycle = beta``, ``t_r = alpha / (2 * beta)``,
    ``link_bw = base.link_bw``) -- only the ratios enter 1D selection.
    Fitting several axes of one mesh needs a *shared* time base so their
    prices are comparable inside one plan: pass the fastest axis's beta
    as ``ref_cycle`` and the fit recovers ``link_bw = ref_cycle / beta``
    (< 1 for slower links) and ``t_r = alpha / (2 * ref_cycle)``.
    """
    alpha, beta = _fit_line(measurements, element_bytes)
    beta = max(beta, 1e-30)
    if ref_cycle is None:
        # the fitted slope is cycle / link_bw; keeping base.link_bw
        # means the implied cycle is beta * link_bw, and t_r must be
        # expressed in those cycles
        t_r = max(alpha / (2.0 * beta * base.link_bw), 0.0)
        link_bw = base.link_bw
    else:
        ref = max(float(ref_cycle), 1e-30)
        t_r = max(alpha / (2.0 * ref), 0.0)
        link_bw = ref / beta
    return Fabric(name=name or f"{base.name}_calibrated",
                  t_r=t_r, store_cost=base.store_cost,
                  link_bw=link_bw, multicast=base.multicast)


def measure_ppermute(mesh: Mesh, axis: str,
                     sizes_bytes: Sequence[int] = (1 << 12, 1 << 16,
                                                   1 << 20, 1 << 22),
                     repeats: int = 5) -> List[Tuple[int, float]]:
    """Time one neighbor-shift ppermute per size; feed to ``fit_fabric``."""
    p = mesh.shape[axis]
    perm = [(i, (i + 1) % p) for i in range(p)]

    out = []
    for nbytes in sizes_bytes:
        n = max(1, nbytes // 4)
        x = jnp.zeros((n,), jnp.float32)

        fn = shard_map(lambda v: lax.ppermute(v, axis, perm), mesh=mesh,
                       in_specs=P(), out_specs=P(), check_rep=False)
        jitted = jax.jit(fn)
        jitted(x).block_until_ready()          # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jitted(x).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        out.append((nbytes, best))
    return out


def fabric_to_dict(f: Fabric) -> Dict[str, Any]:
    return {"name": f.name, "t_r": f.t_r, "store_cost": f.store_cost,
            "link_bw": f.link_bw, "multicast": f.multicast,
            "t_launch": f.t_launch}


def _fabric_from_dict(d: Dict[str, Any]) -> Fabric:
    return Fabric(name=str(d["name"]), t_r=float(d["t_r"]),
                  store_cost=float(d["store_cost"]),
                  link_bw=float(d.get("link_bw", 1.0)),
                  multicast=bool(d.get("multicast", True)),
                  t_launch=float(d.get("t_launch", 0.0)))


def topology_to_dict(t: FabricTopology) -> Dict[str, Any]:
    return {"name": t.name, "default": fabric_to_dict(t.default),
            "axes": {axis: fabric_to_dict(f)
                     for axis, f in t.axis_fabrics}}


def topology_from_dict(d: Dict[str, Any]) -> FabricTopology:
    return FabricTopology(
        default=_fabric_from_dict(d["default"]),
        axis_fabrics=tuple((axis, _fabric_from_dict(fd))
                           for axis, fd in d.get("axes", {}).items()),
        name=str(d.get("name", "")))


def load_topology(path: str) -> Optional[FabricTopology]:
    """Read the fabric topology a v3 cache file was computed under
    (None for v1/v2 files or unreadable paths) -- how a fresh process
    restores a prior per-axis calibration."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return None
    topo = payload.get("topology")
    if not topo:
        return None
    try:
        return topology_from_dict(topo)
    except (KeyError, TypeError, ValueError):
        return None


def find_calibrated_topology(base: Fabric = TPU_V5E_AXIS
                             ) -> Optional[FabricTopology]:
    """Newest fleet-calibrated :class:`FabricTopology` persisted under
    ``REPRO_CACHE_DIR`` (the v3 ``topology`` section), or None.

    Only *calibrated* topologies qualify (``calibrate()`` names them
    ``<base>_calibrated``): a topology merely declared via a
    ``--fabric`` spec describes one launch's assumption, not a measured
    fleet property, and must not leak into unrelated processes sharing
    the cache directory.  Likewise only ``base``'s constants family is
    considered (the calibration keeps ``base`` as the default fabric),
    so a WSE cache never leaks into a TPU engine.  Set
    ``REPRO_RESTORE_TOPOLOGY=0`` to opt out -- e.g. when a process must
    price with the stock constants regardless of what a previous
    calibration run left behind."""
    if os.environ.get("REPRO_RESTORE_TOPOLOGY", "1").lower() in (
            "0", "false", "no", ""):
        return None
    d = cache_dir()
    try:
        names = os.listdir(d)
    except OSError:
        return None
    paths = [os.path.join(d, n) for n in names
             if n.startswith("engine_decisions__") and n.endswith(".json")]

    def _mtime(p: str) -> float:
        try:
            return os.path.getmtime(p)
        except OSError:
            return 0.0

    for path in sorted(paths, key=_mtime, reverse=True):
        topo = load_topology(path)
        if topo is None:
            continue
        if not topo.name.endswith("_calibrated"):
            continue        # declared (--fabric) rather than measured
        if topo.is_uniform and topo.default == base:
            continue        # nothing beyond the stock constants
        if (topo.default != base
                and not topo.default.name.startswith(base.name)):
            continue        # a different fabric family's cache
        return topo
    return None


class CollectiveEngine:
    """Cached, model-driven dispatch for every collective op.

    One engine per fabric-topology parameterization; ``api.get_engine()``
    hands out a process-wide default keyed by fabric so all call sites
    share one decision cache.  ``fabric`` may be a bare :class:`Fabric`
    (every axis priced the same -- the uniform fast path) or a
    :class:`FabricTopology` mapping mesh axis names to per-axis
    constants, in which case the planner prices each phase with the
    constants of the axes it actually traverses.
    """

    def __init__(self, fabric: "Fabric | FabricTopology" = TPU_V5E_AXIS,
                 cache_path: Optional[str] = None, persist: bool = True,
                 element_bytes: int = ICI_ELEMENT_BYTES):
        self.topology = as_topology(fabric)
        self.element_bytes = element_bytes
        self._persist = persist
        self._cache_path_override = cache_path
        self._decisions: Dict[str, Decision] = {}
        self._plans: Dict[str, Dict[str, Any]] = {}
        self._tree_rounds: Dict[Tuple[int, int], Rounds] = {}
        self._tables: Dict[int, Any] = {}
        self._loaded = False
        self._lock = threading.RLock()
        self._dirty = False
        self._last_save = 0.0
        self.stats = {"hits": 0, "misses": 0, "dp_runs": 0,
                      "persisted_loads": 0, "plan_hits": 0,
                      "plan_misses": 0, "latency_dispatches": 0}
        if persist:
            atexit.register(self.flush)

    @property
    def fabric(self) -> Fabric:
        """The topology's default fabric (the pre-topology engines'
        single Fabric; per-axis overrides live in ``self.topology``)."""
        return self.topology.default

    # ------------------------------------------------------------------ #
    # decision cache
    # ------------------------------------------------------------------ #
    @staticmethod
    def _fabric_one_tag(f: Fabric) -> str:
        tag = (f"{f.name}_tr{f.t_r:g}_st{f.store_cost:g}"
               f"_bw{f.link_bw:g}_mc{int(f.multicast)}")
        # uncalibrated fabrics keep the exact pre-t_launch tag, so
        # existing cache files stay valid until a launch calibration
        # actually moves the constants
        if f.t_launch != 0.0:
            tag += f"_tl{f.t_launch:g}"
        return tag

    def _fabric_tag(self) -> str:
        """Cache namespace: the full topology signature.  A uniform
        topology produces exactly the v2 single-fabric tag, so uniform
        engines keep their existing cache files; per-axis overrides
        append to the tag (fresh namespace per calibration)."""
        tag = (f"{self._fabric_one_tag(self.topology.default)}"
               f"_eb{self.element_bytes}_v{MODEL_VERSION}")
        for axis, f in self.topology.axis_fabrics:
            tag += f"__{axis}-{self._fabric_one_tag(f)}"
        return tag

    def _cache_path(self) -> str:
        if self._cache_path_override:
            return self._cache_path_override
        return os.path.join(cache_dir(),
                            f"engine_decisions__{self._fabric_tag()}.json")

    def _elements(self, nbytes: int) -> int:
        return max(1, nbytes // self.element_bytes)

    def _load_persisted(self) -> None:
        if self._loaded or not self._persist:
            self._loaded = True
            return
        self._loaded = True
        path = self._cache_path()
        if not os.path.exists(path):
            return
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            return
        # decisions are only valid for the constants they were computed
        # under (matters when cache_path pins the file name but
        # calibrate() swaps the fabric); a uniform topology's tag equals
        # the v2 single-fabric tag, so v2 files migrate transparently
        if payload.get("fabric") != self._fabric_tag():
            return
        schema = int(payload.get("schema", 1))
        if schema > SCHEMA_VERSION:
            return     # written by a newer build; recompute instead
        for key, d in payload.get("decisions", {}).items():
            if schema < 2:
                # v1 keys are "op|p=8|B=..."; every v1 entry is a bare
                # 1D axis, so its topology signature is just (p,)
                key = key.replace("|p=", "|t=", 1)
            rounds = (_freeze_rounds(d["rounds"])
                      if d.get("rounds") else None)
            self._decisions[key] = Decision(
                op=d["op"], p=int(d["p"]), nbytes=int(d["nbytes"]),
                algorithm=d["algorithm"], predicted=float(d["predicted"]),
                predictions={k: float(v)
                             for k, v in d["predictions"].items()},
                rounds=rounds)
            self.stats["persisted_loads"] += 1
        for key, rec in payload.get("plans", {}).items():
            self._plans[key] = rec
            self.stats["persisted_loads"] += 1

    def _maybe_save(self) -> None:
        """Write-behind: cold-start sweeps decide many shapes back to
        back, so full-file rewrites are throttled to ~1/s; ``flush()``
        (also registered atexit) writes the tail."""
        if self._dirty and time.monotonic() - self._last_save >= 1.0:
            self._save_persisted()

    def flush(self) -> None:
        """Force any unsaved decisions to disk now."""
        with self._lock:
            if self._dirty:
                self._save_persisted()

    def _save_persisted(self) -> None:
        if not self._persist:
            self._dirty = False
            return
        raw = {}
        for key, d in self._decisions.items():
            raw[key] = {"op": d.op, "p": d.p, "nbytes": d.nbytes,
                        "algorithm": d.algorithm, "predicted": d.predicted,
                        "predictions": d.predictions,
                        "rounds": [[list(s) for s in r] for r in d.rounds]
                        if d.rounds else None}
        try:
            path = self._cache_path()
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + f".tmp{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump({"schema": SCHEMA_VERSION,
                           "fabric": self._fabric_tag(),
                           "topology": topology_to_dict(self.topology),
                           "decisions": raw, "plans": self._plans}, f)
            os.replace(tmp, path)
        except OSError:
            # unwritable/bogus cache dir: selection still works, it just
            # stays in-memory for this process
            self._persist = False
        self._dirty = False
        self._last_save = time.monotonic()

    def _tables_for(self, p: int):
        tables = self._tables.get(p)
        if tables is None:
            tables = compute_tables(p)
            self._tables[p] = tables
        return tables

    def tree_rounds(self, p: int, b_elements: int,
                    fabric: Optional[Fabric] = None) -> Rounds:
        """Auto-Gen round schedule for (P, B, fabric), DP'd at most
        once; ``fabric`` defaults to the topology's default fabric."""
        fab = fabric or self.topology.default
        with self._lock:
            key = (p, b_elements, fab)
            rounds = self._tree_rounds.get(key)
            if rounds is None:
                self.stats["dp_runs"] += 1
                tree = autogen_tree(p, b_elements, fabric=fab,
                                    tables=self._tables_for(p))
                rounds = _freeze_rounds(tree.to_rounds())
                self._tree_rounds[key] = rounds
            return rounds

    def _fabric_key_suffix(self, fabric: Optional[Fabric]) -> str:
        """Per-axis constants enter the cache key only when they differ
        from the default fabric, so uniform topologies keep the exact v2
        key space."""
        if fabric is None or fabric == self.topology.default:
            return ""
        return f"|f={self._fabric_one_tag(fabric)}"

    def stats_snapshot(self) -> Dict[str, int]:
        """Atomic copy of the cache counters.  ``self.stats`` is
        mutated in place by every caller sharing the engine; exports
        (and asserting tests) want one consistent view, which this
        returns under the same lock the mutations hold."""
        with self._lock:
            return dict(self.stats)

    def select(self, op: str, nbytes: int, p: int,
               topo: Optional[Tuple[int, ...]] = None,
               fabric: Optional[Fabric] = None) -> Decision:
        d, _ = self._select_meta(op, nbytes, p, topo=topo, fabric=fabric)
        return d

    def _select_meta(self, op: str, nbytes: int, p: int,
                     topo: Optional[Tuple[int, ...]] = None,
                     fabric: Optional[Fabric] = None
                     ) -> Tuple[Decision, bool]:
        """Model-driven selection, memoized by the full topology
        signature ``(op, axis_sizes, bytes, fabric)``.  Returns the
        decision plus whether it came from the cache (the bit a span
        records).

        For a bare 1D axis the signature is ``(p,)``; a folded logical
        axis passes its shape as ``topo`` (e.g. ``(2, 8)``) so a 16-way
        ``data`` axis and a 16-way folded ``(pod, data)`` topology never
        share cache entries even though their modeled costs coincide on
        a uniform fabric -- per-axis calibration splits them.
        ``fabric`` prices the candidate set with axis-local constants (a
        non-default axis of a heterogeneous topology); such decisions
        are keyed with an ``|f=`` suffix so the same axis size under
        different link constants never collides.

        ``allreduce`` keeps the paper-selector candidate set (fixed
        patterns + ring); the other ops additionally model their
        Auto-Gen backend, so a cache miss may run the DP (counted in
        ``stats['dp_runs']`` via the tree/table caches).
        """
        if p <= 1:
            return Decision(op, p, nbytes, "identity", 0.0, {}), False
        fab = fabric or self.topology.default
        with self._lock:
            self._load_persisted()
            key = (_topo_key(op, topo or (p,), nbytes)
                   + self._fabric_key_suffix(fabric))
            hit = self._decisions.get(key)
            if hit is not None:
                self.stats["hits"] += 1
                return hit, True
            self.stats["misses"] += 1
            b = self._elements(nbytes)
            # allreduce keeps the paper-selector candidate set; all_to_all
            # has no reduction tree, so neither models an Auto-Gen backend
            include_autogen = op not in ("allreduce", "all_to_all")
            if include_autogen:
                tables = self._tables_for(p)
            else:
                tables = None
            preds = selector.predict_collective(
                op, p, b, fab, include_autogen=include_autogen,
                tables=tables)
            if op == "allreduce":
                # the paper's TPU selector: star loses to its own
                # broadcast on ICI, so it is not a candidate
                preds.pop("star", None)
            name = min(preds, key=preds.get)
            rounds = (self.tree_rounds(p, self._tree_elements(op, b, p),
                                       fabric=fab)
                      if name == "autogen" else None)
            decision = Decision(op, p, nbytes, name, preds[name],
                                {k: float(v) for k, v in preds.items()},
                                rounds)
            self._decisions[key] = decision
            self._dirty = True
            self._maybe_save()
            return decision, False

    def plan_multi(self, op: str, axes: Sequence[str],
                   sizes: Sequence[int], nbytes: int,
                   shape: Optional[str] = None) -> planner.CollectivePlan:
        """Public cover of :meth:`_plan_multi_meta`: the plan without
        the cache-hit bit.  Annotates the innermost open span (if any)
        with the chosen plan and its predicted cost."""
        plan, hit = self._plan_multi_meta(op, axes, sizes, nbytes,
                                          shape=shape)
        sp = obs_trace.get_tracer().current_span()
        if getattr(sp, "args", {}).get("plan") is None:
            sp.set(plan=plan.describe(), n_chunks=int(plan.n_chunks),
                   algorithm=str(plan.shape),
                   predicted=float(plan.predicted),
                   cache="hit" if hit else "miss")
            if shape is not None:
                sp.set(algorithm_forced=True)
        return plan

    def _plan_multi_meta(self, op: str, axes: Sequence[str],
                         sizes: Sequence[int], nbytes: int,
                         shape: Optional[str] = None
                         ) -> Tuple[planner.CollectivePlan, bool]:
        """Topology-aware joint plan for an axis tuple, memoized and
        persisted by ``(op, axis_sizes, bytes, fabric)``.  Returns the
        bound plan plus whether the scored record came from the cache.

        Each axis is priced with its fabric from ``self.topology`` (by
        axis *name*), so hierarchical compositions genuinely win when
        pod links are slower than intra-pod ICI.  Plans whose axes use
        non-default fabrics carry those constants in the cache key --
        the same ``(2, 8)`` shape under different axis bindings never
        collides; uniform topologies keep the exact v2 key space and
        rebind freely across mesh axis names.

        ``shape`` forces a candidate ("hierarchical", "2d_xy", ...)
        instead of taking the model argmin; forced plans are derived
        from the same scored record, so they are cached once too.
        """
        axes = tuple(axes)
        sizes = tuple(int(s) for s in sizes)
        if len(axes) != len(sizes):
            raise ValueError(f"axes {axes} vs sizes {sizes}")
        axis_fabrics = tuple(self.topology.for_axis(a) for a in axes)
        with self._lock:
            self._load_persisted()
            key = _topo_key(op, sizes, nbytes)
            if any(f != self.topology.default for f in axis_fabrics):
                key += "|f=" + ",".join(self._fabric_one_tag(f)
                                        for f in axis_fabrics)
            if shape is not None:
                key += f"|shape={shape}"
            rec = self._plans.get(key)
            hit = rec is not None
            if rec is None:
                self.stats["plan_misses"] += 1
                rec = planner.plan_collective(
                    op, sizes, nbytes, self.fabric, self.element_bytes,
                    self.select, force_shape=shape,
                    axis_fabrics=axis_fabrics)
                self._plans[key] = rec
                self._dirty = True
                self._maybe_save()
            else:
                self.stats["plan_hits"] += 1
        return planner.bind_plan(rec, op, axes), hit

    def clear_cache(self) -> None:
        with self._lock:
            self._decisions.clear()
            self._plans.clear()
            self._tree_rounds.clear()
            self._tables.clear()
            self._loaded = False

    def decision_table(self) -> List[Decision]:
        """Everything decided so far (introspection/reporting)."""
        with self._lock:
            self._load_persisted()
            return sorted(self._decisions.values(),
                          key=lambda d: (d.op, d.p, d.nbytes))

    # ------------------------------------------------------------------ #
    # calibration
    # ------------------------------------------------------------------ #
    def calibrate(self,
                  measurements: Optional[Any] = None,
                  mesh: Optional[Mesh] = None, axis: Optional[str] = None,
                  sizes_bytes: Sequence[int] = (1 << 12, 1 << 16, 1 << 20,
                                                1 << 22)
                  ) -> "Fabric | FabricTopology":
        """Refit the fabric constants from timings and drop stale
        decisions.

        * ``measurements=[(nbytes, seconds), ...]`` -- refit the default
          fabric (uniform topology); returns the fitted :class:`Fabric`.
        * ``measurements={axis: [(nbytes, seconds), ...], ...}`` -- fit
          one Fabric *per axis* on a shared time base: the fastest
          axis's fitted cycle anchors ``link_bw=1`` and slower axes get
          proportionally smaller ``link_bw`` (and their own ``t_r``).
          Returns the new :class:`FabricTopology`.
        * ``mesh=...`` -- run ``measure_ppermute`` per mesh axis (every
          axis of size > 1, or just ``axis`` if given) and fit per-axis
          as above.

        Either way the engine's cache namespace moves to the new
        constants; the next persisted save records the topology in the
        v3 ``topology`` section.
        """
        if measurements is None:
            if mesh is None:
                raise ValueError("calibrate() needs measurements or a mesh")
            axes = ([axis] if axis is not None
                    else [a for a in mesh.axis_names if mesh.shape[a] > 1])
            if not axes:
                raise ValueError(
                    f"calibrate(mesh=...): no axis of size > 1 to "
                    f"measure in mesh {dict(mesh.shape)}")
            measurements = {a: measure_ppermute(mesh, a, sizes_bytes)
                            for a in axes}
        with self._lock:
            base = self.topology.default
            if isinstance(measurements, dict):
                if not measurements:
                    raise ValueError("calibrate() got an empty per-axis "
                                     "measurements dict")
                lines = {a: _fit_line(m, self.element_bytes)
                         for a, m in measurements.items()}
                # a non-positive -- or vanishing -- fitted slope means
                # the timings carry no bandwidth signal; anchoring the
                # shared time base on it would poison every axis's
                # constants (link_bw ratios of ~1e-20) -- fail loudly
                bad = []
                for a, m in measurements.items():
                    alpha, beta = lines[a]
                    els = [max(1, nb // self.element_bytes)
                           for nb, _ in m]
                    rise = beta * (max(els) - min(els))
                    scale = abs(alpha) + abs(beta) * max(els) + 1e-30
                    if beta <= 0.0 or rise < 1e-6 * scale:
                        bad.append(a)
                bad.sort()
                if bad:
                    raise ValueError(
                        f"calibrate(): non-positive fitted slope for "
                        f"axis(es) {bad}; timings are noise-dominated "
                        f"-- raise sizes_bytes/repeats or calibrate "
                        f"those axes separately")
                # shared time base: the fastest axis's seconds/element
                ref = min(beta for _, beta in lines.values())
                fitted = tuple(
                    (a, fit_fabric(measurements[a], base=base,
                                   name=f"{base.name}_{a}",
                                   element_bytes=self.element_bytes,
                                   ref_cycle=ref))
                    for a in sorted(measurements))
                result: "Fabric | FabricTopology" = FabricTopology(
                    default=base, axis_fabrics=fitted,
                    name=f"{base.name}_calibrated")
                self.topology = result
            else:
                fitted_f = fit_fabric(measurements, base=base,
                                      element_bytes=self.element_bytes)
                self.topology = FabricTopology.uniform(fitted_f)
                result = fitted_f
            # fabric changed => cache namespace (file name) changed too;
            # in-memory decisions and plans predate the new constants
            self._decisions.clear()
            self._plans.clear()
            self._tree_rounds.clear()
            self._loaded = False
        return result

    def calibrate_launch(self,
                         samples: Sequence[Tuple[str, int, int, str, float]]
                         ) -> float:
        """Fit ``Fabric.t_launch`` from measured collective wall times.

        ``samples`` is ``[(op, p, nbytes, algorithm, seconds), ...]`` --
        exactly what ``obs.replay.measure_spans`` produces for decode
        traces (``nbytes`` in the model's convention: global bytes for
        allgather).  Under the model a run costs
        ``seconds = cycle * (base_i + t_launch * L_i)`` where ``base_i``
        is the closed form at ``t_launch = 0`` and ``L_i`` the number of
        sequential program launches (:func:`patterns.launch_count`), so
        a two-column least squares over ``(base_i, L_i)`` recovers the
        seconds-per-cycle scale ``c`` and the per-launch seconds ``d``;
        ``t_launch = d / c`` converts back to model cycles.  Mixing
        sizes *and* algorithms with different launch counts is what
        makes the two columns separable -- an all-oneshot sample set
        cannot identify the constant.

        The engine's topology moves to the fitted constant (every
        per-axis fabric gets the same ``t_launch``: launch overhead is a
        host/framework property, not a per-link one), the cache
        namespace moves with it, and stale decisions are dropped.
        Returns the fitted ``t_launch`` (cycles, >= 0)."""
        samples = list(samples)
        if len(samples) < 2:
            raise ValueError("calibrate_launch() needs >= 2 samples")
        rows, secs = [], []
        base_fab = dataclasses.replace(self.topology.default,
                                       t_launch=0.0)
        for op, p, nbytes, algorithm, seconds in samples:
            b = self._elements(int(nbytes))
            preds = selector.predict_collective(op, int(p), b, base_fab,
                                                include_autogen=False)
            if algorithm not in preds:
                raise ValueError(
                    f"calibrate_launch(): no closed form for "
                    f"{op!r}/{algorithm!r} at P={p}")
            rows.append((preds[algorithm],
                         pat.launch_count(op, algorithm, int(p))))
            secs.append(float(seconds))
        a = np.array(rows, dtype=np.float64)
        y = np.array(secs, dtype=np.float64)
        if np.ptp(a[:, 1]) == 0.0:
            raise ValueError(
                "calibrate_launch(): all samples have the same launch "
                "count; mix algorithms/sizes so the per-launch column "
                "is identifiable")
        (c, d), *_ = np.linalg.lstsq(a, y, rcond=None)
        if c <= 0.0:
            raise ValueError(
                "calibrate_launch(): non-positive fitted cycle scale; "
                "timings are noise-dominated -- use larger sizes or "
                "more repeats")
        t_launch = max(float(d / c), 0.0)
        with self._lock:
            new_default = dataclasses.replace(self.topology.default,
                                              t_launch=t_launch)
            new_axes = tuple(
                (axis, dataclasses.replace(f, t_launch=t_launch))
                for axis, f in self.topology.axis_fabrics)
            self.topology = FabricTopology(default=new_default,
                                           axis_fabrics=new_axes,
                                           name=self.topology.name)
            # constants changed => cache namespace moved; in-memory
            # decisions and plans predate the fitted t_launch
            self._decisions.clear()
            self._plans.clear()
            self._tree_rounds.clear()
            self._loaded = False
        return t_launch

    # ------------------------------------------------------------------ #
    # dispatch: *_inside run under an existing shard_map axis binding
    # ------------------------------------------------------------------ #
    @staticmethod
    def _tree_elements(op: str, b: int, p: int) -> int:
        """Vector length the Auto-Gen DP should optimize for: the
        chunked ops run the tree per B/P-element chunk (that is also
        the size their `autogen` prediction was priced at)."""
        if op in ("reduce_scatter", "allgather"):
            return max(1, -(-b // p))
        return b

    def _resolve(self, op: str, nbytes: int, p: int, algorithm: str,
                 axis: Any = None) -> Tuple[str, Optional[Rounds]]:
        """``nbytes`` is always the GLOBAL vector size the cost model is
        written in terms of (callers of allgather pass shard * P).
        ``axis`` (a mesh axis name, or a tuple for a folded logical
        axis) resolves the axis-local fabric on a heterogeneous
        topology."""
        fab = self.topology.for_axis(axis)
        # annotate the innermost open span -- first writer wins, so a
        # nested resolution (allreduce -> reduce) never overwrites the
        # outer op's decision on the outer op's span
        sp = obs_trace.get_tracer().current_span()
        if getattr(sp, "args", {}).get("algorithm") is not None:
            sp = obs_trace.NULL_SPAN
        if algorithm == "auto":
            d, hit = self._select_meta(op, nbytes, p, fabric=fab)
            sp.set(algorithm=d.algorithm, predicted=float(d.predicted),
                   cache="hit" if hit else "miss",
                   regime=("latency" if d.algorithm == "oneshot"
                           else "bandwidth"))
            if d.algorithm == "oneshot":
                with self._lock:
                    self.stats["latency_dispatches"] += 1
            return d.algorithm, d.rounds
        sp.set(algorithm=algorithm, algorithm_forced=True, cache="forced",
               regime="latency" if algorithm == "oneshot" else "bandwidth")
        if algorithm == "oneshot":
            with self._lock:
                self.stats["latency_dispatches"] += 1
        if algorithm in ("autogen", "autogen_pipelined"):
            b = self._tree_elements(op, self._elements(nbytes), p)
            return algorithm, self.tree_rounds(p, b, fabric=fab)
        return algorithm, None

    # ------------------------------------------------------------------ #
    # span plumbing: every public collective opens one CAT_COLLECTIVE
    # span; `_resolve` / `plan_multi` annotate it with the decision
    # ------------------------------------------------------------------ #
    @staticmethod
    def _collective_span(name: str, op: str, axis_or_axes: Any,
                         nbytes: int, algorithm: str):
        """Open a collective span carrying every key the trace schema
        requires (``REQUIRED_COLLECTIVE_ARGS``), so a span is
        conformant even when the op bypasses the model (native/forced
        paths fill the rest in :meth:`_finish_collective`)."""
        tracer = obs_trace.get_tracer()
        if not tracer.enabled:
            return obs_trace.NULL_SPAN
        if isinstance(axis_or_axes, (tuple, list)):
            names = tuple(str(a) for a in axis_or_axes)
        else:
            names = (str(axis_or_axes),)
        try:
            sizes = tuple(int(impl._axis_size(a)) for a in names)
        except Exception:
            sizes = ()
        return tracer.span(
            name, cat=obs_trace.CAT_COLLECTIVE, op=op, axes=names,
            axis_sizes=sizes, bytes=int(nbytes),
            requested=str(algorithm), plan=None, algorithm=None,
            cache=None, predicted=None, measured_s=None, mode=None)

    @staticmethod
    def _finish_collective(sp, out: jax.Array, requested: str) -> None:
        """Close out a collective span: paths that never reached the
        model (native XLA ops, identity axes) stamp the requested
        algorithm as forced, then the result stamps mode/wall time."""
        span = getattr(sp, "span", None)
        if span is not None and span.args.get("algorithm") is None:
            sp.set(algorithm=str(requested), algorithm_forced=True,
                   cache="forced")
        sp.finish_result(out)

    def reduce_inside(self, x: jax.Array, axis: str,
                      algorithm: str = "auto") -> jax.Array:
        """Paper Reduce: full sum lands on device 0 of the axis."""
        p = impl._axis_size(axis)
        if p == 1:
            return x
        algorithm, rounds = self._resolve("reduce", x.size * x.dtype.itemsize,
                                          p, algorithm, axis)
        if algorithm == "chain":
            return impl.chain_reduce(x, axis)
        if algorithm == "tree":
            return impl.tree_reduce(x, axis)
        if algorithm == "two_phase":
            return impl.two_phase_reduce(x, axis)
        if algorithm == "star":
            return impl.star_reduce(x, axis)
        if algorithm == "autogen":
            return impl.schedule_reduce(x, axis, rounds)
        if algorithm == "autogen_pipelined":
            flat = x.reshape(-1)
            out = impl.schedule_reduce_pipelined(flat, axis, rounds)
            return out.reshape(x.shape)
        raise ValueError(f"unknown reduce algorithm {algorithm!r}")

    def allreduce_inside(self, x: jax.Array, axis: str,
                         algorithm: str = "auto") -> jax.Array:
        if not obs_trace.get_tracer().enabled:
            return self._allreduce_inside(x, axis, algorithm)
        with self._collective_span("allreduce_inside", "allreduce", axis,
                                   x.size * x.dtype.itemsize,
                                   algorithm) as sp:
            out = self._allreduce_inside(x, axis, algorithm)
            self._finish_collective(sp, out, algorithm)
            return out

    def _allreduce_inside(self, x: jax.Array, axis: str,
                          algorithm: str = "auto") -> jax.Array:
        if algorithm == "psum":
            return lax.psum(x, axis)
        p = impl._axis_size(axis)
        if p == 1:
            return x
        algorithm, rounds = self._resolve(
            "allreduce", x.size * x.dtype.itemsize, p, algorithm, axis)
        if algorithm == "oneshot":
            # the latency regime: one fused XLA program over the (possibly
            # folded) axis -- depth 1, a single launch, no staging
            return lax.psum(x, axis)
        if algorithm == "ring":
            flat = x.reshape(-1)
            return impl.ring_allreduce(flat, axis).reshape(x.shape)
        red = self.reduce_inside(x, axis, algorithm)
        return impl.broadcast(red, axis, root=0)

    def reduce_scatter_inside(self, x: jax.Array, axis: str,
                              algorithm: str = "auto") -> jax.Array:
        """Sum over the axis, shard the result: device i gets chunk i
        (``lax.psum_scatter(..., tiled=True)`` semantics; leading dim
        divisible by P)."""
        if not obs_trace.get_tracer().enabled:
            return self._reduce_scatter_inside(x, axis, algorithm)
        with self._collective_span("reduce_scatter_inside",
                                   "reduce_scatter", axis,
                                   x.size * x.dtype.itemsize,
                                   algorithm) as sp:
            out = self._reduce_scatter_inside(x, axis, algorithm)
            self._finish_collective(sp, out, algorithm)
            return out

    def _reduce_scatter_inside(self, x: jax.Array, axis: str,
                               algorithm: str = "auto") -> jax.Array:
        p = impl._axis_size(axis)
        if p == 1:
            return x
        if algorithm != "psum_scatter":
            algorithm, rounds = self._resolve(
                "reduce_scatter", x.size * x.dtype.itemsize, p, algorithm,
                axis)
        if algorithm == "psum_scatter":
            return lax.psum_scatter(x, axis, scatter_dimension=0,
                                    tiled=True)
        if algorithm == "ring":
            return impl.reduce_scatter_ring(x, axis)
        if algorithm == "autogen":
            return impl.schedule_reduce_scatter(x, axis, rounds)
        raise ValueError(f"unknown reduce_scatter algorithm {algorithm!r}")

    def allgather_inside(self, x: jax.Array, axis: str,
                         algorithm: str = "auto") -> jax.Array:
        """Gather shards along the axis into the leading dim
        (``lax.all_gather(..., tiled=True)`` semantics)."""
        if not obs_trace.get_tracer().enabled:
            return self._allgather_inside(x, axis, algorithm)
        # the span (like the cost model) records the GLOBAL gathered
        # bytes, shard * P -- the replayer relies on this convention
        nbytes = x.size * x.dtype.itemsize * impl._axis_size(axis)
        with self._collective_span("allgather_inside", "allgather",
                                   axis, nbytes, algorithm) as sp:
            out = self._allgather_inside(x, axis, algorithm)
            self._finish_collective(sp, out, algorithm)
            return out

    def _allgather_inside(self, x: jax.Array, axis: str,
                          algorithm: str = "auto") -> jax.Array:
        p = impl._axis_size(axis)
        if p == 1:
            return x
        if algorithm != "all_gather":
            # x is the local shard; the cost model prices the global
            # gather, so scale by P
            algorithm, rounds = self._resolve(
                "allgather", x.size * x.dtype.itemsize * p, p, algorithm,
                axis)
        if algorithm in ("all_gather", "oneshot"):
            # "oneshot" is the latency-regime selection of the same
            # single-program gather ("all_gather" is the forced native
            # path that bypasses the model)
            return lax.all_gather(x, axis, tiled=True)
        if algorithm == "ring":
            return impl.allgather_ring(x, axis)
        if algorithm == "doubling":
            return impl.allgather_doubling(x, axis)
        if algorithm == "autogen":
            return impl.schedule_allgather(x, axis, rounds)
        raise ValueError(f"unknown allgather algorithm {algorithm!r}")

    def all_to_all_inside(self, x: jax.Array, axis, algorithm: str = "auto"
                          ) -> jax.Array:
        """Personalized exchange along one axis (or a row-major-folded
        axis tuple): ``lax.all_to_all(x, axis, split_axis=0,
        concat_axis=0, tiled=True)`` semantics -- x is [P*m, ...] with
        destination-major leading chunks, the result source-major.
        ``algorithm``: ``lax`` (XLA native), ``ring``
        (pairwise-exchange, injection-optimal), ``halving`` (Bruck,
        log-launch), or ``auto`` (model argmin)."""
        if not obs_trace.get_tracer().enabled:
            return self._all_to_all_inside(x, axis, algorithm)
        with self._collective_span("all_to_all_inside", "all_to_all",
                                   axis, x.size * x.dtype.itemsize,
                                   algorithm) as sp:
            out = self._all_to_all_inside(x, axis, algorithm)
            self._finish_collective(sp, out, algorithm)
            return out

    def _all_to_all_inside(self, x: jax.Array, axis,
                           algorithm: str = "auto") -> jax.Array:
        p = impl._axis_size(axis)
        if p == 1:
            return x
        if algorithm == "lax":
            return lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
        algorithm, _ = self._resolve(
            "all_to_all", x.size * x.dtype.itemsize, p, algorithm, axis)
        if algorithm == "oneshot":
            return lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
        if algorithm == "ring":
            return impl.all_to_all_ring(x, axis)
        if algorithm == "halving":
            return impl.all_to_all_bruck(x, axis)
        raise ValueError(f"unknown all_to_all algorithm {algorithm!r}")

    def broadcast_inside(self, x: jax.Array, axis: str, root: int = 0,
                         algorithm: str = "auto") -> jax.Array:
        p = impl._axis_size(axis)
        if p == 1:
            return x
        algorithm, rounds = self._resolve(
            "broadcast", x.size * x.dtype.itemsize, p, algorithm, axis)
        if algorithm == "doubling":
            return impl.broadcast(x, axis, root=root)
        if algorithm == "chain":
            return impl.chain_broadcast(x, axis, root=root)
        if algorithm == "autogen":
            if root != 0:
                rounds = impl._rotate_rounds(rounds, p, root)
            seeded = jnp.where(impl._axis_index(axis) == root, x,
                               jnp.zeros_like(x))
            return impl.schedule_broadcast(seeded, axis, rounds)
        raise ValueError(f"unknown broadcast algorithm {algorithm!r}")

    # ------------------------------------------------------------------ #
    # multi-axis dispatch: planner-driven joint plans over axis tuples
    # ------------------------------------------------------------------ #
    @staticmethod
    def _multi_sizes(axes: Sequence[str]) -> Tuple[int, ...]:
        return tuple(impl._axis_size(a) for a in axes)

    @staticmethod
    def _chunk_transpose(x: jax.Array, sizes: Sequence[int]) -> jax.Array:
        """Reorder leading-dim chunks from row-major blocks over
        ``sizes`` to row-major blocks over ``reversed(sizes)`` -- the
        permutation that makes the innermost-first reduce-scatter
        cascade land chunks in ``lax.psum_scatter`` (device-major)
        order."""
        k = len(sizes)
        blocks = x.reshape(tuple(sizes) + (-1,) + x.shape[1:])
        perm = tuple(reversed(range(k))) + tuple(range(k, blocks.ndim))
        return blocks.transpose(perm).reshape(x.shape)

    # ------------------------------------------------------------------ #
    # chunked phase-runner: one wavefront executor for every plan
    # ------------------------------------------------------------------ #
    @staticmethod
    def _phase_names(steps: Sequence["planner.PlanStep"]) -> List[str]:
        """Human labels for a plan's phases, mirroring
        ``CollectivePlan.describe()`` per step."""
        return [
            f"{planner._KIND_ABBREV.get(s.kind, s.kind)}:"
            f"{s.algorithm}@{'x'.join(s.axes)}"
            for s in steps]

    def _run_phases(self, chunks: List[jax.Array],
                    phase_fns: Sequence[Callable[[jax.Array], jax.Array]],
                    op: Optional[str] = None,
                    phase_names: Optional[Sequence[str]] = None
                    ) -> List[jax.Array]:
        """Execute ``phase_fns`` over payload ``chunks`` as a wavefront
        pipeline: in wave ``w``, chunk ``k`` runs phase ``w - k`` -- so
        chunk 0's outer (cross-pod) phase is issued alongside chunk 1's
        inner phase, and phases on disjoint link classes overlap.  The
        chunks are data-independent, so nothing in the emitted program
        orders one chunk's phase after another chunk's; the compiler is
        free to run them concurrently.  With a single chunk this
        degenerates to running the phases back-to-back -- the
        serialized plan executor, shared by every plan shape.

        With tracing enabled each phase call is wrapped in a
        ``jax.named_scope`` (so an XLA profile lines up with the plan's
        phase decomposition) and emits a nested CAT_PHASE span; phase
        spans never block, whatever the tracer's measurement mode."""
        tracer = obs_trace.get_tracer()
        chunks = list(chunks)
        n = len(phase_fns)
        for wave in range(n + len(chunks) - 1):
            for k in range(len(chunks)):
                r = wave - k
                if not 0 <= r < n:
                    continue
                if not tracer.enabled:
                    chunks[k] = phase_fns[r](chunks[k])
                    continue
                label = (phase_names[r]
                         if phase_names and r < len(phase_names)
                         else f"phase{r}")
                scope = f"{op or 'collective'}.{label}".replace(":", "_")
                with jax.named_scope(scope), \
                        tracer.span(label, cat=obs_trace.CAT_PHASE,
                                    op=op, phase=r, chunk=k,
                                    wave=wave) as sp:
                    chunks[k] = phase_fns[r](chunks[k])
                    sp.finish_result(chunks[k], block=False)
        return chunks

    @staticmethod
    def _split_row_chunks(x: jax.Array, p: int, c: int
                          ) -> Tuple[List[jax.Array], int]:
        """Slice ``x`` ([p*m, ...], p row-major device blocks) into
        ``c`` chunks of ``[p*mc, ...]``, chunk ``k`` carrying rows
        ``[k*mc, (k+1)*mc)`` of every device block (zero-padded when
        ``c`` does not divide m).  Returns the chunks and m."""
        m = x.shape[0] // p
        mc = ceil_div(m, c)
        blocks = x.reshape((p, m) + x.shape[1:])
        pad = c * mc - m
        if pad:
            widths = [(0, 0)] * blocks.ndim
            widths[1] = (0, pad)
            blocks = jnp.pad(blocks, widths)
        return [blocks[:, k * mc:(k + 1) * mc].reshape(
                    (p * mc,) + x.shape[1:])
                for k in range(c)], m

    @staticmethod
    def _join_row_chunks(chunks: List[jax.Array], p: int, m: int
                         ) -> jax.Array:
        """Inverse of :meth:`_split_row_chunks` on the output side:
        chunk ``k`` holds rows ``[k*mc, (k+1)*mc)`` of every device
        block of the [p*m, ...] result (pad rows dropped)."""
        mc = chunks[0].shape[0] // p
        trailing = chunks[0].shape[1:]
        parts = [ch.reshape((p, mc) + trailing) for ch in chunks]
        out = jnp.concatenate(parts, axis=1)[:, :m]
        return out.reshape((p * m,) + trailing)

    def allreduce_multi(self, x: jax.Array, axes: Sequence[str],
                        algorithm: str = "auto") -> jax.Array:
        """AllReduce over an axis tuple through a joint topology plan.

        ``algorithm`` is either ``"auto"`` (planner argmin), a plan
        shape (``"sequential" | "hierarchical" | "2d_xy" | "2d_snake" |
        "flat"`` or a ``*_pipelined`` variant, executed chunked over
        ``plan.n_chunks`` payload slices), ``"psum"`` (XLA native over
        the folded axes), or a 1D backend name, which forces the
        sequential shape with that backend on every axis (the legacy
        per-axis loop).
        """
        axes = tuple(axes)
        if len(axes) == 1:
            return self.allreduce_inside(x, axes[0], algorithm)
        if not obs_trace.get_tracer().enabled:
            return self._allreduce_multi(x, axes, algorithm)
        with self._collective_span("allreduce_multi", "allreduce", axes,
                                   x.size * x.dtype.itemsize,
                                   algorithm) as sp:
            out = self._allreduce_multi(x, axes, algorithm)
            self._finish_collective(sp, out, algorithm)
            return out

    def _allreduce_multi(self, x: jax.Array, axes: Tuple[str, ...],
                         algorithm: str) -> jax.Array:
        if algorithm == "psum":
            return lax.psum(x, axes)
        sizes = self._multi_sizes(axes)
        if all(s == 1 for s in sizes):
            return x
        nbytes = x.size * x.dtype.itemsize
        if algorithm == "auto" or algorithm in planner.ALLREDUCE_SHAPES:
            shape = None if algorithm == "auto" else algorithm
            plan = self.plan_multi("allreduce", axes, sizes, nbytes,
                                   shape=shape)
            return self._run_allreduce_plan(x, plan)
        # legacy: explicit 1D backend, innermost axis first
        for ax in reversed(axes):
            x = self.allreduce_inside(x, ax, algorithm)
        return x

    def _run_allreduce_plan(self, x: jax.Array,
                            plan: "planner.CollectivePlan") -> jax.Array:
        if plan.shape == "identity":
            return x
        if plan.shape == "2d_xy":
            (step,) = plan.steps
            patterns = tuple(step.algorithm.split("x"))
            return impl.xy_allreduce_2d(x, step.axes, patterns)
        if plan.shape == "2d_snake":
            (step,) = plan.steps
            return impl.snake_allreduce_2d(x, step.axes)
        if plan.shape in ("flat", "latency"):
            # both are one step over the folded axis tuple; "latency"
            # carries the "oneshot" algorithm, dispatched as a single
            # fused XLA collective (no chunking, no cascade)
            (step,) = plan.steps
            return self.allreduce_inside(x, step.axes, step.algorithm)
        base = planner.base_shape(plan.shape)
        if base not in ("sequential", "hierarchical"):
            raise ValueError(f"unknown plan shape {plan.shape!r}")
        shape0 = x.shape
        flat = x.reshape(-1)
        n = flat.size
        c = max(1, plan.n_chunks)
        chunk_len = ceil_div(n, c)
        pad = c * chunk_len - n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        chunks = [flat[k * chunk_len:(k + 1) * chunk_len]
                  for k in range(c)]
        fns = self._allreduce_phase_fns(plan, base, chunk_len)
        chunks = self._run_phases(chunks, fns, op="allreduce",
                                  phase_names=self._phase_names(plan.steps))
        out = jnp.concatenate(chunks) if c > 1 else chunks[0]
        if pad:
            out = out[:n]
        return out.reshape(shape0)

    def _allreduce_phase_fns(self, plan: "planner.CollectivePlan",
                             base: str, chunk_len: int
                             ) -> List[Callable[[jax.Array], jax.Array]]:
        """Per-phase closures mapping a flat ``[chunk_len]`` slice to
        its reduced ``[chunk_len]`` -- the executable form of a
        sequential or hierarchical allreduce plan, fed to
        :meth:`_run_phases`."""
        if base == "sequential":
            return [
                (lambda v, s=step: self.allreduce_inside(
                    v, s.axes[0], s.algorithm))
                for step in plan.steps]
        rs, mid, ag = plan.steps
        inner = rs.axes[0]
        p_in = impl._axis_size(inner)
        pad = (-chunk_len) % p_in

        def f_rs(v):
            if pad:
                v = jnp.pad(v, (0, pad))
            return self.reduce_scatter_inside(v, inner,
                                              algorithm=rs.algorithm)

        def f_mid(v):
            return self.allreduce_multi(v, mid.axes,
                                        algorithm=mid.algorithm)

        def f_ag(v):
            v = self.allgather_inside(v, inner, algorithm=ag.algorithm)
            return v[:chunk_len] if pad else v

        return [f_rs, f_mid, f_ag]

    def reduce_scatter_multi(self, x: jax.Array, axes: Sequence[str],
                             algorithm: str = "auto") -> jax.Array:
        """Sum over the folded axes, shard the result device-major
        (``lax.psum_scatter(x, axes, tiled=True)`` semantics; leading
        dim divisible by the folded size)."""
        axes = tuple(axes)
        if len(axes) == 1:
            return self.reduce_scatter_inside(x, axes[0], algorithm)
        if not obs_trace.get_tracer().enabled:
            return self._reduce_scatter_multi(x, axes, algorithm)
        with self._collective_span("reduce_scatter_multi",
                                   "reduce_scatter", axes,
                                   x.size * x.dtype.itemsize,
                                   algorithm) as sp:
            out = self._reduce_scatter_multi(x, axes, algorithm)
            self._finish_collective(sp, out, algorithm)
            return out

    def _reduce_scatter_multi(self, x: jax.Array, axes: Tuple[str, ...],
                              algorithm: str) -> jax.Array:
        if algorithm == "psum_scatter":
            return lax.psum_scatter(x, axes, scatter_dimension=0,
                                    tiled=True)
        sizes = self._multi_sizes(axes)
        p = 1
        for s in sizes:
            p *= s
        if p == 1:
            return x
        assert x.shape[0] % p == 0, (x.shape, p)
        nbytes = x.size * x.dtype.itemsize
        shape = None if algorithm == "auto" else algorithm
        plan = self.plan_multi("reduce_scatter", axes, sizes, nbytes,
                               shape=shape)
        if plan.shape == "flat":
            (step,) = plan.steps
            return self.reduce_scatter_inside(x, step.axes,
                                              step.algorithm)
        # cascade: pre-permute chunks so the innermost-first shrink
        # lands each device on its psum_scatter chunk; a pipelined plan
        # slices each device block's rows and wavefronts the phases
        steps = plan.steps

        def f0(v, s=steps[0]):
            return self.reduce_scatter_inside(
                self._chunk_transpose(v, sizes), s.axes[0], s.algorithm)

        fns = [f0] + [
            (lambda v, s=step: self.reduce_scatter_inside(
                v, s.axes[0], s.algorithm))
            for step in steps[1:]]
        names = self._phase_names(steps)
        c = max(1, plan.n_chunks)
        if c == 1:
            return self._run_phases([x], fns, op="reduce_scatter",
                                    phase_names=names)[0]
        chunks, m = self._split_row_chunks(x, p, c)
        chunks = self._run_phases(chunks, fns, op="reduce_scatter",
                                  phase_names=names)
        return jnp.concatenate(chunks, axis=0)[:m]

    def allgather_multi(self, x: jax.Array, axes: Sequence[str],
                        algorithm: str = "auto") -> jax.Array:
        """Gather device-major shards along the folded axes into the
        leading dim (``lax.all_gather(x, axes, tiled=True)``
        semantics)."""
        axes = tuple(axes)
        if len(axes) == 1:
            return self.allgather_inside(x, axes[0], algorithm)
        if not obs_trace.get_tracer().enabled:
            return self._allgather_multi(x, axes, algorithm)
        # global gathered bytes, matching the model's B and the replayer
        nbytes = x.size * x.dtype.itemsize * impl._axis_size(axes)
        with self._collective_span("allgather_multi", "allgather", axes,
                                   nbytes, algorithm) as sp:
            out = self._allgather_multi(x, axes, algorithm)
            self._finish_collective(sp, out, algorithm)
            return out

    def _allgather_multi(self, x: jax.Array, axes: Tuple[str, ...],
                         algorithm: str) -> jax.Array:
        if algorithm == "all_gather":
            return lax.all_gather(x, axes, tiled=True)
        sizes = self._multi_sizes(axes)
        p = 1
        for s in sizes:
            p *= s
        if p == 1:
            return x
        nbytes = x.size * x.dtype.itemsize * p
        shape = None if algorithm == "auto" else algorithm
        plan = self.plan_multi("allgather", axes, sizes, nbytes,
                               shape=shape)
        if plan.shape in ("flat", "latency"):
            (step,) = plan.steps
            return self.allgather_inside(x, step.axes, step.algorithm)
        # cascade: outermost-first growth, then undo the chunk
        # permutation the matching reduce-scatter cascade applied; a
        # pipelined plan slices the shard's rows and wavefronts
        steps = plan.steps

        def f_last(v, s=steps[-1]):
            return self._chunk_transpose(
                self.allgather_inside(v, s.axes[0], s.algorithm),
                tuple(reversed(sizes)))

        fns = [
            (lambda v, s=step: self.allgather_inside(
                v, s.axes[0], s.algorithm))
            for step in steps[:-1]] + [f_last]
        names = self._phase_names(steps)
        c = max(1, plan.n_chunks)
        if c == 1:
            return self._run_phases([x], fns, op="allgather",
                                    phase_names=names)[0]
        s_len = x.shape[0]
        sc = ceil_div(s_len, c)
        pad = c * sc - s_len
        xp = x
        if pad:
            widths = [(0, 0)] * x.ndim
            widths[0] = (0, pad)
            xp = jnp.pad(x, widths)
        chunks = [xp[k * sc:(k + 1) * sc] for k in range(c)]
        chunks = self._run_phases(chunks, fns, op="allgather",
                                  phase_names=names)
        return self._join_row_chunks(chunks, p, s_len)

    def all_to_all_multi(self, x: jax.Array, axes: Sequence[str],
                         algorithm: str = "auto") -> jax.Array:
        """Personalized exchange over an axis tuple through a joint
        topology plan (``lax.all_to_all(x, axes, 0, 0, tiled=True)``
        semantics over the row-major-folded axes).

        ``algorithm`` is ``"auto"`` (planner argmin), a plan shape
        (``"hierarchical" | "sequential" | "flat"`` or a
        ``*_pipelined`` variant, executed chunked over
        ``plan.n_chunks`` payload slices), ``"lax"`` (XLA native
        single-shot over the folded axes), or a 1D backend name
        (``ring``/``halving``), which forces the hierarchical
        (innermost-first) phase order with that backend on every axis.
        """
        axes = tuple(axes)
        if len(axes) == 1:
            # a plan shape collapses to the 1D selector on a bare axis
            if algorithm in planner.ALL_TO_ALL_SHAPES:
                algorithm = "auto"
            return self.all_to_all_inside(x, axes[0], algorithm)
        if not obs_trace.get_tracer().enabled:
            return self._all_to_all_multi(x, axes, algorithm)
        with self._collective_span("all_to_all_multi", "all_to_all",
                                   axes, x.size * x.dtype.itemsize,
                                   algorithm) as sp:
            out = self._all_to_all_multi(x, axes, algorithm)
            self._finish_collective(sp, out, algorithm)
            return out

    def _all_to_all_multi(self, x: jax.Array, axes: Tuple[str, ...],
                          algorithm: str) -> jax.Array:
        if algorithm == "lax":
            return lax.all_to_all(x, axes, split_axis=0, concat_axis=0,
                                  tiled=True)
        sizes = self._multi_sizes(axes)
        p = 1
        for s in sizes:
            p *= s
        if p == 1:
            return x
        assert x.shape[0] % p == 0, (x.shape, p)
        nbytes = x.size * x.dtype.itemsize
        if algorithm == "auto" or algorithm in planner.ALL_TO_ALL_SHAPES:
            shape = None if algorithm == "auto" else algorithm
            plan = self.plan_multi("all_to_all", axes, sizes, nbytes,
                                   shape=shape)
            if plan.shape in ("flat", "latency"):
                (step,) = plan.steps
                return self.all_to_all_inside(x, step.axes,
                                              step.algorithm)
            return self._run_a2a_phases(x, axes, sizes, plan.steps,
                                        plan.n_chunks)
        # legacy: explicit 1D backend on every axis, innermost first
        steps = tuple(
            planner.PlanStep("all_to_all", (a,), algorithm, nbytes)
            for a, s in zip(reversed(axes), reversed(sizes)) if s > 1)
        return self._run_a2a_phases(x, axes, sizes, steps)

    def _a2a_phase_fns(self, axes: Tuple[str, ...],
                       sizes: Tuple[int, ...],
                       steps: Sequence["planner.PlanStep"]
                       ) -> List[Callable[[jax.Array], jax.Array]]:
        """Per-phase closures over the block grid.  Each closure views
        its input's leading dim as a ``sizes``-shaped grid of blocks
        and exchanges along block dim *i* only, turning that
        destination coordinate into the source coordinate in place --
        self-contained per step, so any chunk size divisible into the
        grid runs the same way."""
        k = len(sizes)
        p = 1
        for s in sizes:
            p *= s

        def make(step):
            i = axes.index(step.axes[0])
            perm = ((i,) + tuple(j for j in range(k) if j != i))
            inv = tuple(int(j) for j in np.argsort(perm))

            def fn(v):
                m = v.shape[0] // p
                blocks = v.reshape(tuple(sizes) + (m,) + v.shape[1:])
                full_perm = perm + tuple(range(k, blocks.ndim))
                t = blocks.transpose(full_perm)
                flat = t.reshape((-1,) + v.shape[1:])
                out = self.all_to_all_inside(flat, step.axes[0],
                                             algorithm=step.algorithm)
                full_inv = inv + tuple(range(k, blocks.ndim))
                return out.reshape(t.shape).transpose(full_inv).reshape(
                    v.shape)

            return fn

        return [make(step) for step in steps]

    def _run_a2a_phases(self, x: jax.Array, axes: Tuple[str, ...],
                        sizes: Tuple[int, ...],
                        steps: Sequence["planner.PlanStep"],
                        n_chunks: int = 1) -> jax.Array:
        """Execute per-axis all-to-all phases over the block grid.

        The leading dim is viewed as a ``sizes``-shaped grid of blocks
        (destination-major).  A phase on axis *i* exchanges along block
        dim *i* only, turning that destination coordinate into the
        source coordinate in place -- so after every effective axis has
        run once (any order), the block grid is source-major row-major,
        exactly ``lax.all_to_all`` over the folded tuple.  With
        ``n_chunks > 1`` each block contributes a row slice per chunk
        and the phases run as a wavefront pipeline."""
        fns = self._a2a_phase_fns(axes, sizes, steps)
        names = self._phase_names(steps)
        c = max(1, n_chunks)
        if c == 1:
            return self._run_phases([x], fns, op="all_to_all",
                                    phase_names=names)[0]
        p = 1
        for s in sizes:
            p *= s
        chunks, m = self._split_row_chunks(x, p, c)
        chunks = self._run_phases(chunks, fns, op="all_to_all",
                                  phase_names=names)
        return self._join_row_chunks(chunks, p, m)

    # ------------------------------------------------------------------ #
    # fused compute + collective: matmul feeding a ring reduce-scatter
    # ------------------------------------------------------------------ #
    def price_fused_matmul_rs(self, m: int, k: int, n: int, p: int,
                              axes: Any = None, dtype_bytes: int = 4
                              ) -> Dict[str, float]:
        """Model prices for the fused vs serialized matmul+RS.

        ``[m, k] @ [k, n]`` per device, reduce-scattered over a P-way
        axis (``axes`` resolves the fabric on a heterogeneous topology;
        a tuple folds to the slowest member, as the planner prices flat
        phases).  ``fused`` is the PR 6 overlap closed form with C = P
        chunks (``patterns.t_fused_matmul_rs``); ``serial`` is the full
        GEMM followed by the best cached reduce-scatter decision.
        ``saved`` > 0 is the model saying the block GEMMs are long
        enough to hide the ring hops -- the bit ``"auto"`` dispatch
        acts on."""
        if isinstance(axes, (tuple, list)):
            axes = tuple(axes)
        fab = self.topology.for_axis(axes)
        nbytes = int(m) * int(n) * int(dtype_bytes)
        t_mm = pat.t_matmul(m, k, n)
        fused = pat.t_fused_matmul_rs(p, self._elements(nbytes), t_mm,
                                      fab)
        rs = self.select("reduce_scatter", nbytes, p, fabric=fab)
        serial = t_mm + rs.predicted
        return {"fused": float(fused), "serial": float(serial),
                "saved": float(serial - fused), "t_mm": float(t_mm),
                "t_rs": float(rs.predicted)}

    def fused_matmul_reduce_scatter(self, x: jax.Array,
                                    w: Optional[jax.Array], axes, *,
                                    algorithm: str = "auto",
                                    block_m: Optional[int] = None,
                                    block_n: Optional[int] = None,
                                    interpret: bool = True) -> jax.Array:
        """``reduce_scatter(x @ w)`` over ``axes`` with the GEMM tiles
        overlapping the ring's wire time, run inside shard_map.

        ``x``: local ``[M, K_loc]``; ``w``: local ``[K_loc, N]`` (the
        contraction dim sharded over ``axes``); returns ``[M/P, N]``
        with device ``i`` holding row block ``i`` of the summed product
        (``lax.psum_scatter(..., tiled=True)`` semantics).

        ``algorithm``: ``"auto"`` runs the fused ring exactly when the
        model prices it below the serialized GEMM-then-RS
        (:meth:`price_fused_matmul_rs`); ``"fused"`` / ``"unfused"``
        force either path.  ``w=None`` means the call site has no local
        GEMM to fuse (the FSDP grad-sync reduce-scatter) and the call
        degenerates to the engine's chunk-overlapped reduce-scatter
        over the same axes -- the same opt-in flag covers both sites.
        Shapes the ring cannot tile (M not divisible by P) fall back to
        the gathered path."""
        from repro.kernels import fused_matmul_rs as fk
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        if w is None:
            if len(axes) == 1:
                return self.reduce_scatter_inside(x, axes[0], algorithm)
            return self.reduce_scatter_multi(x, axes, algorithm)
        axis = axes[0] if len(axes) == 1 else axes
        p = impl._axis_size(axis)
        m, k = x.shape
        n = w.shape[-1]
        price = self.price_fused_matmul_rs(
            m, k, n, p, axes=axis, dtype_bytes=x.dtype.itemsize)
        if algorithm == "fused":
            use_fused = True
        elif algorithm == "unfused":
            use_fused = False
        else:
            use_fused = price["saved"] > 0.0
        if m % max(p, 1) != 0:
            use_fused = False       # ring cannot tile the rows
        kwargs: Dict[str, Any] = {"interpret": interpret}
        if block_m is not None:
            kwargs["block_m"] = block_m
        if block_n is not None:
            kwargs["block_n"] = block_n

        def run() -> jax.Array:
            if use_fused:
                return fk.fused_matmul_rs(x, w, axis, **kwargs)
            return fk.matmul_then_rs(x, w, axis)

        if not obs_trace.get_tracer().enabled:
            return run()
        with self._collective_span("fused_matmul_rs", "fused_matmul_rs",
                                   axes, m * n * x.dtype.itemsize,
                                   algorithm) as sp:
            sp.set(algorithm="fused_ring" if use_fused else "unfused",
                   algorithm_forced=algorithm != "auto", cache="model",
                   predicted=price["fused" if use_fused else "serial"],
                   overlap_saved=price["saved"])
            out = run()
            self._finish_collective(sp, out, algorithm)
            return out

    # ------------------------------------------------------------------ #
    # outer wrappers: build the shard_map for replicated operands
    # ------------------------------------------------------------------ #
    def _wrap(self, fn: Callable[[jax.Array], jax.Array], mesh: Mesh,
              in_spec: P, out_spec: P) -> Callable[[jax.Array], jax.Array]:
        return shard_map(fn, mesh=mesh, in_specs=in_spec,
                         out_specs=out_spec, check_rep=False)

    def allreduce(self, x: jax.Array, mesh: Mesh, axis: str,
                  algorithm: str = "auto") -> jax.Array:
        fn = lambda v: self.allreduce_inside(v, axis, algorithm)
        return self._wrap(fn, mesh, P(), P())(x)

    def reduce_to_root(self, x: jax.Array, mesh: Mesh, axis: str,
                       algorithm: str = "auto") -> jax.Array:
        fn = lambda v: self.reduce_inside(v, axis, algorithm)
        return self._wrap(fn, mesh, P(), P())(x)

    def reduce_scatter(self, x: jax.Array, mesh: Mesh, axis: str,
                       algorithm: str = "auto") -> jax.Array:
        """x replicated [N, ...] -> global [N, ...] summed over the axis,
        laid out sharded along it (device i holds chunk i)."""
        fn = lambda v: self.reduce_scatter_inside(v, axis, algorithm)
        return self._wrap(fn, mesh, P(), P(axis))(x)

    def allgather(self, x: jax.Array, mesh: Mesh, axis: str,
                  algorithm: str = "auto") -> jax.Array:
        """x sharded [N, ...] along the axis -> replicated [N, ...]."""
        fn = lambda v: self.allgather_inside(v, axis, algorithm)
        return self._wrap(fn, mesh, P(axis), P())(x)

    def broadcast(self, x: jax.Array, mesh: Mesh, axis: str, root: int = 0,
                  algorithm: str = "auto") -> jax.Array:
        fn = lambda v: self.broadcast_inside(v, axis, root, algorithm)
        return self._wrap(fn, mesh, P(), P())(x)

    def all_to_all(self, x: jax.Array, mesh: Mesh, axis: str,
                   algorithm: str = "auto") -> jax.Array:
        """x sharded [N, ...] along the axis (N a multiple of P*P): each
        device's local [N/P, ...] block is exchanged chunk-for-chunk --
        the distributed transpose."""
        fn = lambda v: self.all_to_all_inside(v, axis, algorithm)
        return self._wrap(fn, mesh, P(axis), P(axis))(x)


__all__ = ["CollectiveEngine", "Decision", "fit_fabric",
           "measure_ppermute", "load_topology", "find_calibrated_topology",
           "topology_to_dict",
           "topology_from_dict", "fabric_to_dict", "SCHEMA_VERSION",
           "ICI_ELEMENT_BYTES"]
