"""Public collective API: model-driven algorithm selection on TPU meshes.

    allreduce(x, mesh, axis, algorithm="auto")
    reduce_scatter(x, mesh, axis, algorithm="auto")
    allgather(x, mesh, axis, algorithm="auto")
    broadcast(x, mesh, axis, root=0, algorithm="auto")
    all_to_all(x, mesh, axis, algorithm="auto")

``algorithm``:
  psum        -- XLA-native (baseline; what GSPMD would emit)
  chain / tree / two_phase / star -- the paper's fixed patterns (Sec. 5)
                 composed with a doubling broadcast (Sec. 6.1)
  ring        -- reduce-scatter + all-gather (Sec. 6.2)
  autogen     -- the Auto-Gen DP tree executed as rounds of disjoint
                 ppermutes (Sec. 5.5, retargeted to ICI)
  auto        -- the model (Eq. 1, TPU-parameterized) picks among the
                 above given (bytes, axis size): the paper's selector.

All dispatch, caching, and calibration lives in the CollectiveEngine
(engine.py); this module keeps the stable functional surface and hands
out a process-wide default engine per Fabric so every call site -- the
gradient-sync path, the serve path, benchmarks -- shares one decision
cache.
"""

from __future__ import annotations

import threading
from typing import Dict, Optional, Union

import jax
from jax.sharding import Mesh

from repro.core.model import TPU_V5E_AXIS, Fabric, FabricTopology
from repro.collectives.engine import (CollectiveEngine,
                                      find_calibrated_topology)

_FabricKey = Union[Fabric, FabricTopology]
_ENGINES: Dict[_FabricKey, CollectiveEngine] = {}
_ENGINES_LOCK = threading.Lock()


def get_engine(fabric: _FabricKey = TPU_V5E_AXIS) -> CollectiveEngine:
    """Process-wide engine for a fabric or fabric topology (shared
    decision cache).

    The first time the *stock* default fabric is requested, the cache
    directory is checked for a fleet-calibrated v3 ``FabricTopology``
    (``load_topology`` on the persisted decision files): when one is
    found, the default engine is built on those per-axis constants
    instead, so a calibration run in one process prices every later
    process without each caller re-installing it.  Opt out with
    ``REPRO_RESTORE_TOPOLOGY=0`` (or pre-empt it via ``set_engine``)."""
    with _ENGINES_LOCK:
        eng = _ENGINES.get(fabric)
        if eng is None:
            build = fabric
            if isinstance(fabric, Fabric):
                restored = find_calibrated_topology(base=fabric)
                if restored is not None:
                    build = restored
            eng = CollectiveEngine(fabric=build)
            _ENGINES[fabric] = eng
        return eng


def set_engine(engine: CollectiveEngine,
               fabric: Optional[_FabricKey] = None) -> None:
    """Install ``engine`` as the default for its (or ``fabric``'s) key.

    An engine built on a heterogeneous :class:`FabricTopology` keys by
    its *default* fabric, so installing one reroutes every call site
    that asks for the plain default (the train/serve paths) through the
    per-axis constants."""
    with _ENGINES_LOCK:
        _ENGINES[fabric or engine.fabric] = engine


def select_algorithm(nbytes: int, p: int,
                     fabric: Fabric = TPU_V5E_AXIS) -> str:
    """The paper's model-driven AllReduce selection with ICI constants.

    On ICI the missing multicast penalizes reduce-then-broadcast at
    large B, so ring wins the bandwidth-bound region while
    tree/two-phase win the latency-bound region (DESIGN.md: hardware
    adaptation).  Cached per (P, bytes) by the engine."""
    return get_engine(fabric).select("allreduce", nbytes, p).algorithm


def allreduce_inside(x: jax.Array, axis: str, algorithm: str = "auto",
                     fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """AllReduce usable *inside* an existing shard_map."""
    return get_engine(fabric).allreduce_inside(x, axis, algorithm)


def allreduce_multi_inside(x: jax.Array, axes, algorithm: str = "auto",
                           fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """Joint multi-axis AllReduce (planner-driven) inside shard_map.

    ``algorithm`` is ``"auto"`` or a plan shape: ``sequential`` /
    ``hierarchical`` / ``2d_xy`` / ``2d_snake`` / ``flat`` (or a 1D
    backend name, forcing the sequential shape with that backend)."""
    return get_engine(fabric).allreduce_multi(x, axes, algorithm)


def reduce_scatter_multi_inside(x: jax.Array, axes,
                                algorithm: str = "auto",
                                fabric: Fabric = TPU_V5E_AXIS
                                ) -> jax.Array:
    """Multi-axis reduce-scatter (``lax.psum_scatter(x, axes,
    tiled=True)`` semantics) inside shard_map."""
    return get_engine(fabric).reduce_scatter_multi(x, axes, algorithm)


def allgather_multi_inside(x: jax.Array, axes, algorithm: str = "auto",
                           fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """Multi-axis allgather (``lax.all_gather(x, axes, tiled=True)``
    semantics) inside shard_map."""
    return get_engine(fabric).allgather_multi(x, axes, algorithm)


def all_to_all_inside(x: jax.Array, axis, algorithm: str = "auto",
                      fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """Personalized exchange (``lax.all_to_all(..., tiled=True)``
    semantics) along one axis inside shard_map."""
    return get_engine(fabric).all_to_all_inside(x, axis, algorithm)


def all_to_all_multi_inside(x: jax.Array, axes, algorithm: str = "auto",
                            fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """Joint multi-axis all_to_all (planner-driven) inside shard_map.

    ``algorithm`` is ``"auto"`` or a plan shape: ``hierarchical`` (the
    2-phase intra-pod/inter-pod decomposition) / ``sequential`` /
    ``flat`` -- or ``"lax"`` (XLA native over the folded axes) or a 1D
    backend name (``ring``/``halving``) forcing the innermost-first
    phase order with that backend."""
    return get_engine(fabric).all_to_all_multi(x, axes, algorithm)


def plan_collective(op: str, mesh: Mesh, axes, nbytes: int,
                    fabric: Fabric = TPU_V5E_AXIS):
    """The joint ``CollectivePlan`` the engine would execute for an op
    over a mesh axis tuple at a given byte size (introspection)."""
    axes = tuple(axes)
    sizes = tuple(mesh.shape[a] for a in axes)
    return get_engine(fabric).plan_multi(op, axes, sizes, nbytes)


def reduce_scatter_inside(x: jax.Array, axis: str, algorithm: str = "auto",
                          fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    return get_engine(fabric).reduce_scatter_inside(x, axis, algorithm)


def allgather_inside(x: jax.Array, axis: str, algorithm: str = "auto",
                     fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    return get_engine(fabric).allgather_inside(x, axis, algorithm)


def broadcast_inside(x: jax.Array, axis: str, root: int = 0,
                     algorithm: str = "auto",
                     fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    return get_engine(fabric).broadcast_inside(x, axis, root, algorithm)


def allreduce(x: jax.Array, mesh: Mesh, axis: str,
              algorithm: str = "auto",
              fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """AllReduce a replicated-along-`axis` array over one mesh axis."""
    return get_engine(fabric).allreduce(x, mesh, axis, algorithm)


def reduce_to_root(x: jax.Array, mesh: Mesh, axis: str,
                   algorithm: str = "chain",
                   fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """Paper Reduce: result valid on device 0 of the axis."""
    return get_engine(fabric).reduce_to_root(x, mesh, axis, algorithm)


def reduce_scatter(x: jax.Array, mesh: Mesh, axis: str,
                   algorithm: str = "auto",
                   fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """Sum over the axis, result sharded along it (device i: chunk i)."""
    return get_engine(fabric).reduce_scatter(x, mesh, axis, algorithm)


def allgather(x: jax.Array, mesh: Mesh, axis: str,
              algorithm: str = "auto",
              fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """Gather axis-sharded leading-dim chunks into a replicated array."""
    return get_engine(fabric).allgather(x, mesh, axis, algorithm)


def broadcast(x: jax.Array, mesh: Mesh, axis: str, root: int = 0,
              algorithm: str = "auto",
              fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """Replicate device `root`'s value across the axis."""
    return get_engine(fabric).broadcast(x, mesh, axis, root, algorithm)


def all_to_all(x: jax.Array, mesh: Mesh, axis: str,
               algorithm: str = "auto",
               fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """Distributed transpose: x sharded along the axis, each device's
    local block exchanged chunk-for-chunk with every peer."""
    return get_engine(fabric).all_to_all(x, mesh, axis, algorithm)


__all__ = ["get_engine", "set_engine", "select_algorithm",
           "allreduce", "allreduce_inside", "allreduce_multi_inside",
           "reduce_scatter", "reduce_scatter_inside",
           "reduce_scatter_multi_inside",
           "allgather", "allgather_inside", "allgather_multi_inside",
           "broadcast", "broadcast_inside", "reduce_to_root",
           "all_to_all", "all_to_all_inside", "all_to_all_multi_inside",
           "plan_collective"]
