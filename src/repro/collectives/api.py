"""Public collective API: model-driven algorithm selection on TPU meshes.

    allreduce(x, mesh, axis, algorithm="auto")

``algorithm``:
  psum        -- XLA-native (baseline; what GSPMD would emit)
  chain / tree / two_phase / star -- the paper's fixed patterns (Sec. 5)
                 composed with a doubling broadcast (Sec. 6.1)
  ring        -- reduce-scatter + all-gather (Sec. 6.2)
  autogen     -- the Auto-Gen DP run with ICI constants at trace time;
                 the resulting pre-order tree executes as rounds of
                 disjoint ppermutes (Sec. 5.5, retargeted)
  auto        -- the model (Eq. 1, TPU-parameterized) picks among the
                 above given (bytes, axis size): the paper's selector.

This is the paper's contribution as a first-class framework feature: the
gradient-synchronization strategy of the trainer is `auto` by default in
pure-DP mode (see overlap.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.autogen import autogen_tree, compute_tables, t_autogen
from repro.core.model import TPU_V5E_AXIS, Fabric
from repro.core import patterns as pat
from repro.collectives import shardmap_impl as impl

_ICI_ELEMENT_BYTES = 512  # one model "element" on the TPU fabric (flit)


def _elements(x: jax.Array) -> int:
    return max(1, (x.size * x.dtype.itemsize) // _ICI_ELEMENT_BYTES)


def select_algorithm(nbytes: int, p: int,
                     fabric: Fabric = TPU_V5E_AXIS) -> str:
    """The paper's model-driven selection with ICI constants.

    Evaluates every implemented AllReduce under Eq. (1); on ICI the
    missing multicast penalizes reduce-then-broadcast at large B, so ring
    wins the bandwidth-bound region while tree/two-phase win the
    latency-bound region (DESIGN.md: hardware adaptation)."""
    b = max(1, nbytes // _ICI_ELEMENT_BYTES)
    cands = {
        "tree": (pat.t_tree(p, b, fabric) + pat.t_broadcast(p, b, fabric)
                 if p & (p - 1) == 0 else float("inf")),
        "two_phase": pat.t_two_phase(p, b, fabric)
        + pat.t_broadcast(p, b, fabric),
        "chain": pat.t_chain(p, b, fabric) + pat.t_broadcast(p, b, fabric),
        "ring": pat.t_ring_allreduce(p, b, fabric),
    }
    return min(cands, key=cands.get)


def _reduce_impl(x, axis: str, algorithm: str, fabric: Fabric):
    p = jax.lax.axis_size(axis)
    if algorithm == "chain":
        return impl.chain_reduce(x, axis)
    if algorithm == "tree":
        return impl.tree_reduce(x, axis)
    if algorithm == "two_phase":
        return impl.two_phase_reduce(x, axis)
    if algorithm == "star":
        return impl.star_reduce(x, axis)
    if algorithm == "autogen":
        tree = autogen_tree(p, _elements(x), fabric=fabric)
        return impl.schedule_reduce(x, axis, tree.to_rounds())
    if algorithm == "autogen_pipelined":
        tree = autogen_tree(p, _elements(x), fabric=fabric)
        flat = x.reshape(-1)
        out = impl.schedule_reduce_pipelined(flat, axis, tree.to_rounds())
        return out.reshape(x.shape)
    raise ValueError(f"unknown reduce algorithm {algorithm!r}")


def allreduce_inside(x: jax.Array, axis: str, algorithm: str = "auto",
                     fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """AllReduce usable *inside* an existing shard_map."""
    if algorithm == "psum":
        return jax.lax.psum(x, axis)
    p = jax.lax.axis_size(axis)
    if algorithm == "auto":
        algorithm = select_algorithm(x.size * x.dtype.itemsize, p, fabric)
    if algorithm == "ring":
        flat = x.reshape(-1)
        return impl.ring_allreduce(flat, axis).reshape(x.shape)
    red = _reduce_impl(x, axis, algorithm, fabric)
    return impl.broadcast(red, axis, root=0)


def allreduce(x: jax.Array, mesh: Mesh, axis: str,
              algorithm: str = "auto",
              fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """AllReduce a replicated-along-`axis` array over one mesh axis."""
    other_axes = tuple(a for a in mesh.axis_names if a != axis)
    spec = P()  # x replicated over the target axis (pure-DP gradient case)
    fn = functools.partial(allreduce_inside, axis=axis,
                           algorithm=algorithm, fabric=fabric)
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_rep=False)(x)


def reduce_to_root(x: jax.Array, mesh: Mesh, axis: str,
                   algorithm: str = "chain",
                   fabric: Fabric = TPU_V5E_AXIS) -> jax.Array:
    """Paper Reduce: result valid on device 0 of the axis."""
    fn = functools.partial(_reduce_impl, axis=axis, algorithm=algorithm,
                           fabric=fabric)
    return shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)(x)


__all__ = ["allreduce", "allreduce_inside", "reduce_to_root",
           "select_algorithm"]
