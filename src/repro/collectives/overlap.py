"""Bucketed gradient AllReduce with compute/comm overlap + compression.

Distributed-optimization layer for pure-DP training (params replicated on
the data/pod axes):

* **Bucketing**: gradients are flattened and packed into fixed-byte
  buckets; each bucket is AllReduced independently, so the paper's
  selector picks the right algorithm *per bucket size* -- small buckets
  ride low-depth trees, big ones ride ring/chain (exactly the Fig. 8
  heatmap in action).
* **Overlap**: buckets are reduced in reverse-layer order, letting XLA's
  latency-hiding scheduler overlap each bucket's ppermute chain with the
  remaining backward compute (on TPU the collectives are async).
* **Compression**: optional bf16 reduction with fp32 error feedback
  (residual carried between steps), halving the collective term.
* **Topology-aware multi-axis plans**: on the multi-pod mesh each
  bucket flows through ``engine.allreduce_multi``, so the planner
  jointly scores the paper's 2D patterns (xy/snake over the folded
  grid), the hierarchical RS -> AR -> AG composition (cross-pod phase
  on 1/P of the bytes), the flat folded ring, and the legacy
  per-axis sequential loop -- and runs the winner.  On heterogeneous
  fabrics the winning plan is often a ``*_pipelined`` variant: the
  engine then splits the bucket into ``plan.n_chunks`` slices and
  wavefronts the phases so one chunk's slow inter-pod phase overlaps
  the next chunk's fast inner phase (chunk count chosen by the
  planner's closed form; tiny buckets fall back to serial phases).
"""

from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.collectives.api import get_engine
from repro.collectives.engine import CollectiveEngine

DEFAULT_BUCKET_BYTES = 32 * 1024 * 1024


def _flatten_to_buckets(tree, bucket_bytes: int):
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    n_per = max(1, bucket_bytes // 4)
    buckets = []
    i = 0
    while i < flat.size:
        buckets.append(flat[i:i + n_per])
        i += n_per
    return buckets, (treedef, sizes, [l.shape for l in leaves],
                     [l.dtype for l in leaves])


def _unflatten(buckets: List[jax.Array], meta) -> Any:
    treedef, sizes, shapes, dtypes = meta
    flat = jnp.concatenate(buckets)
    leaves = []
    off = 0
    for size, shape, dtype in zip(sizes, shapes, dtypes):
        leaves.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(treedef, leaves)


def flatten_tree(tree) -> Tuple[jax.Array, Any]:
    """Flatten a pytree to one fp32 vector + the meta ``unflatten_tree``
    needs to restore shapes/dtypes (the FSDP flat-shard layout)."""
    buckets, meta = _flatten_to_buckets(tree, bucket_bytes=1 << 62)
    return buckets[0], meta


def unflatten_tree(flat: jax.Array, meta) -> Any:
    return _unflatten([flat], meta)


def bucketed_allreduce(grads, mesh: Mesh, axes: Tuple[str, ...] = ("data",),
                       algorithm: str = "auto",
                       bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                       compress: bool = False,
                       error_feedback: Optional[Any] = None,
                       mean: bool = True,
                       engine: Optional[CollectiveEngine] = None):
    """AllReduce a gradient pytree over DP axes.

    Multi-axis (('pod','data')) buckets run the planner's joint
    topology plan (``engine.allreduce_multi``): hierarchical
    RS -> AR -> AG, the paper's 2D xy/snake patterns, the flat folded
    ring, or the sequential per-axis loop -- whichever Eq. (1) prices
    cheapest for the bucket size, per bucket.  Returns
    (reduced_grads, new_error_feedback).

    All collective traffic flows through the CollectiveEngine, so the
    per-bucket `auto` selection is cached across steps (one model sweep
    per bucket size, not one per trace).
    """
    if engine is None:
        engine = get_engine()
    if error_feedback is not None:
        grads = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                             grads, error_feedback)
    buckets, meta = _flatten_to_buckets(grads, bucket_bytes)

    def reduce_bucket(b):
        v = b
        if compress:
            v = v.astype(jnp.bfloat16)
        v = engine.allreduce_multi(v, axes, algorithm=algorithm)
        return v.astype(jnp.float32)

    spec = P()
    fn = shard_map(lambda *bs: tuple(reduce_bucket(b) for b in bs),
                   mesh=mesh, in_specs=spec, out_specs=spec,
                   check_rep=False)
    # reverse order: last layers' buckets first (they finish backward
    # earliest -> overlap with remaining backward compute)
    reduced = list(fn(*buckets[::-1]))[::-1]

    n = 1
    for ax in axes:
        n *= mesh.shape[ax]
    if mean:
        reduced = [b / n for b in reduced]

    new_ef = None
    if compress:
        # error feedback: residual between fp32 sum and bf16-compressed sum
        exact = [b * (n if mean else 1) for b in buckets]
        new_ef_flat = [e - r * (n if mean else 1)
                       for e, r in zip(exact, reduced)]
        new_ef = _unflatten(new_ef_flat, meta)
    out = _unflatten(reduced, meta)
    return out, new_ef


def bucket_algorithm_plan(grads, mesh: Mesh,
                          axes: Tuple[str, ...] = ("data",),
                          bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                          engine: Optional[CollectiveEngine] = None
                          ) -> List[Tuple[int, str]]:
    """What the planner would run per bucket (introspection/reporting).

    Takes the same axis tuple ``bucketed_allreduce`` executes with.  A
    single axis reports the 1D selector's algorithm; a multi-axis
    topology reports the joint plan shape, e.g.
    ``hierarchical(rs:ring->ar:ring->ag:doubling)``.
    """
    if engine is None:
        engine = get_engine()
    if isinstance(axes, str):       # tolerate the old single-axis form
        axes = (axes,)
    axes = tuple(axes)
    sizes = tuple(mesh.shape[a] for a in axes)
    leaves = jax.tree.leaves(grads)
    total = sum(l.size * 4 for l in leaves)
    plan = []
    off = 0
    while off < total:
        b = min(bucket_bytes, total - off)
        if len(axes) == 1:
            plan.append((b, engine.select("allreduce", b,
                                          sizes[0]).algorithm))
        else:
            plan.append((b, engine.plan_multi("allreduce", axes, sizes,
                                              b).describe()))
        off += b
    return plan


__all__ = ["bucketed_allreduce", "bucket_algorithm_plan",
           "flatten_tree", "unflatten_tree", "DEFAULT_BUCKET_BYTES"]
