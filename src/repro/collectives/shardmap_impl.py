"""The paper's Reduce/AllReduce algorithms as JAX shard_map programs.

Per-device SPMD ports of Sec. 5/6 over one mesh axis, built from
``jax.lax.ppermute`` steps (the TPU analogue of one wavelet hop -- see
DESIGN.md: multicast does not exist on ICI, so Broadcast becomes
log-depth doubling and the paper's pipelining maps to chunked schedules).

Every function runs *inside* shard_map (axis_name bound); the public
entry points live in api.py.  All algorithms compute the exact same sum
as ``jax.lax.psum`` (validated in tests/test_collectives_multidev.py).
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _axis_size(axis) -> int:
    # jax >= 0.4.32 removed lax.axis_size; psum of a Python scalar is
    # evaluated statically under shard_map and returns the axis size.
    # Accepts a tuple of axis names (folded logical axis, row-major).
    size = getattr(lax, "axis_size", None)
    if size is not None and not isinstance(axis, tuple):
        return size(axis)
    return lax.psum(1, axis)


def _axis_index(axis):
    """Linear index along an axis or a row-major-folded axis tuple."""
    if isinstance(axis, tuple):
        idx = lax.axis_index(axis[0])
        for a in axis[1:]:
            idx = idx * _axis_size(a) + lax.axis_index(a)
        return idx
    return lax.axis_index(axis)


def _masked_accumulate(x, received, is_receiver):
    return jnp.where(is_receiver, x + received, x)


# ---------------------------------------------------------------------- #
# fixed patterns (Sec. 5) -- reduce to device 0 of the axis
# ---------------------------------------------------------------------- #
def chain_reduce(x: jax.Array, axis: str) -> jax.Array:
    """Pipelined chain: device i receives i+1's partial, adds, passes on.
    P-1 ppermute steps; result lands on device 0 (others hold garbage
    partials, as on the WSE)."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    acc = x
    for t in range(p - 1):
        # device (p-1-t) has a complete suffix partial; send left
        src = p - 1 - t
        shifted = lax.ppermute(acc, axis, [(src, src - 1)])
        acc = jnp.where(idx == src - 1, acc + shifted, acc)
    return acc


def tree_reduce(x: jax.Array, axis: str) -> jax.Array:
    """Recursive halving (Sec. 5.3): log2 P rounds of pairwise sends."""
    p = _axis_size(axis)
    assert p & (p - 1) == 0, f"tree_reduce needs power-of-two axis, got {p}"
    idx = _axis_index(axis)
    acc = x
    step = 1
    while step < p:
        pairs = [(s + step, s) for s in range(0, p, 2 * step)]
        shifted = lax.ppermute(acc, axis, pairs)
        is_recv = (idx % (2 * step)) == 0
        acc = jnp.where(is_recv, acc + shifted, acc)
        step *= 2
    return acc


def two_phase_reduce(x: jax.Array, axis: str, group: int | None = None
                     ) -> jax.Array:
    """Two-Phase (Sec. 5.4): chain within groups of S, then chain over the
    group leaders.  The natural hierarchical reduce; with axis=('pod',...)
    flattened this is pod-local + cross-pod."""
    p = _axis_size(axis)
    if group is None:
        group = max(1, round(p ** 0.5))
    group = min(group, p)
    idx = _axis_index(axis)
    n_groups = -(-p // group)
    acc = x

    # phase 1: chain within each group towards its leader (g*group)
    for t in range(group - 1):
        pairs = []
        for g in range(n_groups):
            src = g * group + (group - 1 - t)
            if src < p and src > g * group:
                pairs.append((src, src - 1))
        if not pairs:
            continue
        shifted = lax.ppermute(acc, axis, pairs)
        dsts = jnp.array([d for _, d in pairs])
        is_recv = jnp.isin(idx, dsts)
        acc = jnp.where(is_recv, acc + shifted, acc)

    # phase 2: chain over leaders
    for t in range(n_groups - 1):
        src = (n_groups - 1 - t) * group
        dst = src - group
        shifted = lax.ppermute(acc, axis, [(src, dst)])
        acc = jnp.where(idx == dst, acc + shifted, acc)
    return acc


def star_reduce(x: jax.Array, axis: str) -> jax.Array:
    """Star (Sec. 5.1): everyone sends to the root.  On ICI this is an
    all-gather-to-one; modeled as P-1 serialized ppermutes (the root's
    injection bandwidth is the contention term)."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    acc = x
    for t in range(p - 1):
        shifted = lax.ppermute(x, axis, [(t + 1, 0)])
        acc = jnp.where(idx == 0, acc + shifted, acc)
    return acc


def broadcast(x: jax.Array, axis: str, root: int = 0) -> jax.Array:
    """Log-depth doubling broadcast (ICI has no multicast; DESIGN.md)."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    have = (idx == root)
    acc = jnp.where(have, x, jnp.zeros_like(x))
    step = 1
    while step < p:
        pairs = [((root + s) % p, (root + s + step) % p)
                 for s in range(step)]
        shifted = lax.ppermute(acc, axis, pairs)
        offset = (idx - root) % p
        is_new = (offset >= step) & (offset < 2 * step)
        acc = jnp.where(is_new, shifted, acc)
        step *= 2
    return acc


def chain_broadcast(x: jax.Array, axis: str, root: int = 0) -> jax.Array:
    """Linear-pipeline broadcast: root passes down the line, P-1 hops.
    Latency-heavy but minimal-energy; the model decides when it wins."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    acc = jnp.where(idx == root, x, jnp.zeros_like(x))
    for t in range(p - 1):
        src = (root + t) % p
        dst = (root + t + 1) % p
        shifted = lax.ppermute(acc, axis, [(src, dst)])
        acc = jnp.where(idx == dst, shifted, acc)
    return acc


# ---------------------------------------------------------------------- #
# ReduceScatter / AllGather (Sec. 6.2 halves, exposed as first-class ops)
# ---------------------------------------------------------------------- #
def reduce_scatter_ring(x: jax.Array, axis: str) -> jax.Array:
    """Ring reduce-scatter: device i ends with the full sum of chunk i
    (matches ``lax.psum_scatter(..., tiled=True)``).  Leading dim must be
    divisible by P."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    n = x.shape[0]
    assert n % p == 0, (n, p)
    chunks = x.reshape((p, n // p) + x.shape[1:])
    right = [(i, (i + 1) % p) for i in range(p)]

    def rs_step(t, ch):
        send_idx = (idx - 1 - t) % p
        sent = jnp.take(ch, send_idx, axis=0)
        recv = lax.ppermute(sent, axis, right)
        recv_idx = (idx - 2 - t) % p
        return ch.at[recv_idx].set(jnp.take(ch, recv_idx, axis=0) + recv)

    chunks = lax.fori_loop(0, p - 1, rs_step, chunks)
    return jnp.take(chunks, idx, axis=0)


def allgather_ring(x: jax.Array, axis: str) -> jax.Array:
    """Ring all-gather: out[i*m:(i+1)*m] holds device i's shard (matches
    ``lax.all_gather(..., tiled=True)``)."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    m = x.shape[0]
    chunks = jnp.zeros((p,) + x.shape, x.dtype).at[idx].set(x)
    right = [(i, (i + 1) % p) for i in range(p)]

    def ag_step(t, ch):
        send_idx = (idx - t) % p
        sent = jnp.take(ch, send_idx, axis=0)
        recv = lax.ppermute(sent, axis, right)
        recv_idx = (idx - t - 1) % p
        return ch.at[recv_idx].set(recv)

    chunks = lax.fori_loop(0, p - 1, ag_step, chunks)
    return chunks.reshape((p * m,) + x.shape[1:])


def allgather_doubling(x: jax.Array, axis: str) -> jax.Array:
    """Recursive-doubling all-gather: log2 P rounds, round k exchanging
    only the 2^k-shard block each device owns so far (wire total
    B*(P-1)/P per device, exactly what ``t_doubling_allgather``
    prices); latency-optimal for small shards.  P must be a power of
    two."""
    p = _axis_size(axis)
    assert p & (p - 1) == 0, f"doubling allgather needs power-of-two P, {p}"
    idx = _axis_index(axis)
    m = x.shape[0]
    zeros_tail = (0,) * (x.ndim - 1)
    out = jnp.zeros((p * m,) + x.shape[1:], x.dtype)
    out = lax.dynamic_update_slice(out, x, (idx * m,) + zeros_tail)
    step = 1
    while step < p:
        group = idx // step
        sent = lax.dynamic_slice(out, (group * step * m,) + zeros_tail,
                                 (step * m,) + x.shape[1:])
        pairs = [(i, i ^ step) for i in range(p)]
        recv = lax.ppermute(sent, axis, pairs)
        out = lax.dynamic_update_slice(
            out, recv, ((group ^ 1) * step * m,) + zeros_tail)
        step *= 2
    return out


# ---------------------------------------------------------------------- #
# AllToAll (personalized exchange).  Semantics match
# ``lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=True)``:
# x is [P*m, ...] with destination-major leading chunks; the output's
# chunk j is device j's chunk for *this* device (source-major).  The
# axis may be a tuple (row-major-folded logical axis), in which case
# chunk order is the folded device order.
# ---------------------------------------------------------------------- #
def all_to_all_ring(x: jax.Array, axis) -> jax.Array:
    """Pairwise-exchange (shift) all-to-all: P-1 rounds; round t ships
    the B/P chunk destined t ranks away as one shift-by-t ppermute.
    Injection-optimal (B*(P-1)/P wire per device)."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    n = x.shape[0]
    assert n % p == 0, (n, p)
    chunks = x.reshape((p, n // p) + x.shape[1:])
    out = chunks                     # slot idx: own chunk stays local
    for t in range(1, p):
        sent = jnp.take(chunks, (idx + t) % p, axis=0)
        recv = lax.ppermute(sent, axis,
                            [(i, (i + t) % p) for i in range(p)])
        out = out.at[(idx - t) % p].set(recv)
    return out.reshape(x.shape)


def all_to_all_bruck(x: jax.Array, axis) -> jax.Array:
    """Bruck recursive-halving all-to-all: ceil(log2 P) rounds, round k
    shipping every chunk whose (rotated) slot index has bit k set a
    2^k-rank shift.  A chunk starting in slot j travels exactly j ranks
    forward, so after the initial rotation (slot j <- chunk destined
    (idx + j) mod P) every chunk lands on its destination; the final
    gather restores source-major order."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    n = x.shape[0]
    assert n % p == 0, (n, p)
    chunks = x.reshape((p, n // p) + x.shape[1:])
    rot = jnp.take(chunks, (idx + jnp.arange(p)) % p, axis=0)
    k = 0
    while (1 << k) < p:
        shift = 1 << k
        slots = jnp.array([j for j in range(p) if (j >> k) & 1])
        sent = jnp.take(rot, slots, axis=0)
        recv = lax.ppermute(sent, axis,
                            [(i, (i + shift) % p) for i in range(p)])
        rot = rot.at[slots].set(recv)
        k += 1
    # slot j now holds the block from source (idx - j) mod P
    out = jnp.take(rot, (idx - jnp.arange(p)) % p, axis=0)
    return out.reshape(x.shape)


# ---------------------------------------------------------------------- #
# ring AllReduce (Sec. 6.2): reduce-scatter + all-gather
# ---------------------------------------------------------------------- #
def ring_allreduce(x: jax.Array, axis: str) -> jax.Array:
    """Classic bidirectional-mapping ring (paper Fig. 7), chunked so each
    round moves B/P elements."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    n = x.shape[0]
    pad = (-n) % p
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    chunks = xp.reshape((p, -1) + x.shape[1:])
    right = [(i, (i + 1) % p) for i in range(p)]

    # reduce-scatter: after P-1 rounds, device i owns the full sum of
    # chunk (i+1) % p
    def rs_step(t, ch):
        send_idx = (idx - t) % p
        sent = jnp.take(ch, send_idx, axis=0)
        recv = lax.ppermute(sent, axis, right)
        recv_idx = (idx - t - 1) % p
        upd = jnp.take(ch, recv_idx, axis=0) + recv
        return ch.at[recv_idx].set(upd)

    chunks = lax.fori_loop(0, p - 1, rs_step, chunks)

    # all-gather: circulate the owned chunks
    def ag_step(t, ch):
        send_idx = (idx + 1 - t) % p
        sent = jnp.take(ch, send_idx, axis=0)
        recv = lax.ppermute(sent, axis, right)
        recv_idx = (idx - t) % p
        return ch.at[recv_idx].set(recv)

    chunks = lax.fori_loop(0, p - 1, ag_step, chunks)
    out = chunks.reshape((-1,) + x.shape[1:])
    return out[:n] if pad else out


# ---------------------------------------------------------------------- #
# 2D collectives (Sec. 7) over a pair of named mesh axes: axes[0] is the
# outer (row-index, M rows) axis, axes[1] the inner (column-index, N
# columns) axis -- the folded m x n grid the paper's 2D lemmas price.
# ---------------------------------------------------------------------- #
REDUCE_FNS = {"chain": chain_reduce, "tree": tree_reduce,
              "two_phase": two_phase_reduce, "star": star_reduce}


def xy_reduce_2d(x: jax.Array, axes: Tuple[str, str],
                 patterns: Tuple[str, str] = ("chain", "chain")
                 ) -> jax.Array:
    """X-Y Reduce (Sec. 7.2): 1D reduce along every row (inner axis),
    then along column 0 (outer axis).  ``patterns`` names the 1D pattern
    per dimension, (outer, inner).  Result lands on device (0, 0)."""
    x = REDUCE_FNS[patterns[1]](x, axes[1])
    return REDUCE_FNS[patterns[0]](x, axes[0])


def snake_reduce_2d(x: jax.Array, axes: Tuple[str, str]) -> jax.Array:
    """Snake Reduce (Sec. 7.3): one pipelined chain over the
    boustrophedon order of the M x N grid, every hop unit-distance.
    Result lands on device (0, 0) (snake rank 0)."""
    ay, ax = axes
    m, n = _axis_size(ay), _axis_size(ax)
    iy, ix = _axis_index(ay), _axis_index(ax)

    def pos(rank: int) -> Tuple[int, int]:
        y, k = divmod(rank, n)
        return (y, k if y % 2 == 0 else n - 1 - k)

    acc = x
    for t in range(m * n - 1):
        (ys, xs), (yd, xd) = pos(m * n - 1 - t), pos(m * n - 2 - t)
        if ys == yd:
            shifted = lax.ppermute(acc, ax, [(xs, xd)])
        else:
            shifted = lax.ppermute(acc, ay, [(ys, yd)])
        recv = (ix == xd) & (iy == yd)
        acc = jnp.where(recv, acc + shifted, acc)
    return acc


def broadcast_2d(x: jax.Array, axes: Tuple[str, str],
                 root: Tuple[int, int] = (0, 0)) -> jax.Array:
    """2D broadcast from ``root``: doubling down the root's column
    (outer axis), then along every row (ICI has no multicast)."""
    x = broadcast(x, axes[0], root=root[0])
    return broadcast(x, axes[1], root=root[1])


def xy_allreduce_2d(x: jax.Array, axes: Tuple[str, str],
                    patterns: Tuple[str, str] = ("chain", "chain")
                    ) -> jax.Array:
    """2D AllReduce as X-Y Reduce + 2D broadcast (Sec. 7.4)."""
    return broadcast_2d(xy_reduce_2d(x, axes, patterns), axes)


def snake_allreduce_2d(x: jax.Array, axes: Tuple[str, str]) -> jax.Array:
    """2D AllReduce as Snake Reduce + 2D broadcast (Sec. 7.4)."""
    return broadcast_2d(snake_reduce_2d(x, axes), axes)


# ---------------------------------------------------------------------- #
# schedule-driven executor: runs any ReduceTree (Auto-Gen) as rounds of
# disjoint ppermutes (the paper's code generation, retargeted to ICI)
# ---------------------------------------------------------------------- #
def schedule_reduce(x: jax.Array, axis: str,
                    rounds: Sequence[Sequence[Tuple[int, int]]]) -> jax.Array:
    idx = _axis_index(axis)
    acc = x
    for sends in rounds:
        pairs = list(sends)
        shifted = lax.ppermute(acc, axis, pairs)
        dsts = jnp.array([d for _, d in pairs])
        is_recv = jnp.isin(idx, dsts)
        acc = jnp.where(is_recv, acc + shifted, acc)
    return acc


def schedule_reduce_pipelined(x: jax.Array, axis: str,
                              rounds: Sequence[Sequence[Tuple[int, int]]],
                              n_chunks: int = 4) -> jax.Array:
    """The paper's *pipelining* at tile granularity: the vector is split
    into chunks and the round schedule is software-pipelined -- round r
    operates on chunk c while round r+1 already moves chunk c-1, so a
    depth-D tree costs D + n_chunks - 1 ppermute waves of B/n_chunks
    bytes instead of D waves of B bytes.  On ICI the per-wave latency
    term is amortized exactly like the WSE's per-wavelet pipeline
    (DESIGN.md: wavelets -> chunked ppermute)."""
    idx = _axis_index(axis)
    n = x.shape[0]
    pad = (-n) % n_chunks
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    chunks = list(xp.reshape((n_chunks, -1) + x.shape[1:]))
    n_rounds = len(rounds)
    # wavefront schedule: at wave w, chunk c undergoes round w - c
    for wave in range(n_rounds + n_chunks - 1):
        for c in range(n_chunks):
            r = wave - c
            if 0 <= r < n_rounds:
                pairs = list(rounds[r])
                shifted = lax.ppermute(chunks[c], axis, pairs)
                dsts = jnp.array([d for _, d in pairs])
                is_recv = jnp.isin(idx, dsts)
                chunks[c] = jnp.where(is_recv, chunks[c] + shifted,
                                      chunks[c])
    out = jnp.stack(chunks).reshape((-1,) + x.shape[1:])
    return out[:n] if pad else out


def schedule_broadcast(x: jax.Array, axis: str,
                       rounds: Sequence[Sequence[Tuple[int, int]]]
                       ) -> jax.Array:
    """Run a ReduceTree schedule *in reverse* as a broadcast from the
    tree root: in a reduce, every (child -> parent) send happens after
    the child has heard from its own children, so the reversed round
    list visits each edge parent-before-child -- a valid multicast
    order."""
    idx = _axis_index(axis)
    acc = x
    for sends in reversed(list(rounds)):
        pairs = [(d, s) for s, d in sends]
        shifted = lax.ppermute(acc, axis, pairs)
        dsts = jnp.array([d for _, d in pairs])
        is_recv = jnp.isin(idx, dsts)
        acc = jnp.where(is_recv, shifted, acc)
    return acc


def _rotate_rounds(rounds: Sequence[Sequence[Tuple[int, int]]], p: int,
                   shift: int) -> List[List[Tuple[int, int]]]:
    return [[((s + shift) % p, (d + shift) % p) for s, d in sends]
            for sends in rounds]


def schedule_reduce_scatter(x: jax.Array, axis: str,
                            rounds: Sequence[Sequence[Tuple[int, int]]]
                            ) -> jax.Array:
    """Auto-Gen reduce-scatter: chunk c runs the root-0 reduce schedule
    rotated by c, so its sum lands on device c; every device keeps its
    own chunk.  Semantics match ``lax.psum_scatter(..., tiled=True)``."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    n = x.shape[0]
    assert n % p == 0, (n, p)
    chunks = x.reshape((p, n // p) + x.shape[1:])
    out = []
    for c in range(p):
        out.append(schedule_reduce(chunks[c], axis,
                                   _rotate_rounds(rounds, p, c)))
    return jnp.take(jnp.stack(out), idx, axis=0)


def schedule_allgather(x: jax.Array, axis: str,
                       rounds: Sequence[Sequence[Tuple[int, int]]]
                       ) -> jax.Array:
    """Auto-Gen all-gather: chunk c is broadcast from device c along the
    reversed reduce schedule rotated by c."""
    p = _axis_size(axis)
    idx = _axis_index(axis)
    m = x.shape[0]
    gathered = []
    for c in range(p):
        seeded = jnp.where(idx == c, x, jnp.zeros_like(x))
        gathered.append(schedule_broadcast(seeded, axis,
                                           _rotate_rounds(rounds, p, c)))
    return jnp.concatenate(gathered, axis=0).reshape((p * m,) + x.shape[1:])


__all__ = [
    "chain_reduce", "tree_reduce", "two_phase_reduce", "star_reduce",
    "broadcast", "chain_broadcast", "ring_allreduce",
    "reduce_scatter_ring", "allgather_ring", "allgather_doubling",
    "all_to_all_ring", "all_to_all_bruck",
    "xy_reduce_2d", "snake_reduce_2d", "broadcast_2d", "xy_allreduce_2d",
    "snake_allreduce_2d",
    "schedule_reduce", "schedule_reduce_pipelined", "schedule_broadcast",
    "schedule_reduce_scatter", "schedule_allgather",
]
