"""Deterministic synthetic LM data pipeline.

Produces sharded token batches keyed by (seed, step) with a counter-based
RNG, so every host can materialize exactly its own shard without
coordination -- the property a 1000-node deployment needs (no shared
filesystem reads on the hot path, restart-stable ordering).

The "documents" are Zipf-ish token streams with a simple Markov flavour
so cross-entropy actually decreases during the example runs (a uniform
stream has nothing to learn).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1


class SyntheticLMDataset:
    """Stateless map-style dataset: batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig, num_shards: int = 1,
                 shard_index: int = 0):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.num_shards = num_shards
        self.shard_index = shard_index
        self.local_batch = cfg.global_batch // num_shards
        # Zipf-ish unigram table (fixed by seed)
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_alpha)
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(cfg.vocab_size)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Tokens + next-token labels for this shard at `step`."""
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed, step, self.shard_index, 0xD47A))
        shape = (self.local_batch, c.seq_len + 1)
        draws = rng.choice(c.vocab_size, size=shape, p=self._probs)
        toks = self._perm[draws]
        # Markov flavour: even positions copy their predecessor w.p. 1/2
        copy = rng.random(shape) < 0.5
        copy[:, 0] = False
        toks = np.where(copy, np.roll(toks, 1, axis=1), toks)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_specs(cfg: ArchConfig, seq_len: int, global_batch: int,
                     with_labels: bool = True) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for a training batch (dry-run input stand-ins)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if with_labels:
        specs["labels"] = jax.ShapeDtypeStruct((global_batch, seq_len),
                                               jnp.int32)
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len, cfg.d_model), cfg.activation_dtype)
    if cfg.frontend == "vision":
        specs["soft_emb"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.frontend_tokens, cfg.d_model),
            cfg.activation_dtype)
    return specs


__all__ = ["DataConfig", "SyntheticLMDataset", "make_batch_specs"]
