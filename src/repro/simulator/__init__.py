"""WSE fabric simulators: our deterministic stand-in for the CS-2.

Two levels of fidelity:

* ``flow``   -- stream-level event simulation over the Schedule IR;
               exact for the serialized-receive / pipelined-last-child
               execution semantics; scales to the full 512x512 grid.
* ``fabric`` -- wavelet-level cycle simulation of routers, ramps, colors,
               multicast and backpressure on small grids; used to validate
               the flow simulator's assumptions (and the sums themselves).

The paper notes (Sec. 1.4) that CS-2 PE programs are deterministic state
machines that a cycle-accurate fabric simulator models faithfully; these
modules play that role here.
"""

from repro.simulator.flow import (simulate_allreduce, simulate_broadcast,
                                  simulate_reduce_tree, simulate_ring_allreduce)
from repro.simulator import fabric, runner

__all__ = [
    "simulate_reduce_tree", "simulate_broadcast", "simulate_allreduce",
    "simulate_ring_allreduce", "fabric", "runner",
]
