"""Run model predictions against the simulators and report errors.

This is the reproduction analogue of the paper's CS-2 measurements: the
flow simulator plays the role of the machine (deterministic, Sec. 8.1),
and we report ``|model - sim| / sim`` relative errors per pattern, as the
paper does per figure.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import patterns as pat
from repro.core.autogen import AutoGenTables, autogen_tree, t_autogen
from repro.core.model import Fabric, WSE2
from repro.core.schedule import (ReduceTree, binary_tree, chain_tree,
                                 snake_tree, star_tree, two_phase_tree)
from repro.simulator import flow


@dataclasses.dataclass
class Comparison:
    pattern: str
    p: int
    b: int
    model_cycles: float
    sim_cycles: float

    @property
    def rel_error(self) -> float:
        if self.sim_cycles == 0:
            return 0.0
        return abs(self.model_cycles - self.sim_cycles) / self.sim_cycles


def _tree_for(pattern: str, p: int, b: int,
              tables: Optional[AutoGenTables] = None) -> ReduceTree:
    if pattern == "star":
        return star_tree(p)
    if pattern == "chain":
        return chain_tree(p)
    if pattern == "tree":
        return binary_tree(p)
    if pattern == "two_phase":
        return two_phase_tree(p)
    if pattern == "autogen":
        return autogen_tree(p, b, tables=tables)
    raise KeyError(pattern)


def _model_reduce(pattern: str, p: int, b: int, fabric: Fabric,
                  tables: Optional[AutoGenTables]) -> float:
    if pattern == "autogen":
        t, _ = t_autogen(p, b, fabric, tables)
        return t
    return pat.REDUCE_PATTERNS[pattern](p, b, fabric)


def compare_reduce(pattern: str, p: int, b: int, fabric: Fabric = WSE2,
                   tables: Optional[AutoGenTables] = None) -> Comparison:
    tree = _tree_for(pattern, p, b, tables)
    sim = flow.simulate_reduce_tree(tree, b, fabric)
    model = _model_reduce(pattern, p, b, fabric, tables)
    return Comparison(pattern, p, b, model, sim.cycles)


def compare_allreduce(pattern: str, p: int, b: int, fabric: Fabric = WSE2,
                      tables: Optional[AutoGenTables] = None) -> Comparison:
    if pattern == "ring":
        sim = flow.simulate_ring_allreduce(p, b, fabric)
        model = pat.t_ring_allreduce(p, b, fabric)
    else:
        tree = _tree_for(pattern, p, b, tables)
        sim = flow.simulate_allreduce(tree, b, fabric)
        model = pat.t_reduce_then_broadcast(
            _model_reduce(pattern, p, b, fabric, tables), p, b, fabric)
    return Comparison(pattern, p, b, model, sim.cycles)


def compare_reduce_2d(pattern: str, m: int, n: int, b: int,
                      fabric: Fabric = WSE2,
                      tables: Optional[AutoGenTables] = None) -> Comparison:
    """X-Y patterns and the snake on an M x N grid."""
    if pattern == "snake":
        tree = snake_tree(m, n)
        sim = flow.simulate_reduce_tree(tree, b, fabric)
        model = pat.t_snake_reduce(m, n, b, fabric)
    else:
        row = _tree_for(pattern, n, b, tables)
        col = _tree_for(pattern, m, b, tables)
        sim = flow.simulate_xy_reduce(row, col, b, fabric)
        if pattern == "autogen":
            model = (_model_reduce(pattern, n, b, fabric, tables)
                     + _model_reduce(pattern, m, b, fabric, tables))
        else:
            model = pat.t_xy_reduce(pattern, m, n, b, fabric)
    return Comparison(f"xy_{pattern}" if pattern != "snake" else "snake",
                      m * n, b, model, sim.cycles)


def compare_allreduce_2d(pattern: str, m: int, n: int, b: int,
                         fabric: Fabric = WSE2,
                         tables: Optional[AutoGenTables] = None) -> Comparison:
    red = compare_reduce_2d(pattern, m, n, b, fabric, tables)
    bc_sim = flow.simulate_broadcast_2d(m, n, b, fabric)
    bc_model = pat.t_broadcast_2d(m, n, b, fabric)
    return Comparison(red.pattern + "+bcast2d", m * n, b,
                      red.model_cycles + bc_model,
                      red.sim_cycles + bc_sim.cycles)


def compare_broadcast(p: int, b: int, fabric: Fabric = WSE2) -> Comparison:
    sim = flow.simulate_broadcast(p, b, fabric)
    return Comparison("bcast", p, b, pat.t_broadcast(p, b, fabric),
                      sim.cycles)


__all__ = ["Comparison", "compare_reduce", "compare_allreduce",
           "compare_reduce_2d", "compare_allreduce_2d", "compare_broadcast"]
