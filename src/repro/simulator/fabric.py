"""Wavelet-level cycle simulator of a 1D row of WSE routers + PEs.

Models, from first principles (paper Sec. 2.2):

* 1 wavelet per link per cycle (links are shared *bandwidth*);
* per-color router queues (virtual channels): a stalled stream does not
  block other colors -- each communication edge of a schedule gets its own
  color, mirroring the paper's multi-color implementations;
* ramp latency T_R between router and PE in each direction;
* the PE performs one add pipeline step per cycle; a small output queue
  (send-DSD queue) of capacity 2 exerts backpressure on the add pipeline;
* routers serialize receives: PE v accepts child j's stream only after
  child j-1's stream has fully drained (routing-configuration switches,
  Fig. 3); early wavelets stall in their color queue;
* internal vertices pipeline their last child: element m of the outgoing
  stream is emitted right after element m was added (Fig. 5).

Used to validate the flow-level simulator and the performance model on
small instances -- and it checks numerical correctness: the root's
accumulator must equal the exact sum of all PE vectors.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.model import Fabric, WSE2
from repro.core.schedule import ReduceTree

_QUEUE_CAP = 2     # per-color router queue entries
_OUT_CAP = 2       # PE send-DSD queue entries


@dataclasses.dataclass
class Wavelet:
    edge: int          # edge id == child vertex id (doubles as its color)
    seq: int           # element index within the stream
    value: float
    moved_at: int = -1


@dataclasses.dataclass
class FabricResult:
    cycles: int
    root_sum: np.ndarray


class _PE:
    def __init__(self, vid: int, tree: ReduceTree, b: int, data: np.ndarray):
        self.vid = vid
        self.b = b
        self.acc = data.astype(np.float64).copy()
        self.children = tree.children[vid]
        self.recv_counts = {c: 0 for c in self.children}
        self.active_child = 0
        self.emitted = 0
        self.out_queue: Deque[Wavelet] = deque()
        self.parent = tree.parent[vid]
        self.pipelined_ready = b if not self.children else 0

    def current_child(self) -> Optional[int]:
        if self.active_child < len(self.children):
            return self.children[self.active_child]
        return None

    def accepts(self, edge: int) -> bool:
        return self.current_child() == edge

    def can_absorb(self) -> bool:
        if self.parent < 0:
            return True
        if (self.active_child == len(self.children) - 1
                and len(self.out_queue) >= _OUT_CAP):
            return False  # emit stall would stall the add pipeline
        return True

    def absorb(self, w: Wavelet) -> None:
        self.acc[w.seq] += w.value
        self.recv_counts[w.edge] += 1
        if (self.active_child == len(self.children) - 1
                and self.parent >= 0):
            self.pipelined_ready = self.recv_counts[w.edge]
        if self.recv_counts[w.edge] == self.b:
            self.active_child += 1

    def try_emit(self) -> None:
        if self.parent < 0 or self.emitted >= self.b:
            return
        if len(self.out_queue) >= _OUT_CAP:
            return
        if self.emitted < self.pipelined_ready:
            self.out_queue.append(
                Wavelet(self.vid, self.emitted,
                        float(self.acc[self.emitted])))
            self.emitted += 1


def simulate_reduce_fabric(tree: ReduceTree, b: int,
                           data: Optional[np.ndarray] = None,
                           fabric: Fabric = WSE2,
                           max_cycles: int = 10_000_000) -> FabricResult:
    """Cycle-level simulation of a 1D reduce tree (ids on a row; all edges
    towards lower ids / westward)."""
    p = tree.num_pes
    # keep t_r exact: calibrated fabrics carry non-integer ramp
    # latencies, and truncating silently mis-simulated them -- a ramp
    # exit is simply not ready until the first integer cycle >= t_r out
    t_r = float(fabric.t_r)
    if data is None:
        data = np.random.default_rng(0).standard_normal((p, b))
    expected = data.sum(axis=0)
    if p == 1:
        return FabricResult(0, data[0].astype(np.float64))

    for c, par in tree.edges():
        if par >= c:
            raise ValueError("fabric sim expects edges towards lower ids")

    pes = [_PE(v, tree, b, data[v]) for v in range(p)]
    dest = {c: par for c, par in tree.edges()}

    # rq[i][e]: router i's queue for color/edge e
    rq: List[Dict[int, Deque[Wavelet]]] = [dict() for _ in range(p)]
    ramp_down: List[Deque[Tuple[int, Wavelet]]] = [deque() for _ in range(p)]
    ramp_up: List[Deque[Tuple[int, Wavelet]]] = [deque() for _ in range(p)]
    rr: List[int] = [0] * p  # round-robin arbitration state per link

    def q(i: int, e: int) -> Deque[Wavelet]:
        if e not in rq[i]:
            rq[i][e] = deque()
        return rq[i][e]

    for cycle in range(1, max_cycles):
        # A. down-ramp delivery -> PE absorb (one add per cycle)
        for v in range(p):
            pe = pes[v]
            if ramp_down[v]:
                ready, w = ramp_down[v][0]
                if ready <= cycle and pe.can_absorb():
                    ramp_down[v].popleft()
                    pe.absorb(w)
            pe.try_emit()

        # B. PE out-queue -> up-ramp (one entry per cycle)
        for v in range(p):
            pe = pes[v]
            if pe.out_queue:
                w = pe.out_queue.popleft()
                ramp_up[v].append((cycle + t_r, w))

        # C. up-ramp exit -> own router's color queue
        for v in range(p):
            if ramp_up[v]:
                ready, w = ramp_up[v][0]
                if ready <= cycle and len(q(v, w.edge)) < _QUEUE_CAP:
                    ramp_up[v].popleft()
                    w.moved_at = cycle
                    q(v, w.edge).append(w)

        # D. westward link i -> i-1: one wavelet per link per cycle,
        #    round-robin over colors with head-of-line routability.
        for i in range(1, p):
            colors = sorted(rq[i].keys())
            if not colors:
                continue
            n = len(colors)
            moved = False
            for k in range(n):
                e = colors[(rr[i] + k) % n]
                dq = rq[i][e]
                if not dq or dq[0].moved_at >= cycle:
                    continue
                if dest[e] == i:
                    continue  # waiting for this router's ramp, not the link
                w = dq[0]
                at = i - 1
                if dest[w.edge] == at:
                    # enters destination router's queue (stalls there if the
                    # PE is not accepting yet)
                    if len(q(at, e)) < _QUEUE_CAP:
                        dq.popleft()
                        w.moved_at = cycle
                        q(at, e).append(w)
                        moved = True
                else:
                    if len(q(at, e)) < _QUEUE_CAP:
                        dq.popleft()
                        w.moved_at = cycle
                        q(at, e).append(w)
                        moved = True
                if moved:
                    rr[i] = (colors.index(e) + 1) % n
                    break

        # E. destination router -> down-ramp: one wavelet per router/cycle,
        #    only for the stream the PE currently accepts.
        for v in range(p):
            pe = pes[v]
            cur = pe.current_child()
            if cur is None:
                continue
            dq = rq[v].get(cur)
            if dq and dq[0].moved_at < cycle and dest[cur] == v:
                w = dq.popleft()
                ramp_down[v].append((cycle + t_r, w))

        root = pes[tree.root]
        if root.active_child == len(root.children):
            got = root.acc
            if not np.allclose(got, expected, rtol=1e-9, atol=1e-9):
                raise AssertionError("fabric reduce produced a wrong sum")
            return FabricResult(cycle, got)

    raise RuntimeError("fabric simulation did not converge (deadlock?)")


def simulate_broadcast_fabric(p: int, b: int, fabric: Fabric = WSE2
                              ) -> FabricResult:
    """Flooding broadcast from PE 0 eastward with free router multicast:
    element m leaves PE 0 at cycle m; completion when the farthest PE
    stored the last element.  Deterministic closed pipeline.  Fractional
    (calibrated) ramp latencies round up to the completing cycle."""
    t_r = float(fabric.t_r)
    last = (b - 1) + t_r + (p - 1) + t_r + 1
    return FabricResult(int(math.ceil(last)), np.arange(b, dtype=np.float64))


__all__ = ["simulate_reduce_fabric", "simulate_broadcast_fabric",
           "FabricResult", "Wavelet"]
