"""Flow-level (stream) simulator for reduction schedules.

Execution semantics (paper Secs. 2.2, 5, Fig. 5/6):

* every message is a stream of B elements moving at 1 element/cycle;
* a stream from child c to parent v starts flowing once c has *started*
  producing its combined vector (pipelining), travels ``dist(c, v)`` hops
  (1 cycle/hop), descends the ramp (T_R), and is added at 1 element/cycle;
* a vertex receives its children strictly in order: child j's elements are
  only accepted after child j-1's stream has fully drained (the router's
  routing configuration serializes this; earlier wavelets stall);
* the *last* child's stream is pipelined through: the parent emits element
  m (after an add + up-ramp) as soon as element m is reduced.

These recurrences reproduce the closed forms up to O(1) cycles per hop;
the wavelet-level ``fabric`` simulator validates them from first
principles on small grids.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.model import Fabric, WSE2
from repro.core.schedule import ReduceTree


@dataclasses.dataclass
class SimResult:
    cycles: float
    label: str = ""


def simulate_reduce_tree(tree: ReduceTree, b: int,
                         fabric: Fabric = WSE2) -> SimResult:
    """Simulate one reduction described by an ordered tree.

    Returns the cycle at which the root has finished accumulating the
    global sum.
    """
    t_r = fabric.t_r
    p = tree.num_pes
    if p == 1:
        return SimResult(0.0, tree.label)

    emit_first: List[Optional[float]] = [None] * p

    # children-before-parents order
    order = tree._topo_leaves_first()

    def arrival_first(c: int, v: int) -> float:
        # first element of c's stream is ready to be added at v
        assert emit_first[c] is not None
        return emit_first[c] + tree.hop_distance(c, v) + t_r

    recv_done: List[float] = [0.0] * p
    for v in order:
        ch = tree.children[v]
        if not ch:
            emit_first[v] = t_r  # leaf: first element up the ramp
            continue
        done = 0.0
        for c in ch[:-1]:
            done = max(done, arrival_first(c, v)) + b
        last = ch[-1]
        first_ready = max(done, arrival_first(last, v))
        recv_done[v] = first_ready + b
        # pipelined emit towards v's parent: add(1) + up-ramp(T_R)
        emit_first[v] = first_ready + fabric.store_cost + t_r
    return SimResult(recv_done[tree.root], tree.label)


def simulate_broadcast(p: int, b: int, fabric: Fabric = WSE2,
                       distance: Optional[int] = None) -> SimResult:
    """Flooding broadcast: root streams B elements; multicast duplicates at
    every router for free; completion when the farthest PE stored the last
    element.  T = T_R + (B - 1) + dist + T_R + 1."""
    if p == 1:
        return SimResult(0.0, "bcast")
    if distance is None:
        distance = p - 1
    cycles = fabric.t_r + (b - 1) + distance + fabric.t_r + fabric.store_cost
    return SimResult(cycles, "bcast")


def simulate_broadcast_2d(m: int, n: int, b: int,
                          fabric: Fabric = WSE2) -> SimResult:
    return simulate_broadcast(m * n, b, fabric,
                              distance=(m - 1) + (n - 1))


def simulate_allreduce(tree: ReduceTree, b: int, fabric: Fabric = WSE2,
                       distance: Optional[int] = None) -> SimResult:
    """Reduce-then-Broadcast AllReduce over the same PE set."""
    red = simulate_reduce_tree(tree, b, fabric)
    if distance is None:
        # broadcast from the root back across the same extent
        if tree.positions is None:
            distance = tree.num_pes - 1
        else:
            distance = max(tree.hop_distance(tree.root, v)
                           for v in range(tree.num_pes))
    bc = simulate_broadcast(tree.num_pes, b, fabric, distance=distance)
    return SimResult(red.cycles + bc.cycles, f"{tree.label}+bcast")


def simulate_ring_allreduce(p: int, b: int, fabric: Fabric = WSE2) -> SimResult:
    """Round-based ring AllReduce (Sec. 6.2 mapping (a)).

    2(P-1) rounds; each round every PE sends a B/P chunk to its successor.
    The wrap-around edge travels P-1 hops; a round completes when the
    slowest edge drains (rounds are not pipelined against each other
    because round r+1's sends depend on round r's receives).
    """
    if p == 1:
        return SimResult(0.0, "ring")
    chunk = b / p
    per_round = chunk + (p - 1) + 2 * fabric.t_r + fabric.store_cost
    return SimResult(2 * (p - 1) * per_round, "ring")


def simulate_xy_reduce(tree_row: ReduceTree, tree_col: ReduceTree, b: int,
                       fabric: Fabric = WSE2) -> SimResult:
    """X-Y Reduce: all rows reduce in parallel, then column 0 reduces."""
    tx = simulate_reduce_tree(tree_row, b, fabric)
    ty = simulate_reduce_tree(tree_col, b, fabric)
    return SimResult(tx.cycles + ty.cycles,
                     f"xy({tree_row.label})")


def simulate_xy_allreduce(tree_row: ReduceTree, tree_col: ReduceTree, b: int,
                          m: int, n: int, fabric: Fabric = WSE2) -> SimResult:
    """2D AllReduce = X-Y Reduce + 2D flooding broadcast (Sec. 7.4)."""
    red = simulate_xy_reduce(tree_row, tree_col, b, fabric)
    bc = simulate_broadcast_2d(m, n, b, fabric)
    return SimResult(red.cycles + bc.cycles, f"{red.label}+bcast2d")


__all__ = [
    "SimResult", "simulate_reduce_tree", "simulate_broadcast",
    "simulate_broadcast_2d", "simulate_allreduce", "simulate_ring_allreduce",
    "simulate_xy_reduce", "simulate_xy_allreduce",
]
