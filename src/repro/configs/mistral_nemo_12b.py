"""Mistral-Nemo 12B: dense GQA, 128k context.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Nemo-Base-2407; hf",
)
