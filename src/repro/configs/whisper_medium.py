"""Whisper-medium backbone: encoder-decoder; conv audio frontend is a
stub (input_specs provides frame embeddings).  [arXiv:2212.04356;
unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, encoder_layers=24, d_model=1024, num_heads=16,
    num_kv_heads=16, d_ff=4096, vocab_size=51865,
    frontend="audio",
    source="arXiv:2212.04356; unverified",
)
