"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (ArchConfig, SHAPES, ShapeSpec,
                                cell_is_runnable)

_MODULES = {
    "arctic-480b": "arctic_480b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-medium": "whisper_medium",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "yi-34b": "yi_34b",
    "minicpm-2b": "minicpm_2b",
    "llava-next-34b": "llava_next_34b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {name: get_config(name) for name in list_archs()}


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "cell_is_runnable",
           "get_config", "all_configs", "list_archs"]
