"""Architecture + shape configuration system.

One ``ArchConfig`` per assigned architecture (exact values from the
assignment table), plus a ``reduced()`` transform used by the CPU smoke
tests.  ``ShapeSpec`` defines the four assigned input shapes; helpers
decide which (arch x shape) cells are runnable (long_500k only for
sub-quadratic decode families, per the assignment rules -- see DESIGN.md
Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dense_ff: int = 0         # Arctic-style parallel dense residual FFN
    capacity_factor: float = 1.25
    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    # --- hybrid (RecurrentGemma) ---
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru", "rglru", "local")
    local_window: int = 2048
    # --- encoder-decoder (Whisper backbone) ---
    encoder_layers: int = 0        # 0 -> decoder-only
    # --- modality frontend stub ---
    frontend: Optional[str] = None  # None | "audio" | "vision"
    frontend_tokens: int = 0        # soft tokens prepended (vision)
    # --- misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""
    # --- perf levers (hillclimb knobs; defaults = paper-faithful
    # baseline) ---
    attn_probs_bf16: bool = False   # PV matmul on bf16 probabilities
    logits_bf16: bool = False       # lm head output in bf16 (CE upcasts)
    moe_shardmap_ep: bool = True    # explicit shard_map EP dispatch
                                    # (False = GSPMD-resolved scatter/
                                    # gather; kept for §Perf baselines)
    moe_ep: bool = False            # true expert parallelism: tokens
                                    # sharded over the EP axes, dispatch/
                                    # combine as explicit all-to-all
                                    # (models/moe_ep.py)
    moe_ep_algorithm: str = "auto"  # exchange backend: "lax" (bare
                                    # single-shot) or an engine
                                    # algorithm/plan shape ("auto",
                                    # "hierarchical", "ring", ...)
    fused_tp: bool = False          # TP down-projection psum decomposed
                                    # as reduce-scatter + allgather with
                                    # the RS fused into the GEMM ring
                                    # (kernels/fused_matmul_rs.py);
                                    # launchers also flip the module
                                    # switch via layers.set_fused_tp
    remat_policy: str = "full"      # full | dots | dots_no_batch
    grad_barrier: bool = False      # optimization_barrier on block-input
                                    # cotangents (keeps TP grad
                                    # all-reduces in bf16)
    sp_residuals: bool = False      # sequence-parallel residual stream:
                                    # shard saved layer inputs over
                                    # 'model' (Megatron SP); cuts remat-
                                    # saved activation memory ~TP-fold

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        if self.num_heads == 0:
            return 0
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, math.ceil(self.d_model / 16))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context_decode(self) -> bool:
        """Constant-state decode: SSM and hybrid (RG-LRU + local window)."""
        return self.family in ("ssm", "hybrid")

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    # ------------------------------------------------------------------ #
    # analytic parameter counts (for MODEL_FLOPS in the roofline)
    # ------------------------------------------------------------------ #
    def param_count(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d
        head = d * self.vocab_size
        per_layer = 0
        if self.family == "ssm":
            di, n, r = self.d_inner, self.ssm_state, self.dt_rank
            per_layer = (d * 2 * di            # in_proj
                         + self.ssm_conv * di  # conv
                         + di * (r + 2 * n)    # x_proj
                         + r * di + di         # dt_proj
                         + di * n + di         # A_log, D
                         + di * d              # out_proj
                         + d)                  # norm
            return emb + head + self.num_layers * per_layer + d
        attn = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                + self.num_heads * hd * d)
        dense_mlp = 3 * d * self.d_ff
        norms = 2 * d
        if self.family == "moe":
            router = d * self.num_experts
            experts = self.num_experts * 3 * d * self.d_ff
            dense_res = 3 * d * self.moe_dense_ff if self.moe_dense_ff else 0
            per_layer = attn + router + experts + dense_res + norms
        elif self.family == "hybrid":
            total = 0
            lru = d
            gate_block = lru // max(self.num_heads, 1)
            for i in range(self.num_layers):
                kind = self.block_pattern[i % len(self.block_pattern)]
                mlp = 3 * d * self.d_ff
                if kind == "local":
                    total += attn + mlp + norms
                else:  # RG-LRU recurrent block (Griffin)
                    total += (2 * d * lru                 # two input branches
                              + self.ssm_conv * lru       # temporal conv
                              + 2 * lru * gate_block      # block-diag a/i gates
                              + lru                       # Lambda
                              + lru * d                   # out proj
                              + mlp + norms)
            return emb + head + total + d
        elif self.family == "encdec":
            # encoder self-attn + mlp; decoder self-attn + cross-attn + mlp
            enc = self.encoder_layers * (attn + dense_mlp + norms)
            dec = self.num_layers * (2 * attn + dense_mlp + 3 * d)
            return emb + head + enc + dec + 2 * d
        else:
            per_layer = attn + dense_mlp + norms
        return emb + head + self.num_layers * per_layer + d

    def active_param_count(self) -> int:
        """Parameters touched per token (= param_count for non-MoE)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        hd = self.resolved_head_dim
        attn = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                + self.num_heads * hd * d)
        router = d * self.num_experts
        experts_active = self.experts_per_token * 3 * d * self.d_ff
        dense_res = 3 * d * self.moe_dense_ff if self.moe_dense_ff else 0
        per_layer = attn + router + experts_active + dense_res + 2 * d
        return (self.vocab_size * d * 2 + self.num_layers * per_layer + d)

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        pattern = self.block_pattern or ()
        n_layers = len(pattern) if pattern else 2
        kv = min(self.num_kv_heads, 2) if self.num_kv_heads else 0
        heads = 4 if self.num_heads else 0
        if self.num_kv_heads == self.num_heads:
            kv = heads
        elif self.num_kv_heads == 1:
            kv = 1
        return dataclasses.replace(
            self,
            num_layers=n_layers,
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16 if heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_dense_ff=64 if self.moe_dense_ff else 0,
            ssm_state=min(self.ssm_state, 8),
            local_window=32,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_tokens=8 if self.frontend_tokens else 0,
        )


def layer_units(cfg: ArchConfig) -> int:
    """Number of homogeneous layer-units for cost extrapolation: pattern
    cycles for hybrids, enc+dec pairs for enc-dec, layers otherwise."""
    if cfg.family == "hybrid":
        cyc = len(cfg.block_pattern)
        return cfg.num_layers // cyc
    return cfg.num_layers


def with_layer_units(cfg: ArchConfig, units: int) -> ArchConfig:
    """Same architecture with ``units`` layer-units (keeps the hybrid
    tail remainder so the unit slope is exact)."""
    if cfg.family == "hybrid":
        cyc = len(cfg.block_pattern)
        rem = cfg.num_layers % cyc
        return dataclasses.replace(cfg, num_layers=units * cyc + rem)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, num_layers=units,
                                   encoder_layers=units)
    return dataclasses.replace(cfg, num_layers=units)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic decode archs."""
    if shape.name == "long_500k" and not cfg.supports_long_context_decode:
        return False, ("skipped: pure full-attention arch cannot serve 524k "
                       "context (quadratic attention); per assignment rule")
    return True, ""


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "cell_is_runnable",
           "layer_units", "with_layer_units"]
