"""MiniCPM-2B: llama-like dense (WSD schedule lives in repro.optim).
[arXiv:2404.06395; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753,
    source="arXiv:2404.06395; hf",
)
