"""LLaVA-NeXT-34B backbone: Yi-34B-shaped LM with anyres vision tiling
stubbed -- input_specs provides patch/soft-token embeddings.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="dense",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    frontend="vision", frontend_tokens=576,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
