"""Lower bounds for 1D Reduce (Lemma 5.5) and helpers.

The 1D lower bound is a DP over (split, depth) decompositions:

    E*(P, 1, D) >= min_i  E*(i, 1, D) + E*(P-i, 1, D-1) + min(i, P-i+1)

with E*(1, 1, D) = 0 and E*(P, 1, 0) = inf for P >= 2.  The runtime bound
(contention dropped -- it only weakens a lower bound) is

    T*(P, B) >= min_D  B * E*(P, 1, D) / (P-1) + (P-1) + D*(2*T_R+1)
"""

from __future__ import annotations

import os
from typing import Optional

import numpy as np

from repro.core.autogen import cache_dir
from repro.core.model import Fabric, WSE2

INF = np.float32(np.inf)


def compute_lb_energy(p_max: int, d_max: Optional[int] = None,
                      use_cache: bool = True) -> np.ndarray:
    """Return table ``e[d, P]`` = E*(P, 1, D=d) for d in 0..d_max.

    Note the self-reference E*(i, D) on the *same* depth level, which forces
    an in-order sweep over P per level.
    """
    if d_max is None:
        d_max = max(p_max - 1, 1)
    d_max = max(1, min(d_max, max(p_max - 1, 1)))

    cache_path = os.path.join(cache_dir(), f"lb_P{p_max}_D{d_max}.npy")
    if use_cache and os.path.exists(cache_path):
        return np.load(cache_path)

    e = np.full((d_max + 1, p_max + 1), INF, dtype=np.float32)
    e[:, 1] = 0.0
    # extra cost of the last message: min(i, P - i + 1) for split at i
    for d in range(1, d_max + 1):
        for p in range(2, p_max + 1):
            i = np.arange(1, p, dtype=np.int64)
            extra = np.minimum(i, p - i + 1).astype(np.float32)
            cand = e[d, 1:p] + e[d - 1, p - 1:0:-1] + extra
            e[d, p] = cand.min()
    if use_cache:
        os.makedirs(cache_dir(), exist_ok=True)
        tmp = cache_path + f".tmp{os.getpid()}.npy"
        np.save(tmp, e)
        os.replace(tmp, cache_path)
    return e


def t_lower_bound(p: int, b: int, fabric: Fabric = WSE2,
                  lb_table: Optional[np.ndarray] = None) -> float:
    """T*(P, B): minimum over depth of the three cost contributions."""
    if p == 1:
        return 0.0
    if lb_table is None or lb_table.shape[1] <= p:
        lb_table = compute_lb_energy(p)
    d_max = lb_table.shape[0] - 1
    ds = np.arange(1, d_max + 1, dtype=np.float64)
    e = lb_table[1:, p].astype(np.float64)
    t = (b * e / ((p - 1) * fabric.link_bw) + (p - 1)
         + ds * fabric.per_depth_cost)
    t = np.where(np.isfinite(e), t, np.inf)
    return float(t.min())


def t_all_to_all_lower_bound(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Theta(B*(P-1)/P) injection bound for AllToAll (personalized
    exchange, no reduction): every device must send -- and receive --
    B*(P-1)/P elements through its own ramp, in at least one launch:

        T*(P, B) >= B*(P-1)/P / link_bw + (2*T_R + 1)

    Topology effects (the ring-bisection ~B*P/4 per-link load of a
    single-shot folded exchange) only raise candidate costs above this;
    dropping them keeps it a bound on every implemented pattern."""
    if p <= 1:
        return 0.0
    return b * (p - 1) / p / fabric.link_bw + fabric.per_depth_cost


__all__ = ["compute_lb_energy", "t_lower_bound", "t_all_to_all_lower_bound"]
