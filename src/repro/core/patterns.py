"""Closed-form pattern costs from the paper (Lemmas 4.1, 5.1-5.4, 6.1, 7.1).

Every function returns the model estimate (Eq. 1) in cycles.  Functions are
deliberately kept in one-to-one correspondence with the paper's lemmas so
that the unit tests can assert our generic ``ReduceTree.cost_terms`` +
``CostTerms.cycles`` machinery reproduces each lemma exactly.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Optional

from repro.core.model import CostTerms, Fabric, WSE2, ceil_div, log2i
from repro.core import schedule as sched


# ---------------------------------------------------------------------- #
# 1D primitives
# ---------------------------------------------------------------------- #
def t_message(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Sending a B-vector across a row of P PEs: T = B + P + 2*T_R."""
    terms = CostTerms(depth=1, distance=p - 1, energy=b * (p - 1),
                      contention=b, links=max(p - 1, 1), label="message",
                      launches=1)
    return terms.cycles(fabric)


def t_broadcast(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Flooding broadcast == message (Lemma 4.1), thanks to multicast."""
    return t_message(p, b, fabric)


# ---------------------------------------------------------------------- #
# 1D Reduce patterns
# ---------------------------------------------------------------------- #
def t_star(p: int, b: int, fabric: Fabric = WSE2, refined: bool = True) -> float:
    """Star Reduce (Lemma 5.1).  ``refined`` uses the paper's closer look:
    the star forms a perfect pipeline at the root, so
    T = B*(P-1) + 2*T_R + 1 (no congestion term)."""
    if p == 1:
        return 0.0
    if refined:
        return (b * (p - 1) / fabric.link_bw + 2 * fabric.t_r
                + fabric.store_cost + fabric.t_launch * (p - 1))
    terms = CostTerms(depth=1, distance=p - 1,
                      energy=b * p * (p - 1) / 2.0,
                      contention=b * (p - 1), links=p - 1, label="star",
                      launches=p - 1)
    return terms.cycles(fabric)


def t_chain(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Chain Reduce (Lemma 5.2): T = B + (2*T_R + 2)(P - 1)."""
    if p == 1:
        return 0.0
    return (b / fabric.link_bw + fabric.hop_pipeline_cost * (p - 1)
            + fabric.t_launch * (p - 1))


def t_tree(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Binary-tree Reduce (Lemma 5.3), P a power of two."""
    if p == 1:
        return 0.0
    lg = log2i(p)
    bw = fabric.link_bw
    bandwidth = b * p / (2.0 * (p - 1)) * lg / bw + (p - 1)
    return (max(b * lg / bw, bandwidth) + fabric.per_depth_cost * lg
            + fabric.t_launch * lg)


def t_two_phase(p: int, b: int, fabric: Fabric = WSE2,
                s: Optional[int] = None) -> float:
    """Two-Phase Reduce (Lemma 5.4).  With S = sqrt(P) (P = S^2):
    T <= max(2B, 2B - 2B/sqrt(P) + P) + (2*sqrt(P) - 2)(2*T_R + 1).
    For general S we evaluate the cost terms directly (same derivation)."""
    if p == 1:
        return 0.0
    if s is None:
        s = max(1, round(math.sqrt(p)))
    s = min(s, p)
    g = ceil_div(p, s)
    depth = (s - 1) + (g - 1)
    energy = (s - 1) * b * g + s * b * (g - 1)
    contention = 2 * b if (g > 1 and s > 1) else b
    terms = CostTerms(depth=depth, distance=p - 1, energy=energy,
                      contention=contention, links=p,
                      label=f"two_phase(S={s})", launches=depth)
    return terms.cycles(fabric)


def t_autogen_tree(tree: "sched.ReduceTree", b: int,
                   fabric: Fabric = WSE2) -> float:
    """Model cost of an arbitrary ordered reduction tree (Sec. 5.5)."""
    return tree.cost_terms(b).cycles(fabric)


REDUCE_PATTERNS: Dict[str, Callable[[int, int, Fabric], float]] = {
    "star": t_star,
    "chain": t_chain,
    "tree": t_tree,
    "two_phase": t_two_phase,
}


# ---------------------------------------------------------------------- #
# 1D AllReduce patterns
# ---------------------------------------------------------------------- #
def t_reduce_then_broadcast(t_reduce: float, p: int, b: int,
                            fabric: Fabric = WSE2) -> float:
    """Naive AllReduce (Sec. 6.1): T = T_reduce + T_bcast.

    The broadcast term honors the fabric: flooding multicast on the WSE
    (Lemma 4.1), log-depth doubling where multicast is missing (ICI) --
    that is what the shard_map implementation actually executes."""
    if fabric.multicast:
        return t_reduce + t_broadcast(p, b, fabric)
    return t_reduce + t_doubling_broadcast(p, b, fabric)


def t_allreduce(pattern: str, p: int, b: int, fabric: Fabric = WSE2) -> float:
    if pattern == "ring":
        return t_ring_allreduce(p, b, fabric)
    if pattern == "oneshot":
        return t_oneshot_allreduce(p, b, fabric)
    return t_reduce_then_broadcast(
        REDUCE_PATTERNS[pattern](p, b, fabric), p, b, fabric)


def t_ring_allreduce(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Ring AllReduce mapped onto the mesh row (Lemma 6.1):
    T = 2(P-1)B/P + 4P - 6 + 2(P-1)(2*T_R + 1)."""
    if p == 1:
        return 0.0
    bw = fabric.link_bw
    contention = 2.0 * (p - 1) * b / p / bw
    # E/N with E = 2(P-1) rounds * links
    bandwidth = 2.0 * (p - 1) * b / p / bw
    distance = 2.0 * (2 * p - 3)
    depth = 2.0 * (p - 1)
    return (max(contention, bandwidth + distance)
            + fabric.per_depth_cost * depth
            + fabric.t_launch * depth)


ALLREDUCE_PATTERNS = ("star", "chain", "tree", "two_phase", "ring",
                      "oneshot")


# ---------------------------------------------------------------------- #
# ReduceScatter / AllGather / Broadcast variants (engine candidate sets).
# Costs model the shard_map implementations in collectives/shardmap_impl
# on a multicast-free fabric (ICI): broadcast is doubling or a serialized
# chain, ring halves are the two phases of Lemma 6.1.
# ---------------------------------------------------------------------- #
def t_ring_reduce_scatter(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """One ring half: P-1 rounds of B/P-element sends around the row."""
    if p == 1:
        return 0.0
    moved = (p - 1) * b / p / fabric.link_bw
    distance = float(2 * p - 3)
    return (moved + distance + fabric.per_depth_cost * (p - 1)
            + fabric.t_launch * (p - 1))


def t_ring_allgather(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Same wire traffic as the reduce-scatter half, minus nothing the
    model separates -- symmetric phase of Lemma 6.1."""
    return t_ring_reduce_scatter(p, b, fabric)


def t_doubling_allgather(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Recursive doubling: round k ships a 2^k*(B/P) block; log2 P
    launches."""
    if p == 1:
        return 0.0
    lg = math.ceil(math.log2(p))
    return (b * (p - 1) / p / fabric.link_bw + fabric.per_depth_cost * lg
            + fabric.t_launch * lg)


def t_doubling_broadcast(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Log-depth doubling of the full vector: each of the ceil(log2 P)
    rounds is a serialized B-element send (no multicast on ICI)."""
    if p == 1:
        return 0.0
    lg = math.ceil(math.log2(p))
    return (lg * b / fabric.link_bw + fabric.per_depth_cost * lg
            + fabric.t_launch * lg)


def t_chain_broadcast(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Unpipelined hop-by-hop relay: P-1 serialized B-element sends."""
    if p == 1:
        return 0.0
    return (p - 1) * (b / fabric.link_bw + fabric.per_depth_cost
                      + fabric.t_launch)


REDUCE_SCATTER_PATTERNS: Dict[str, Callable[[int, int, Fabric], float]] = {
    "ring": t_ring_reduce_scatter,
}


# ---------------------------------------------------------------------- #
# AllToAll (personalized exchange): every device holds B elements, B/P
# destined to each peer.  The op conserves bytes (no reduction), so the
# candidate frontier is injection-vs-launch count: the pairwise/ring
# exchange is injection-optimal (B*(P-1)/P per device) at P-1 launches,
# the Bruck recursive-halving ships ~B/2 per round but only needs
# ceil(log2 P) launches -- the small-B winner.  On the physical ring the
# shift-by-t round's messages travel min(t, P-t) hops, so per-link
# traffic sums to ~B*P/4: the ring-bisection term the planner's flat
# single-shot pays and the hierarchical 2-phase decomposition avoids.
# ---------------------------------------------------------------------- #
def _ring_hop_sum(p: int) -> int:
    """Total shortest-path hop distance of the P-1 shift rounds."""
    return sum(min(t, p - t) for t in range(1, p))


def t_ring_all_to_all(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Pairwise-exchange all-to-all on a ring: P-1 rounds; round t ships
    the B/P chunk destined t ranks away as one shift-by-t permutation."""
    if p <= 1:
        return 0.0
    bw = fabric.link_bw
    chunk = b / p
    contention = b * (p - 1) / p / bw          # injection per device
    bandwidth = chunk * _ring_hop_sum(p) / bw  # per-link element load
    distance = float(p - 1)                    # pipeline fill across rounds
    return (max(contention, bandwidth + distance)
            + fabric.per_depth_cost * (p - 1)
            + fabric.t_launch * (p - 1))


def t_halving_all_to_all(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Bruck recursive halving: round k ships every chunk whose slot has
    bit k set (~B/2 elements) a 2^k-rank shift; ceil(log2 P) launches
    total, trading ~log2(P)/2 x injected bytes for log-depth latency."""
    if p <= 1:
        return 0.0
    bw = fabric.link_bw
    chunk = b / p
    sent = 0.0        # elements injected per device, all rounds
    link_load = 0.0   # per-link element load (energy / P links)
    distance = 0.0
    rounds = 0
    shift = 1
    while shift < p:
        n_slots = sum(1 for j in range(p) if (j >> rounds) & 1)
        hop = min(shift, p - shift)
        sent += chunk * n_slots
        link_load += chunk * n_slots * hop
        distance += hop
        rounds += 1
        shift <<= 1
    return (max(sent / bw, link_load / bw + distance)
            + fabric.per_depth_cost * rounds
            + fabric.t_launch * rounds)


# ---------------------------------------------------------------------- #
# One-shot latency algorithms: the whole collective as a single program
# launch (lax.psum / lax.all_gather / lax.all_to_all over the -- possibly
# folded -- axis).  The wire story is a direct exchange with no
# store-and-forward reuse: the AllReduce blasts each device's full vector
# to every peer (K-way combine at the receiver), the AllGather/AllToAll
# unicast each chunk straight to its consumer, so per-link load carries
# the full shortest-path hop sum.  More bytes than the multi-round
# patterns at large B -- but depth 1 and one launch, which is the whole
# point below the crossover the selector computes from these forms.
# The distance term is P (>= M+N-1 for every 2D folding of P), keeping
# each form above the Lemma 7.2 / injection lower bounds the planner
# validates candidates against.
# ---------------------------------------------------------------------- #
def t_oneshot_allreduce(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Single-launch all-broadcast + local K-way reduce: every device
    absorbs (P-1)*B elements, one launch, depth 1."""
    if p == 1:
        return 0.0
    bw = fabric.link_bw
    contention = b * (p - 1) / bw
    bandwidth = b * _ring_hop_sum(p) / p / bw
    distance = float(p)
    return (max(contention, bandwidth + distance)
            + fabric.per_depth_cost + fabric.t_launch)


def t_oneshot_allgather(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Single-launch direct shard exchange (B = gathered size): each
    device unicasts its B/P shard to all P-1 peers in one program."""
    if p == 1:
        return 0.0
    bw = fabric.link_bw
    shard = b / p
    contention = b * (p - 1) / p / bw
    bandwidth = shard * _ring_hop_sum(p) / bw
    distance = float(p)
    return (max(contention, bandwidth + distance)
            + fabric.per_depth_cost + fabric.t_launch)


def t_oneshot_all_to_all(p: int, b: int, fabric: Fabric = WSE2) -> float:
    """Single-launch personalized exchange (B = per-device bytes): all
    P-1 destination chunks in flight at once, depth 1."""
    if p <= 1:
        return 0.0
    bw = fabric.link_bw
    chunk = b / p
    contention = b * (p - 1) / p / bw
    bandwidth = chunk * _ring_hop_sum(p) / bw
    distance = float(p)
    return (max(contention, bandwidth + distance)
            + fabric.per_depth_cost + fabric.t_launch)


#: program launches per (op, algorithm) at axis size P -- the L_i column
#: of the ``calibrate_launch`` least-squares design matrix.
def launch_count(op: str, algorithm: str, p: int) -> float:
    if p <= 1:
        return 0.0
    lg = math.ceil(math.log2(p))
    if algorithm == "oneshot":
        return 1.0
    if op == "allreduce":
        if algorithm == "ring":
            return float(2 * (p - 1))
        s = min(max(1, round(math.sqrt(p))), p)
        reduce_rounds = {"star": p - 1, "chain": p - 1, "tree": lg,
                         "two_phase": (s - 1) + (ceil_div(p, s) - 1),
                         }.get(algorithm, p - 1)
        return float(reduce_rounds + lg)     # + doubling broadcast half
    if op in ("reduce_scatter", "allgather", "all_to_all", "broadcast"):
        return float({"ring": p - 1, "doubling": lg, "halving": lg,
                      "chain": p - 1}.get(algorithm, p - 1))
    return float(p - 1)


#: nominal MXU throughput in MACs per model cycle: a v5e axis cycle is
#: ~11.4 ns (one 512-byte flit over a 45 GB/s link) and the chip peaks
#: near 1e14 MAC/s, so ~2^20 MACs fit in one cycle.  Only the *ratio*
#: of compute to wire time enters the fused-overlap decision.
MXU_MACS_PER_CYCLE = float(1 << 20)


def t_matmul(m: int, k: int, n: int,
             macs_per_cycle: float = MXU_MACS_PER_CYCLE) -> float:
    """Model cycles for an [m, k] @ [k, n] GEMM at nominal MXU rate."""
    return float(m) * float(k) * float(n) / macs_per_cycle


def t_fused_matmul_rs(p: int, b: int, t_mm: float,
                      fabric: Fabric = WSE2) -> float:
    """Overlapped fused matmul + ring reduce-scatter (the PR 6 wavefront
    closed form with C = P chunks over two disjoint resource classes,
    MXU vs wire).

    ``b`` is the full [M, N] partial product in elements, ``t_mm`` the
    cycles of the full local GEMM.  The ring computes one of the P row
    blocks per step (``t_mm / p`` MXU cycles) while the previous step's
    accumulator rotates downstream (one B/P-element hop); fill is one
    GEMM chunk, then P-1 beats of the slower class, then the last hop::

        T_fused = t_mm/P + (P-1) * max(t_mm/P, t_hop) + t_hop

    with ``t_hop = (B/P)/bw + per_depth_cost + t_launch`` -- the same
    per-hop price ``t_ring_reduce_scatter`` charges P-1 times.  Against
    the serialized ``t_mm + t_rs`` this wins exactly when a block GEMM
    outlasts a hop (compute long enough to hide the wire), which is the
    crossover the engine's pricing exposes."""
    t_mm = float(t_mm)
    if p <= 1:
        return t_mm
    t_hop = ((b / p) / fabric.link_bw + fabric.per_depth_cost
             + fabric.t_launch)
    return t_mm / p + (p - 1) * max(t_mm / p, t_hop) + t_hop


ALL_TO_ALL_PATTERNS: Dict[str, Callable[[int, int, Fabric], float]] = {
    "ring": t_ring_all_to_all,
    "halving": t_halving_all_to_all,
}

ALLGATHER_PATTERNS: Dict[str, Callable[[int, int, Fabric], float]] = {
    "ring": t_ring_allgather,
    "doubling": t_doubling_allgather,
    "oneshot": t_oneshot_allgather,
}

BROADCAST_PATTERNS: Dict[str, Callable[[int, int, Fabric], float]] = {
    "doubling": t_doubling_broadcast,
    "chain": t_chain_broadcast,
}


# ---------------------------------------------------------------------- #
# 2D collectives (Sec. 7); grid is M rows x N cols, root at (0, 0)
# ---------------------------------------------------------------------- #
def t_broadcast_2d(m: int, n: int, b: int, fabric: Fabric = WSE2) -> float:
    """Lemma 7.1: T = B + M + N - 2 + 2*T_R + 1."""
    p = m * n
    terms = CostTerms(depth=1, distance=(m - 1) + (n - 1),
                      energy=b * (p - 1), contention=b,
                      links=max(p - 1, 1), label="bcast2d")
    return terms.cycles(fabric)


def t_xy_reduce(pattern: str, m: int, n: int, b: int,
                fabric: Fabric = WSE2,
                fabric_m: Optional[Fabric] = None,
                fabric_n: Optional[Fabric] = None) -> float:
    """X-Y Reduce (Sec. 7.2): 1D reduce along rows, then along column 0.

    ``fabric_m`` / ``fabric_n`` price each grid dimension with its own
    (axis-local) constants on a heterogeneous topology; both default to
    ``fabric``."""
    fn = REDUCE_PATTERNS[pattern]
    return (fn(n, b, fabric_n or fabric) + fn(m, b, fabric_m or fabric))


def t_snake_reduce(m: int, n: int, b: int, fabric: Fabric = WSE2) -> float:
    """Snake Reduce (Sec. 7.3): chain over all M*N PEs, unit hops.  On a
    heterogeneous grid pass the slowest of the two axis fabrics -- the
    one chain crosses both link classes."""
    return t_chain(m * n, b, fabric)


def t_xy_allreduce(pattern: str, m: int, n: int, b: int,
                   fabric: Fabric = WSE2,
                   fabric_m: Optional[Fabric] = None,
                   fabric_n: Optional[Fabric] = None) -> float:
    """AllReduce on x then y (Sec. 7.4, first variant)."""
    return (t_allreduce(pattern, n, b, fabric_n or fabric)
            + t_allreduce(pattern, m, b, fabric_m or fabric))


def t_reduce_bcast_2d(pattern: str, m: int, n: int, b: int,
                      fabric: Fabric = WSE2) -> float:
    """AllReduce as 2D Reduce + 2D Broadcast (Sec. 7.4, second variant)."""
    if pattern == "snake":
        red = t_snake_reduce(m, n, b, fabric)
    else:
        red = t_xy_reduce(pattern, m, n, b, fabric)
    return red + t_broadcast_2d(m, n, b, fabric)


def t_lower_bound_2d(m: int, n: int, b: int, fabric: Fabric = WSE2) -> float:
    """Lemma 7.2: T >= max(B, B/8 + M + N - 1) + 2*T_R + 1, plus one
    program launch (any collective dispatches at least once).

    On a heterogeneous grid instantiate with a fabric no slower than any
    axis's (max link_bw, min latency) so the bound stays a bound."""
    bw = fabric.link_bw
    return (max(float(b) / bw, b / 8.0 / bw + m + n - 1)
            + fabric.per_depth_cost * 1.0 + fabric.t_launch * 1.0)


__all__ = [
    "t_message", "t_broadcast", "t_star", "t_chain", "t_tree",
    "t_two_phase", "t_autogen_tree", "t_reduce_then_broadcast",
    "t_allreduce", "t_ring_allreduce", "t_broadcast_2d", "t_xy_reduce",
    "t_snake_reduce", "t_xy_allreduce", "t_reduce_bcast_2d",
    "t_lower_bound_2d", "t_ring_reduce_scatter", "t_ring_allgather",
    "t_doubling_allgather", "t_doubling_broadcast", "t_chain_broadcast",
    "t_ring_all_to_all", "t_halving_all_to_all",
    "t_oneshot_allreduce", "t_oneshot_allgather", "t_oneshot_all_to_all",
    "launch_count", "t_matmul", "t_fused_matmul_rs",
    "MXU_MACS_PER_CYCLE",
    "REDUCE_PATTERNS", "ALLREDUCE_PATTERNS", "REDUCE_SCATTER_PATTERNS",
    "ALLGATHER_PATTERNS", "BROADCAST_PATTERNS", "ALL_TO_ALL_PATTERNS",
]
