"""Schedule IR: ordered reduction trees (the paper's pre-order trees).

Every 1D Reduce pattern in the paper -- Star, Chain, Tree, Two-Phase, and
the Auto-Gen output -- is an instance of one IR: a rooted tree over PEs
0..P-1 in which every vertex receives the (partial) vectors of its children
*in order* and forwards its combined vector to its parent.  The paper's
execution semantics (Sec. 5.5, Fig. 6) are:

* a vertex fully receives each child's message before the next child's
  message is accepted (routing configurations serialize receives);
* the *last* child's stream is pipelined: element m of the parent's
  outgoing message departs once element m of the last child has been
  added (this is what makes Chain cost B + (2T_R+2)(P-1) instead of B*P);
* communication edges may not overlap/cross, which for pre-order trees is
  equivalent to every subtree owning a contiguous interval of PE indices.

The IR carries enough structure to (a) evaluate the spatial cost terms,
(b) drive the flow-level and wavelet-level simulators, and (c) be lowered
to a round-based ``ppermute`` program for TPU meshes.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Tuple

from repro.core.model import CostTerms, is_power_of_two


Position = Tuple[int, int]


@dataclasses.dataclass
class ReduceTree:
    """Ordered reduction tree over PEs ``0..p-1`` (root receives the sum)."""

    parent: List[int]            # parent[v], -1 for the root
    children: List[List[int]]    # children in receive order
    root: int
    positions: Optional[List[Position]] = None  # defaults to 1D row layout
    label: str = ""

    # ------------------------------------------------------------------ #
    # basic structure
    # ------------------------------------------------------------------ #
    @property
    def num_pes(self) -> int:
        return len(self.parent)

    def position(self, v: int) -> Position:
        if self.positions is None:
            return (v, 0)
        return self.positions[v]

    def hop_distance(self, u: int, v: int) -> int:
        """Manhattan (X-Y routed) hop distance between two PEs."""
        (ux, uy), (vx, vy) = self.position(u), self.position(v)
        return abs(ux - vx) + abs(uy - vy)

    def edges(self) -> Iterator[Tuple[int, int]]:
        """Yield (child, parent) pairs."""
        for v, p in enumerate(self.parent):
            if p >= 0:
                yield (v, p)

    def subtree_sizes(self) -> List[int]:
        size = [1] * self.num_pes
        for v in self._topo_leaves_first():
            if self.parent[v] >= 0:
                size[self.parent[v]] += size[v]
        return size

    def _topo_leaves_first(self) -> List[int]:
        """Vertices ordered so that children precede parents."""
        order: List[int] = []
        stack = [self.root]
        while stack:
            v = stack.pop()
            order.append(v)
            stack.extend(self.children[v])
        order.reverse()
        return order

    # ------------------------------------------------------------------ #
    # validation (invariants used by the hypothesis property tests)
    # ------------------------------------------------------------------ #
    def validate(self, require_contiguous: bool = True) -> None:
        p = self.num_pes
        if p == 0:
            raise ValueError("empty tree")
        if self.parent[self.root] != -1:
            raise ValueError("root must have parent -1")
        roots = [v for v in range(p) if self.parent[v] == -1]
        if roots != [self.root]:
            raise ValueError(f"expected a single root {self.root}, got {roots}")
        # children/parent consistency
        seen = set()
        for v in range(p):
            for c in self.children[v]:
                if self.parent[c] != v:
                    raise ValueError(f"child {c} of {v} has parent {self.parent[c]}")
                if c in seen:
                    raise ValueError(f"vertex {c} appears as a child twice")
                seen.add(c)
        if len(seen) != p - 1:
            raise ValueError("not all non-root vertices are children")
        # connectivity
        if len(self._topo_leaves_first()) != p:
            raise ValueError("tree is not connected")
        if require_contiguous and self.positions is None:
            self._validate_contiguous()

    def _validate_contiguous(self) -> None:
        """Non-overlapping edges <=> every subtree is an index interval."""
        lo = list(range(self.num_pes))
        hi = list(range(self.num_pes))
        size = [1] * self.num_pes
        for v in self._topo_leaves_first():
            par = self.parent[v]
            if par >= 0:
                lo[par] = min(lo[par], lo[v])
                hi[par] = max(hi[par], hi[v])
                size[par] += size[v]
        for v in range(self.num_pes):
            if hi[v] - lo[v] + 1 != size[v]:
                raise ValueError(
                    f"subtree of {v} is not contiguous: [{lo[v]},{hi[v]}] size {size[v]}"
                )

    # ------------------------------------------------------------------ #
    # spatial cost terms (feeds the performance model, Eq. 1)
    # ------------------------------------------------------------------ #
    def cost_terms(self, b: int, links: Optional[float] = None) -> CostTerms:
        depth = [0] * self.num_pes
        path_hops = [0] * self.num_pes
        energy = 0.0
        stack = [self.root]
        while stack:
            v = stack.pop()
            for c in self.children[v]:
                d = self.hop_distance(c, v)
                energy += float(b) * d
                depth[c] = depth[v] + 1
                path_hops[c] = path_hops[v] + d
                stack.append(c)
        contention = float(b) * max(
            (len(ch) for ch in self.children), default=0
        )
        if links is None:
            links = float(max(self.num_pes - 1, 1))
        return CostTerms(
            depth=float(max(depth)),
            distance=float(max(path_hops)),
            energy=energy,
            contention=contention,
            links=float(links),
            label=self.label,
        )

    # ------------------------------------------------------------------ #
    # lowering to rounds of disjoint sends (for the TPU ppermute executor)
    # ------------------------------------------------------------------ #
    def to_rounds(self) -> List[List[Tuple[int, int]]]:
        """Rounds of (src, dst) pairs; within a round all dsts are distinct
        and all srcs are distinct, so one round == one masked ppermute+add.

        An edge fires after (a) its source finished receiving all of its own
        children and (b) the previous sibling edge (receive order!) fired.
        """
        fire: List[int] = [0] * self.num_pes  # round in which v's edge fires
        # compute in leaves-first order: fire[v] depends on children of v and
        # on previous siblings.
        done: List[int] = [0] * self.num_pes  # round after which v is reduced
        for v in self._topo_leaves_first():
            r = 0
            for c in self.children[v]:
                # child c's edge fires after c is fully reduced and after the
                # previous sibling's edge.
                f = max(done[c], r)
                fire[c] = f
                r = f + 1
            done[v] = r
        rounds: List[List[Tuple[int, int]]] = []
        for v, p in self.edges():
            r = fire[v]
            while len(rounds) <= r:
                rounds.append([])
            rounds[r].append((v, p))
        # drop the root's (nonexistent) edge; sanity: disjointness
        for r, sends in enumerate(rounds):
            dsts = [d for _, d in sends]
            srcs = [s for s, _ in sends]
            if len(set(dsts)) != len(dsts) or len(set(srcs)) != len(srcs):
                raise AssertionError(f"round {r} has colliding sends: {sends}")
        return rounds


# ---------------------------------------------------------------------- #
# fixed patterns as trees (root = PE 0, the leftmost PE)
# ---------------------------------------------------------------------- #
def star_tree(p: int) -> ReduceTree:
    """Every PE sends directly to the root (Sec. 5.1); receive order is by
    distance so nearer streams drain first."""
    parent = [-1] + [0] * (p - 1)
    children = [list(range(1, p))] + [[] for _ in range(p - 1)]
    return ReduceTree(parent, children, root=0, label="star")


def chain_tree(p: int) -> ReduceTree:
    """Pipelined chain (Sec. 5.2): i receives from i+1."""
    parent = [i - 1 for i in range(p)]
    children = [[i + 1] if i + 1 < p else [] for i in range(p)]
    return ReduceTree(parent, children, root=0, label="chain")


def binary_tree(p: int) -> ReduceTree:
    """Recursive-halving tree (Sec. 5.3); p must be a power of two."""
    if not is_power_of_two(p):
        raise ValueError(f"binary_tree needs a power-of-two P, got {p}")
    parent = [-1] * p
    children: List[List[int]] = [[] for _ in range(p)]
    step = 1
    while step < p:
        for v in range(0, p, 2 * step):
            u = v + step
            if u < p:
                parent[u] = v
                children[v].append(u)  # receive order == round order
        step *= 2
    return ReduceTree(parent, children, root=0, label="tree")


def two_phase_tree(p: int, s: Optional[int] = None) -> ReduceTree:
    """Two-Phase Reduce (Sec. 5.4): chain within groups of S, then a chain
    over the group leaders.  Default S = round(sqrt(P))."""
    if s is None:
        s = max(1, round(p ** 0.5))
    s = min(s, p)
    parent = [-1] * p
    children: List[List[int]] = [[] for _ in range(p)]
    leaders = list(range(0, p, s))
    # phase 1: chain within each group towards its leader
    for g in leaders:
        end = min(g + s, p)
        for v in range(g + 1, end):
            parent[v] = v - 1
            children[v - 1].append(v)
    # phase 2: chain over leaders; leader g receives its group first, then
    # the next leader (pipelined last child).
    for i in range(len(leaders) - 1):
        a, b_ = leaders[i], leaders[i + 1]
        parent[b_] = a
        children[a].append(b_)
    return ReduceTree(parent, children, root=0, label=f"two_phase(S={s})")


def snake_tree(m: int, n: int) -> ReduceTree:
    """2D Snake Reduce (Sec. 7.3): a chain over the boustrophedon order of
    an M x N grid; every hop has distance 1."""
    order: List[int] = []
    positions: List[Position] = []
    for y in range(m):
        xs = range(n) if y % 2 == 0 else range(n - 1, -1, -1)
        for x in xs:
            order.append(y * n + x)
    p = m * n
    # Re-index so that PE ids follow the snake (pre-order = snake order).
    positions = [(0, 0)] * p
    for rank, flat in enumerate(order):
        positions[rank] = (flat % n, flat // n)
    parent = [i - 1 for i in range(p)]
    children = [[i + 1] if i + 1 < p else [] for i in range(p)]
    return ReduceTree(parent, children, root=0, positions=positions,
                      label="snake")


PATTERN_BUILDERS: dict = {
    "star": star_tree,
    "chain": chain_tree,
    "tree": binary_tree,
    "two_phase": two_phase_tree,
}


__all__ = [
    "ReduceTree",
    "star_tree",
    "chain_tree",
    "binary_tree",
    "two_phase_tree",
    "snake_tree",
    "PATTERN_BUILDERS",
]
