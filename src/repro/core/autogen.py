"""Auto-Gen Reduce (Sec. 5.5): DP over pre-order reduction trees.

The DP computes, in unit-vector-length ("per element") terms,

    E(P, D, C) = min_i  E(i, D, C-1) + E(P-i, D-1, C) + i

the minimum energy of a reduce over P consecutive PEs with depth <= D and
per-PE contention <= C.  The runtime estimate for vector length B is then

    T(P, B) = min_{(D, C)}  max(C*B, B*E(P,D,C)/(P-1) + P-1) + D*(2*T_R+1)

and the optimal tree is recovered by backtracking the argmin splits.  The
tree generalizes Star (star graph), Chain (path), Tree and Two-Phase, so
Auto-Gen matches or beats every fixed pattern under the model (Sec. 5.5).

Exploring all (D, C) pairs up to P is O(P^4); we restrict the search to the
downward-closed region  {C <= c_small}  U  {D <= d_small}  which provably
contains every pattern family the model can favor (chain-like solutions
need large D but C ~ 1..c_small; star-like solutions need large C but
D ~ 1..d_small; everything in between has both small).  Tables are cached
on disk keyed by the region parameters.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.model import Fabric, WSE2
from repro.core.schedule import ReduceTree

_DEFAULT_CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "..",
                                  "..", "var", "cache")


def cache_dir() -> str:
    """On-disk cache root shared by the Auto-Gen tables and the
    CollectiveEngine decision cache.  Override with REPRO_CACHE_DIR."""
    return os.environ.get("REPRO_CACHE_DIR", _DEFAULT_CACHE_DIR)

INF = np.float32(np.inf)


@dataclasses.dataclass
class AutoGenTables:
    """DP tables over the explored (D, C) region."""

    p_max: int
    pairs: List[Tuple[int, int]]          # explored (d, c) pairs
    pair_index: Dict[Tuple[int, int], int]
    energy: np.ndarray                    # [n_pairs, p_max + 1] float32
    split: np.ndarray                     # [n_pairs, p_max + 1] int16 argmin i

    def e(self, d: int, c: int, p: int) -> float:
        idx = self.pair_index.get((d, c))
        if idx is None:
            return float("inf")
        return float(self.energy[idx, p])


def _region_pairs(d_max: int, c_max: int, d_small: int, c_small: int
                  ) -> List[Tuple[int, int]]:
    pairs = []
    for d in range(1, d_max + 1):
        c_hi = c_max if d <= d_small else c_small
        for c in range(1, c_hi + 1):
            pairs.append((d, c))
    return pairs


def compute_tables(p_max: int, d_max: Optional[int] = None,
                   c_max: Optional[int] = None, d_small: int = 12,
                   c_small: int = 16, use_cache: bool = True) -> AutoGenTables:
    """Fill the Auto-Gen DP tables for all P <= p_max."""
    if d_max is None:
        d_max = max(p_max - 1, 1)
    if c_max is None:
        c_max = max(p_max - 1, 1)
    d_max = max(1, min(d_max, p_max - 1 if p_max > 1 else 1))
    c_max = max(1, min(c_max, p_max - 1 if p_max > 1 else 1))
    d_small = min(d_small, d_max)
    c_small = min(c_small, c_max)

    cache_key = f"autogen_P{p_max}_D{d_max}_C{c_max}_ds{d_small}_cs{c_small}"
    cache_path = os.path.join(cache_dir(), cache_key + ".npz")
    pairs = _region_pairs(d_max, c_max, d_small, c_small)
    pair_index = {pc: k for k, pc in enumerate(pairs)}

    if use_cache and os.path.exists(cache_path):
        data = np.load(cache_path)
        return AutoGenTables(p_max, pairs, pair_index,
                             data["energy"], data["split"])

    n = len(pairs)
    energy = np.full((n, p_max + 1), INF, dtype=np.float32)
    split = np.zeros((n, p_max + 1), dtype=np.int16)
    energy[:, 1] = 0.0  # single PE: nothing to do
    if p_max == 1:
        return AutoGenTables(p_max, pairs, pair_index, energy, split)

    # Precompute index helpers for the min-plus convolution:
    #   M[P] = min_{1<=i<=P-1}  (A[i] + i) + B2[P-i]
    i_vals = np.arange(1, p_max, dtype=np.int64)          # i = 1..p_max-1
    p_vals = np.arange(0, p_max + 1, dtype=np.int64)      # P = 0..p_max
    diff = p_vals[None, :] - i_vals[:, None]              # P - i
    valid = diff >= 1
    diff_clip = np.clip(diff, 0, p_max)

    zero_c = np.full(p_max + 1, INF, dtype=np.float32)    # E(., d, 0)
    zero_c[1] = 0.0
    zero_d = zero_c                                        # E(., 0, c)

    for k, (d, c) in enumerate(pairs):
        a = energy[pair_index[(d, c - 1)]] if c >= 2 else zero_c
        b2 = energy[pair_index[(d - 1, c)]] if (d - 1, c) in pair_index \
            else (zero_d if d == 1 else None)
        if b2 is None:
            # (d-1, c) outside region: can only happen when c > c_small and
            # d == d_small + 1, which _region_pairs excludes.  Guard anyway.
            b2 = zero_d
        av = a[1:p_max].astype(np.float32) + i_vals.astype(np.float32)
        mat = av[:, None] + np.where(valid, b2[diff_clip], INF)
        energy[k] = np.minimum(mat.min(axis=0), energy[k])
        split[k] = np.argmin(mat, axis=0) + 1
        energy[k, 1] = 0.0

    if use_cache:
        os.makedirs(cache_dir(), exist_ok=True)
        tmp = cache_path + f".tmp{os.getpid()}.npz"
        np.savez_compressed(tmp, energy=energy, split=split)
        os.replace(tmp, cache_path)
    return AutoGenTables(p_max, pairs, pair_index, energy, split)


# ---------------------------------------------------------------------- #
# runtime evaluation + tree extraction
# ---------------------------------------------------------------------- #
def t_autogen(p: int, b: int, fabric: Fabric = WSE2,
              tables: Optional[AutoGenTables] = None
              ) -> Tuple[float, Tuple[int, int]]:
    """Best model runtime over the explored (D, C) region, and its (D, C)."""
    if p == 1:
        return 0.0, (0, 0)
    if tables is None or tables.p_max < p:
        tables = compute_tables(p)
    ds = np.array([d for d, _ in tables.pairs], dtype=np.float64)
    cs = np.array([c for _, c in tables.pairs], dtype=np.float64)
    e = tables.energy[:, p].astype(np.float64)
    bw = fabric.link_bw
    t = (np.maximum(cs * b / bw, b * e / ((p - 1) * bw) + (p - 1))
         + ds * fabric.per_depth_cost)
    t = np.where(np.isfinite(e), t, np.inf)
    k = int(np.argmin(t))
    return float(t[k]), tables.pairs[k]


def autogen_tree(p: int, b: int, fabric: Fabric = WSE2,
                 tables: Optional[AutoGenTables] = None) -> ReduceTree:
    """Extract the optimal ordered reduction tree for (P, B)."""
    if tables is None or tables.p_max < p:
        tables = compute_tables(p)
    _, (d_opt, c_opt) = t_autogen(p, b, fabric, tables)
    parent = [-1] * p
    children: List[List[int]] = [[] for _ in range(p)]

    def build(offset: int, pp: int, d: int, c: int) -> None:
        if pp <= 1:
            return
        k = tables.pair_index[(d, c)]
        i = int(tables.split[k, pp])
        if not (1 <= i <= pp - 1):
            raise AssertionError(f"bad split {i} for (P={pp}, D={d}, C={c})")
        # earlier children of `offset` come from the left part [offset, offset+i)
        build(offset, i, d, c - 1)
        # last (pipelined) child: vertex offset+i owns [offset+i, offset+pp)
        child = offset + i
        parent[child] = offset
        children[offset].append(child)
        build(child, pp - i, d - 1, c)

    if p > 1:
        build(0, p, d_opt, c_opt)
    tree = ReduceTree(parent, children, root=0,
                      label=f"autogen(D={d_opt},C={c_opt})")
    tree.validate()
    return tree


__all__ = ["AutoGenTables", "cache_dir", "compute_tables", "t_autogen",
           "autogen_tree"]
