"""Model-driven algorithm selection (the paper's Figs. 1, 8, 10).

Given (B, P) -- and a fabric parameterization -- evaluate every pattern
under the performance model and pick the winner.  This is the mechanism the
paper uses both to choose collectives and to generate Fig. 8/10 heatmaps,
and the mechanism our TPU collective layer reuses with ICI constants.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence


from repro.core import patterns as pat
from repro.core.autogen import AutoGenTables, compute_tables, t_autogen
from repro.core.lowerbound import compute_lb_energy, t_lower_bound
from repro.core.model import Fabric, WSE2, slowest_fabric


@dataclasses.dataclass
class Selection:
    name: str
    predicted_cycles: float
    all_predictions: Dict[str, float]


def predict_reduce(p: int, b: int, fabric: Fabric = WSE2,
                   include_autogen: bool = True,
                   tables: Optional[AutoGenTables] = None) -> Dict[str, float]:
    preds = {name: fn(p, b, fabric) for name, fn in pat.REDUCE_PATTERNS.items()
             if name != "tree" or (p & (p - 1)) == 0}
    if include_autogen:
        preds["autogen"], _ = t_autogen(p, b, fabric, tables)
    return preds


def best_reduce(p: int, b: int, fabric: Fabric = WSE2,
                include_autogen: bool = True,
                tables: Optional[AutoGenTables] = None) -> Selection:
    preds = predict_reduce(p, b, fabric, include_autogen, tables)
    name = min(preds, key=preds.get)
    return Selection(name, preds[name], preds)


def predict_allreduce(p: int, b: int, fabric: Fabric = WSE2,
                      include_autogen: bool = True,
                      tables: Optional[AutoGenTables] = None
                      ) -> Dict[str, float]:
    preds: Dict[str, float] = {}
    for name in pat.ALLREDUCE_PATTERNS:
        if name == "tree" and (p & (p - 1)) != 0:
            continue
        preds[name] = pat.t_allreduce(name, p, b, fabric)
    if include_autogen:
        t_red, _ = t_autogen(p, b, fabric, tables)
        preds["autogen"] = pat.t_reduce_then_broadcast(t_red, p, b, fabric)
    return preds


def best_allreduce(p: int, b: int, fabric: Fabric = WSE2,
                   include_autogen: bool = True,
                   tables: Optional[AutoGenTables] = None) -> Selection:
    preds = predict_allreduce(p, b, fabric, include_autogen, tables)
    name = min(preds, key=preds.get)
    return Selection(name, preds[name], preds)


# ---------------------------------------------------------------------- #
# op-generic prediction: one entry point per collective kind.  This is
# the seam the CollectiveEngine dispatches through; each op returns the
# model estimate for every implemented backend.
# ---------------------------------------------------------------------- #
def predict_reduce_scatter(p: int, b: int, fabric: Fabric = WSE2,
                           include_autogen: bool = True,
                           tables: Optional[AutoGenTables] = None
                           ) -> Dict[str, float]:
    preds = {name: fn(p, b, fabric)
             for name, fn in pat.REDUCE_SCATTER_PATTERNS.items()}
    if include_autogen and p > 1:
        # implemented as P rotated per-chunk tree reduces over B/P
        # elements, serialized in the trace -- model the serialization.
        t_chunk, _ = t_autogen(p, max(1, -(-b // p)), fabric, tables)
        preds["autogen"] = p * t_chunk
    return preds


def predict_allgather(p: int, b: int, fabric: Fabric = WSE2,
                      include_autogen: bool = True,
                      tables: Optional[AutoGenTables] = None
                      ) -> Dict[str, float]:
    preds = {name: fn(p, b, fabric)
             for name, fn in pat.ALLGATHER_PATTERNS.items()
             if name != "doubling" or (p & (p - 1)) == 0}
    if include_autogen and p > 1:
        # reversed reduce schedule per rotated chunk (see shardmap_impl)
        t_chunk, _ = t_autogen(p, max(1, -(-b // p)), fabric, tables)
        preds["autogen"] = p * t_chunk
    return preds


def predict_broadcast(p: int, b: int, fabric: Fabric = WSE2,
                      include_autogen: bool = True,
                      tables: Optional[AutoGenTables] = None
                      ) -> Dict[str, float]:
    preds = {name: fn(p, b, fabric)
             for name, fn in pat.BROADCAST_PATTERNS.items()}
    if include_autogen and p > 1:
        # broadcast down the reversed Auto-Gen tree costs what the
        # reduce up it does (same edges, store replaced by copy)
        preds["autogen"], _ = t_autogen(p, b, fabric, tables)
    return preds


def predict_all_to_all(p: int, b: int, fabric: Fabric = WSE2,
                       include_autogen: bool = True,
                       tables: Optional[AutoGenTables] = None
                       ) -> Dict[str, float]:
    """AllToAll has no reduction tree, so there is no Auto-Gen backend:
    the candidate set is the closed-form patterns (injection-optimal
    pairwise ring vs log-launch Bruck halving).  ``include_autogen`` /
    ``tables`` are accepted for signature uniformity and ignored."""
    del include_autogen, tables
    preds = {name: fn(p, b, fabric)
             for name, fn in pat.ALL_TO_ALL_PATTERNS.items()}
    return preds


def best_all_to_all(p: int, b: int, fabric: Fabric = WSE2) -> Selection:
    preds = predict_all_to_all(p, b, fabric)
    name = min(preds, key=preds.get)
    return Selection(name, preds[name], preds)


_OP_PREDICTORS = {
    "reduce": predict_reduce,
    "allreduce": predict_allreduce,
    "reduce_scatter": predict_reduce_scatter,
    "allgather": predict_allgather,
    "broadcast": predict_broadcast,
    "all_to_all": predict_all_to_all,
}

COLLECTIVE_OPS = tuple(_OP_PREDICTORS)


def predict_collective(op: str, p: int, b: int, fabric: Fabric = WSE2,
                       include_autogen: bool = True,
                       tables: Optional[AutoGenTables] = None
                       ) -> Dict[str, float]:
    try:
        fn = _OP_PREDICTORS[op]
    except KeyError:
        raise ValueError(f"unknown collective op {op!r}; "
                         f"expected one of {COLLECTIVE_OPS}") from None
    return fn(p, b, fabric, include_autogen, tables)


def best_collective(op: str, p: int, b: int, fabric: Fabric = WSE2,
                    include_autogen: bool = True,
                    tables: Optional[AutoGenTables] = None) -> Selection:
    preds = predict_collective(op, p, b, fabric, include_autogen, tables)
    name = min(preds, key=preds.get)
    return Selection(name, preds[name], preds)


# ---------------------------------------------------------------------- #
# heatmaps (Figs. 8 and 10): best fixed algorithm per (B, P) cell
# ---------------------------------------------------------------------- #
def heatmap_1d_allreduce(b_values: Sequence[int], p_values: Sequence[int],
                         fabric: Fabric = WSE2) -> List[List[str]]:
    grid = []
    for b in b_values:
        row = []
        for p in p_values:
            row.append(best_allreduce(p, b, fabric,
                                      include_autogen=False).name)
        grid.append(row)
    return grid


def t_broadcast_2d_fabric(m: int, n: int, b: int,
                          fabric: Fabric = WSE2,
                          fabric_m: Optional[Fabric] = None,
                          fabric_n: Optional[Fabric] = None) -> float:
    """2D broadcast honoring the fabric: flooding multicast on the WSE
    (Lemma 7.1), per-axis log-depth doubling where multicast is missing
    (ICI) -- what the 2D shard_map implementation actually executes.

    ``fabric_m`` / ``fabric_n`` price each grid dimension with its own
    axis-local constants; the flooding form (one stream crossing both
    dimensions) conservatively takes the slower of the two."""
    fm = fabric_m or fabric
    fn_ = fabric_n or fabric
    if fm == fn_:
        if fm.multicast:
            return pat.t_broadcast_2d(m, n, b, fm)
        return (pat.t_doubling_broadcast(m, b, fm)
                + pat.t_doubling_broadcast(n, b, fm))
    if fm.multicast and fn_.multicast:
        return pat.t_broadcast_2d(m, n, b, slowest_fabric(fm, fn_))
    return (pat.t_doubling_broadcast(m, b, fm)
            + pat.t_doubling_broadcast(n, b, fn_))


def predict_allreduce_2d(m: int, n: int, b: int, fabric: Fabric = WSE2,
                         fabric_m: Optional[Fabric] = None,
                         fabric_n: Optional[Fabric] = None
                         ) -> Dict[str, float]:
    """2D AllReduce candidates over an M x N grid (Sec. 7.4): every X-Y
    pattern plus the snake, each composed with the fabric-appropriate
    2D broadcast.  The seam the topology planner and the Fig. 10
    heatmap share.  Per-axis constants (``fabric_m``/``fabric_n``)
    price each grid dimension with its own fabric; the snake chain --
    which crosses both link classes -- takes the slower of the two."""
    fm = fabric_m or fabric
    fn_ = fabric_n or fabric
    bc = t_broadcast_2d_fabric(m, n, b, fabric, fabric_m=fm, fabric_n=fn_)
    preds: Dict[str, float] = {}
    for name in ("star", "chain", "tree", "two_phase"):
        if name == "tree" and ((m & (m - 1)) != 0 or (n & (n - 1)) != 0):
            continue
        preds[f"xy_{name}"] = pat.t_xy_reduce(name, m, n, b, fabric,
                                              fabric_m=fm,
                                              fabric_n=fn_) + bc
    preds["snake"] = pat.t_snake_reduce(m, n, b,
                                        slowest_fabric(fm, fn_)) + bc
    return preds


def heatmap_2d_allreduce(b_values: Sequence[int], side_values: Sequence[int],
                         fabric: Fabric = WSE2) -> List[List[str]]:
    """Best fixed 2D AllReduce (X-Y pattern + bcast, or snake + bcast)."""
    grid = []
    for b in b_values:
        row = []
        for side in side_values:
            preds = predict_allreduce_2d(side, side, b, fabric)
            row.append(min(preds, key=preds.get))
        grid.append(row)
    return grid


def optimality_ratios(p: int, b_values: Sequence[int], fabric: Fabric = WSE2,
                      tables: Optional[AutoGenTables] = None,
                      lb_table=None) -> Dict[str, List[float]]:
    """Fig. 1: pattern cost / lower bound, per vector length."""
    if tables is None:
        tables = compute_tables(p)
    if lb_table is None:
        lb_table = compute_lb_energy(p)
    out: Dict[str, List[float]] = {}
    for b in b_values:
        lb = max(t_lower_bound(p, b, fabric, lb_table), 1e-9)
        preds = predict_reduce(p, b, fabric, include_autogen=True,
                               tables=tables)
        for name, t in preds.items():
            out.setdefault(name, []).append(t / lb)
    return out


__all__ = [
    "Selection", "predict_reduce", "best_reduce", "predict_allreduce",
    "best_allreduce", "predict_reduce_scatter", "predict_allgather",
    "predict_broadcast", "predict_all_to_all", "best_all_to_all",
    "predict_collective", "best_collective",
    "predict_allreduce_2d", "t_broadcast_2d_fabric",
    "COLLECTIVE_OPS", "heatmap_1d_allreduce", "heatmap_2d_allreduce",
    "optimality_ratios",
]
