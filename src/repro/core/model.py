"""Spatial performance model for wafer-scale (and torus-ICI) fabrics.

Implements the paper's Eq. (1):

    T = max(C, E/N + L) + (2*T_R + 1) * D

over the four spatial cost terms

    D  depth       -- longest chain of sequentially dependent messages
    L  distance    -- hops travelled along the critical path
    E  energy      -- total element-hops injected into the fabric
    C  contention  -- max elements received (or sent) by any single PE

with N the number of links usable by the pattern and T_R the ramp
(processor<->router) latency.  All costs are in elements == cycles
(1 element/link/cycle on the WSE).

Two parameterizations are provided:

* ``WSE2`` -- the Cerebras CS-2 constants from the paper (T_R = 2).
* ``TPUv5eAxis`` -- re-parameterization of the same model for a TPU v5e ICI
  axis, used by the TPU collective selector (see DESIGN.md: hardware
  adaptation).  There, "cycles" are nanoseconds, a "link" is an ICI link,
  and T_R models per-hop SerDes/launch latency.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class Fabric:
    """Hardware constants that parameterize the spatial model.

    ``link_bw`` is the link bandwidth in elements/cycle *relative to the
    model's unit link* (WSE: 1).  All wire-serialized terms scale by
    ``1 / link_bw``, so a ``link_bw=0.25`` axis prices its traffic 4x
    slower than a ``link_bw=1.0`` axis of the same topology -- the knob
    per-axis calibration uses to express "pod links are slower than
    intra-pod ICI" on a shared time base.
    """

    name: str
    t_r: float          # ramp latency (cycles) each way between PE and router
    store_cost: float   # cycles to store/add one received element
    link_bw: float = 1.0  # elements per cycle per link (WSE: 1)
    multicast: bool = True  # WSE routers replicate; ICI must software-fan-out
    t_launch: float = 0.0  # per-launch host/framework overhead (cycles):
    #                        dispatching one collective program, on top of
    #                        the wire-side t_r the depth term already pays.
    #                        0 until fitted (engine.calibrate_launch), so
    #                        the bandwidth-regime prices are unchanged.

    @property
    def per_depth_cost(self) -> float:
        """Cost charged per unit of depth: down-ramp + up-ramp + store."""
        return 2.0 * self.t_r + self.store_cost

    @property
    def hop_pipeline_cost(self) -> float:
        """Pipeline latency added per chain hop: link + ramps + add."""
        return 2.0 * self.t_r + 2.0


#: The paper's CS-2 parameterization (T_R measured to be 2, Sec. 2.2).
WSE2 = Fabric(name="wse2", t_r=2.0, store_cost=1.0)

#: A TPU v5e ICI axis viewed through the same model.  Units: one "element"
#: is one 512-byte ICI flit-group; one "cycle" is the time to push it over
#: one 45 GB/s usable link (~11.4 ns); t_r models the ~1 us per-launch
#: collective-permute latency expressed in those cycles.
TPU_V5E_AXIS = Fabric(name="tpu_v5e_axis", t_r=88.0, store_cost=1.0,
                      multicast=False)


def slowest_fabric(*fabrics: Fabric) -> Fabric:
    """Conservative pick for traffic that may traverse any of several
    link classes (a folded/flat schedule, the snake chain): the fabric
    with the worst bandwidth, ties broken by latency.  With identical
    fabrics this returns the first one, so uniform topologies price
    through the exact same object."""
    if not fabrics:
        raise ValueError("slowest_fabric() needs at least one fabric")
    return max(fabrics,
               key=lambda f: (1.0 / f.link_bw, f.t_r, f.store_cost,
                              f.t_launch))


@dataclasses.dataclass(frozen=True)
class FabricTopology:
    """Per-axis fabric constants for a heterogeneous mesh.

    Maps mesh axis *names* to the :class:`Fabric` whose constants price
    traffic on that axis's links; axes without an entry use ``default``.
    All per-axis fabrics must share one time base (one "cycle"), with
    relative link speed expressed through ``Fabric.link_bw`` -- that is
    what per-axis calibration produces.

    A uniform topology (no overrides) prices bit-for-bit identically to
    passing the bare ``default`` Fabric everywhere: every consumer takes
    the ``for_axis`` fast path that hands back the same object.
    """

    default: Fabric
    axis_fabrics: Tuple[Tuple[str, Fabric], ...] = ()
    name: str = ""

    def __post_init__(self):
        # normalize: duplicate axes collapse last-wins, overrides equal
        # to the default are dropped, and entries sort by axis name --
        # equality/hashing then ignore construction order
        merged = dict(self.axis_fabrics)
        kept = tuple(sorted(
            ((a, f) for a, f in merged.items() if f != self.default),
            key=lambda af: af[0]))
        object.__setattr__(self, "axis_fabrics", kept)
        if not self.name:
            object.__setattr__(self, "name", self.default.name)

    @classmethod
    def uniform(cls, fabric: Fabric) -> "FabricTopology":
        """Every axis priced with the same constants (the pre-topology
        behavior and the fast path)."""
        return cls(default=fabric)

    @property
    def is_uniform(self) -> bool:
        return not self.axis_fabrics

    def for_axis(self, axis: Union[str, Sequence[str], None]) -> Fabric:
        """Fabric for one mesh axis; a tuple (a folded logical axis)
        resolves to the slowest member, conservatively."""
        if axis is None:
            return self.default
        if isinstance(axis, (tuple, list)):
            return slowest_fabric(*(self.for_axis(a) for a in axis))
        for a, f in self.axis_fabrics:
            if a == axis:
                return f
        return self.default

    def with_axis(self, axis: str, fabric: Fabric) -> "FabricTopology":
        kept = tuple((a, f) for a, f in self.axis_fabrics if a != axis)
        return FabricTopology(default=self.default,
                              axis_fabrics=kept + ((axis, fabric),),
                              name=self.name)

    def describe(self) -> str:
        base = (f"{self.default.name}"
                f"(t_r={self.default.t_r:g}, bw={self.default.link_bw:g})")
        if self.is_uniform:
            return base
        per = ", ".join(f"{a}: t_r={f.t_r:g}, bw={f.link_bw:g}"
                        for a, f in self.axis_fabrics)
        return f"{base} [{per}]"


def as_topology(fabric: Union[Fabric, FabricTopology]) -> FabricTopology:
    if isinstance(fabric, FabricTopology):
        return fabric
    return FabricTopology.uniform(fabric)


#: named relative-speed presets for the CLI topology spec:
#: (link_bw multiplier, t_r multiplier) applied to the base fabric
FABRIC_PRESETS: Dict[str, Tuple[float, float]] = {
    "fast": (1.0, 1.0),        # the base axis fabric, unchanged
    "slow": (0.25, 4.0),       # 4x slower cross-pod link
    "dcn": (1.0 / 16.0, 16.0),  # data-center-network-ish inter-pod hop
}


def _fabric_from_dict(d: Dict, base: Fabric) -> Fabric:
    return Fabric(name=str(d.get("name", base.name)),
                  t_r=float(d.get("t_r", base.t_r)),
                  store_cost=float(d.get("store_cost", base.store_cost)),
                  link_bw=float(d.get("link_bw", base.link_bw)),
                  multicast=bool(d.get("multicast", base.multicast)),
                  t_launch=float(d.get("t_launch", base.t_launch)))


def parse_fabric_topology(spec: str,
                          base: Fabric = TPU_V5E_AXIS) -> FabricTopology:
    """Parse a CLI/JSON heterogeneous-topology spec.

    Two forms:

    * ``"pod=slow,data=fast"`` -- comma-separated ``axis=value`` pairs
      where ``value`` is a preset name (:data:`FABRIC_PRESETS`) or a
      bare float, read as a ``link_bw`` multiplier on ``base`` (so
      ``pod=0.25`` is a 4x-slower pod link).
    * a path to a JSON file ``{"default": {...}, "axes": {"pod": {...}}}``
      whose fabric dicts may set any of ``name/t_r/store_cost/link_bw/
      multicast`` (missing fields inherit from ``default``/``base``).
    """
    spec = spec.strip()
    if spec.endswith(".json") or os.path.isfile(spec):
        with open(spec) as f:
            payload = json.load(f)
        default = _fabric_from_dict(payload.get("default", {}), base)
        axes = tuple(
            (axis, _fabric_from_dict(d, default))
            for axis, d in sorted(payload.get("axes", {}).items()))
        return FabricTopology(default=default, axis_fabrics=axes)
    default = base
    axes = []
    for part in filter(None, (p.strip() for p in spec.split(","))):
        if "=" not in part:
            raise ValueError(f"bad fabric spec entry {part!r}; expected "
                             f"axis=preset or axis=<link_bw multiplier>")
        axis, value = (s.strip() for s in part.split("=", 1))
        if value in FABRIC_PRESETS:
            bw_mult, tr_mult = FABRIC_PRESETS[value]
            suffix = value
        else:
            try:
                bw_mult, tr_mult = float(value), 1.0
            except ValueError:
                raise ValueError(
                    f"unknown fabric preset {value!r} for axis {axis!r}; "
                    f"have {sorted(FABRIC_PRESETS)} or a float "
                    f"link_bw multiplier") from None
            if bw_mult <= 0.0:
                raise ValueError(
                    f"link_bw multiplier for axis {axis!r} must be > 0, "
                    f"got {value!r}")
            suffix = f"bw{value}"
        if (bw_mult, tr_mult) == (1.0, 1.0):
            fab = base          # "fast"/1.0: the base fabric itself, so
                                # the axis stays on the uniform fast path
        else:
            fab = dataclasses.replace(base, name=f"{base.name}_{suffix}",
                                      link_bw=base.link_bw * bw_mult,
                                      t_r=base.t_r * tr_mult)
        if axis == "default":
            default = fab
        else:
            axes.append((axis, fab))
    return FabricTopology(default=default, axis_fabrics=tuple(axes))


@dataclasses.dataclass(frozen=True)
class CostTerms:
    """Spatial cost decomposition of one collective pattern instance."""

    depth: float
    distance: float
    energy: float
    contention: float
    links: float
    label: str = ""
    launches: float = 0.0   # sequential program launches the pattern
    #                         issues; each pays Fabric.t_launch

    def cycles(self, fabric: Fabric = WSE2) -> float:
        """Paper Eq. (1), with wire terms scaled by the link bandwidth
        and ``launches`` program dispatches each paying ``t_launch``."""
        bw = fabric.link_bw
        if self.links <= 0:
            bandwidth_term = self.distance
        else:
            bandwidth_term = self.energy / (self.links * bw) + self.distance
        return (
            max(self.contention / bw, bandwidth_term)
            + fabric.per_depth_cost * self.depth
            + fabric.t_launch * self.launches
        )

    def dominant_term(self, fabric: Fabric = WSE2) -> str:
        """Name of the largest contributor (for analysis/reporting)."""
        bw = fabric.link_bw
        bandwidth = (self.energy / (self.links * bw)
                     if self.links > 0 else 0.0)
        parts = {
            "contention": self.contention / bw,
            "bandwidth": bandwidth,
            "distance": self.distance,
            "depth": fabric.per_depth_cost * self.depth,
            "launch": fabric.t_launch * self.launches,
        }
        return max(parts, key=parts.get)


def validate_positive(p: int, b: int) -> None:
    if p < 1:
        raise ValueError(f"need at least one PE, got P={p}")
    if b < 1:
        raise ValueError(f"need vector length >= 1, got B={b}")


def is_power_of_two(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


def log2i(x: int) -> int:
    if not is_power_of_two(x):
        raise ValueError(f"{x} is not a power of two")
    return x.bit_length() - 1


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


__all__ = [
    "Fabric",
    "FabricTopology",
    "WSE2",
    "TPU_V5E_AXIS",
    "CostTerms",
    "as_topology",
    "slowest_fabric",
    "parse_fabric_topology",
    "FABRIC_PRESETS",
    "validate_positive",
    "is_power_of_two",
    "log2i",
    "ceil_div",
]
