"""Spatial performance model for wafer-scale (and torus-ICI) fabrics.

Implements the paper's Eq. (1):

    T = max(C, E/N + L) + (2*T_R + 1) * D

over the four spatial cost terms

    D  depth       -- longest chain of sequentially dependent messages
    L  distance    -- hops travelled along the critical path
    E  energy      -- total element-hops injected into the fabric
    C  contention  -- max elements received (or sent) by any single PE

with N the number of links usable by the pattern and T_R the ramp
(processor<->router) latency.  All costs are in elements == cycles
(1 element/link/cycle on the WSE).

Two parameterizations are provided:

* ``WSE2`` -- the Cerebras CS-2 constants from the paper (T_R = 2).
* ``TPUv5eAxis`` -- re-parameterization of the same model for a TPU v5e ICI
  axis, used by the TPU collective selector (see DESIGN.md: hardware
  adaptation).  There, "cycles" are nanoseconds, a "link" is an ICI link,
  and T_R models per-hop SerDes/launch latency.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Fabric:
    """Hardware constants that parameterize the spatial model."""

    name: str
    t_r: float          # ramp latency (cycles) each way between PE and router
    store_cost: float   # cycles to store/add one received element
    link_bw: float = 1.0  # elements per cycle per link (WSE: 1)
    multicast: bool = True  # WSE routers replicate; ICI must software-fan-out

    @property
    def per_depth_cost(self) -> float:
        """Cost charged per unit of depth: down-ramp + up-ramp + store."""
        return 2.0 * self.t_r + self.store_cost

    @property
    def hop_pipeline_cost(self) -> float:
        """Pipeline latency added per chain hop: link + ramps + add."""
        return 2.0 * self.t_r + 2.0


#: The paper's CS-2 parameterization (T_R measured to be 2, Sec. 2.2).
WSE2 = Fabric(name="wse2", t_r=2.0, store_cost=1.0)

#: A TPU v5e ICI axis viewed through the same model.  Units: one "element"
#: is one 512-byte ICI flit-group; one "cycle" is the time to push it over
#: one 45 GB/s usable link (~11.4 ns); t_r models the ~1 us per-launch
#: collective-permute latency expressed in those cycles.
TPU_V5E_AXIS = Fabric(name="tpu_v5e_axis", t_r=88.0, store_cost=1.0,
                      multicast=False)


@dataclasses.dataclass(frozen=True)
class CostTerms:
    """Spatial cost decomposition of one collective pattern instance."""

    depth: float
    distance: float
    energy: float
    contention: float
    links: float
    label: str = ""

    def cycles(self, fabric: Fabric = WSE2) -> float:
        """Paper Eq. (1)."""
        if self.links <= 0:
            bandwidth_term = self.distance
        else:
            bandwidth_term = self.energy / self.links + self.distance
        return (
            max(self.contention, bandwidth_term)
            + fabric.per_depth_cost * self.depth
        )

    def dominant_term(self, fabric: Fabric = WSE2) -> str:
        """Name of the largest contributor (for analysis/reporting)."""
        bandwidth = self.energy / self.links if self.links > 0 else 0.0
        parts = {
            "contention": self.contention,
            "bandwidth": bandwidth,
            "distance": self.distance,
            "depth": fabric.per_depth_cost * self.depth,
        }
        return max(parts, key=parts.get)


def validate_positive(p: int, b: int) -> None:
    if p < 1:
        raise ValueError(f"need at least one PE, got P={p}")
    if b < 1:
        raise ValueError(f"need vector length >= 1, got B={b}")


def is_power_of_two(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


def log2i(x: int) -> int:
    if not is_power_of_two(x):
        raise ValueError(f"{x} is not a power of two")
    return x.bit_length() - 1


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


__all__ = [
    "Fabric",
    "WSE2",
    "TPU_V5E_AXIS",
    "CostTerms",
    "validate_positive",
    "is_power_of_two",
    "log2i",
    "ceil_div",
]
