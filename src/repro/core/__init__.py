"""Core library: the paper's performance model, algorithms, and bounds."""

from repro.core.model import CostTerms, Fabric, TPU_V5E_AXIS, WSE2
from repro.core import patterns, schedule
from repro.core.autogen import autogen_tree, compute_tables, t_autogen
from repro.core.lowerbound import compute_lb_energy, t_lower_bound
from repro.core.selector import (best_allreduce, best_reduce,
                                 optimality_ratios, predict_allreduce,
                                 predict_reduce)

__all__ = [
    "CostTerms", "Fabric", "WSE2", "TPU_V5E_AXIS", "patterns", "schedule",
    "autogen_tree", "compute_tables", "t_autogen", "compute_lb_energy",
    "t_lower_bound", "best_allreduce", "best_reduce", "optimality_ratios",
    "predict_allreduce", "predict_reduce",
]
