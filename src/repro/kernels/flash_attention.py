"""Pallas TPU kernel: flash attention with GQA, causal and sliding-window
masks (online softmax; Rabe & Staats / Dao et al., re-tiled for the MXU).

Blocking: grid = (batch*heads, S/bq, S/bk) with the KV dimension sequential
("arbitrary") so the running max/denominator/accumulator scratch carries
across KV steps.  Per-step VMEM working set is

    q tile (bq, d) + k tile (bk, d) + v tile (bk, d) + acc (bq, d) f32

with bq = bk = 128 hardware-aligned MXU tiles by default (d is the model's
head_dim, 64..128).  Causal/window-irrelevant KV blocks are skipped
entirely via ``pl.when`` (halves the FLOPs for causal prefill).

The dry-run model path uses the pure-JAX chunked oracle; this kernel is
the TPU execution path, validated in interpret mode on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, kv_steps: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, m_scr.dtype)
        l_scr[...] = jnp.zeros(l_scr.shape, l_scr.dtype)
        acc_scr[...] = jnp.zeros(acc_scr.shape, acc_scr.dtype)

    q_start = qi * block_q
    kv_start = ki * block_k
    needed = jnp.bool_(True)
    if causal:
        needed &= kv_start <= q_start + block_q - 1
    if window is not None:
        needed &= kv_start + block_k - 1 > q_start - window

    @pl.when(needed)
    def _update():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_ids = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.bool_(True)
        if causal:
            mask &= k_ids <= q_ids
        if window is not None:
            mask &= k_ids > q_ids - window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_scr[...][:, :1]                        # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # rows with no valid key yet keep m = -inf; guard the exp
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(jnp.where(mask, s - safe_m, _NEG_INF))
        corr = jnp.where(jnp.isfinite(m_prev),
                         jnp.exp(m_prev - safe_m), 0.0)   # (bq, 1)
        l_new = l_scr[...][:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ki == kv_steps - 1)
    def _finalize():
        l = l_scr[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jax.Array:
    """[B, H, S, D] x [B, Hkv, S, D] -> [B, H, S, D] attention."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / (d ** 0.5)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)
    kv_steps = s // block_k

    def kv_index(bh, qi, ki):
        return ((bh // h) * hkv + (bh % h) // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_steps=kv_steps)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q, kv_steps),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # running denom
            pltpu.VMEM((block_q, d), jnp.float32),     # accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


__all__ = ["flash_attention", "DEFAULT_BLOCK_Q", "DEFAULT_BLOCK_K"]
