"""Pallas TPU kernel: K-way fused accumulate.

The WSE reduce's compute hot spot is the elementwise add pipeline (one add
per cycle per PE).  On TPU the analogous hot spot in a reduction endpoint
is accumulating K partial vectors: a chain of K-1 binary adds reads
2(K-1)*N and writes (K-1)*N elements of HBM, while a fused K-way add reads
K*N and writes N -- a ~3x traffic cut for K=8.  This kernel performs the
fused accumulate with explicit VMEM tiling.

Layout: ``stacked`` [K, N] -> out [N].  The grid tiles N; each grid step
holds a (K, block_n) tile in VMEM and reduces over K in registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# (K, 512 lanes) tiles: K is small (2..32); 512 f32 lanes = 2 KB rows,
# keeping the tile well under VMEM while filling the 8x128 VPU layout.
DEFAULT_BLOCK_N = 512


def _multi_add_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...].astype(jnp.float32), axis=0).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def multi_add(stacked: jax.Array, *, block_n: int = DEFAULT_BLOCK_N,
              interpret: bool = True) -> jax.Array:
    """Sum K stacked partials: [K, N] -> [N] with fp32 accumulation."""
    k, n = stacked.shape
    block_n = min(block_n, n)
    if n % block_n != 0:
        pad = block_n - n % block_n
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        out = multi_add(stacked, block_n=block_n, interpret=interpret)
        return out[:n]
    grid = (n // block_n,)
    return pl.pallas_call(
        _multi_add_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), stacked.dtype),
        interpret=interpret,
    )(stacked)


__all__ = ["multi_add", "DEFAULT_BLOCK_N"]
