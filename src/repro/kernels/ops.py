"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True off-TPU so the kernels execute (and are
tested) on CPU; on a real TPU backend the compiled kernels run natively.
"""

from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import multi_add as _ma
from repro.kernels import paged_attention as _pa
from repro.kernels import selective_scan as _ss
from repro.kernels.ref import (flash_attention_ref, multi_add_ref,
                               paged_attention_ref, selective_scan_ref)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def multi_add(stacked, *, block_n: int = _ma.DEFAULT_BLOCK_N,
              interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _ma.multi_add(stacked, block_n=block_n, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    block_q: int = _fa.DEFAULT_BLOCK_Q,
                    block_k: int = _fa.DEFAULT_BLOCK_K,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    interpret: bool | None = None):
    """Block-indexed decode attention over a paged KV cache."""
    if interpret is None:
        interpret = _default_interpret()
    return _pa.paged_attention(q, k_pages, v_pages, block_tables, lengths,
                               interpret=interpret)


def selective_scan(dt, x, b, c, a, h0, *,
                   block_d: int = _ss.DEFAULT_BLOCK_D,
                   chunk: int = _ss.DEFAULT_CHUNK,
                   interpret: bool | None = None,
                   trainable: bool = False):
    """Fused Mamba-1 scan.  ``trainable=True`` uses the custom-VJP
    variant whose backward kernel recomputes within chunks from saved
    chunk-boundary states (flash-style)."""
    if interpret is None:
        interpret = _default_interpret()
    if trainable:
        return _ss.selective_scan_trainable(dt, x, b, c, a, h0, block_d,
                                            chunk, interpret)
    return _ss.selective_scan(dt, x, b, c, a, h0, block_d=block_d,
                              chunk=chunk, interpret=interpret)


__all__ = ["multi_add", "flash_attention", "paged_attention",
           "selective_scan", "multi_add_ref", "flash_attention_ref",
           "paged_attention_ref", "selective_scan_ref"]
