"""Pallas fused matmul + ring reduce-scatter.

The tensor-parallel down-projection (and the FSDP boundary GEMM) ends
in a reduce-scatter over the contraction axis: every device holds
``x [M, K_loc]`` and ``w [K_loc, N]`` (K sharded), the full product is
``sum_d x_d @ w_d``, and device ``i`` only needs row block ``i`` of it.
Running the GEMM to completion and then reduce-scattering serializes
MXU time behind wire time.  This kernel interleaves them: the output's
``P`` row blocks are computed one ring step at a time, each block's
partial accumulated into a buffer that rotates downstream between
steps -- so the last GEMM tiles overlap the first wire bytes, the
wafer-scale playbook applied to the TPU ring.

Schedule (device ``d``, ring step ``t = 0..P-1``)::

    acc      <- gemm(x[rows of block (d+1) % P], w)          # t = 0
    for t in 1..P-1:
        acc  <- ppermute(acc, d -> d-1)                      # wire
        acc +<- gemm(x[rows of block (d+t+1) % P], w)        # MXU

The ppermute and the step-``t`` GEMM are data-independent, so the
compiler overlaps them; after ``P-1`` rotations device ``d`` holds
``sum_d' partial_d'[d]`` -- exactly ``lax.psum_scatter(x @ w, axis,
tiled=True)``.

The per-block GEMM is a Pallas tiled matmul (fp32 accumulation,
``interpret=True`` default so it runs everywhere; flip off on real
TPUs).  The oracle lives in ``kernels/ref.py``
(``fused_matmul_rs_ref``); ``matmul_then_rs`` is the unfused gathered
fallback used off-TPU and for shapes the ring cannot tile (M not
divisible by P).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.collectives import shardmap_impl as impl

#: MXU output tiles: multiples of the 128x128 systolic array; trimmed
#: down automatically for the small shapes tests use.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 256


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _matmul_impl(x: jax.Array, w: jax.Array, block_m: int, block_n: int,
                 interpret: bool) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    if m % block_m or n % block_n:
        pm = (-m) % block_m
        pn = (-n) % block_n
        out = _matmul_impl(jnp.pad(x, ((0, pm), (0, 0))),
                           jnp.pad(w, ((0, 0), (0, pn))),
                           block_m, block_n, interpret)
        return out[:m, :n]
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
                  pl.BlockSpec((k, block_n), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w)


# pallas_call has no autodiff rule; the fused path sits inside the
# differentiated train step (TP down-projection), so give the tiled
# GEMM the standard matmul VJP (dense jnp.dot backward -- the backward
# GEMMs get their own fused treatment only if routed through here too).
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _mm(x, w, block_m, block_n, interpret):
    return _matmul_impl(x, w, block_m, block_n, interpret)


def _mm_fwd(x, w, block_m, block_n, interpret):
    return _matmul_impl(x, w, block_m, block_n, interpret), (x, w)


def _mm_bwd(block_m, block_n, interpret, res, g):
    x, w = res
    gf = g.astype(jnp.float32)
    dx = jnp.dot(gf, w.astype(jnp.float32).T,
                 preferred_element_type=jnp.float32).astype(x.dtype)
    dw = jnp.dot(x.astype(jnp.float32).T, gf,
                 preferred_element_type=jnp.float32).astype(w.dtype)
    return dx, dw


_mm.defvjp(_mm_fwd, _mm_bwd)


@functools.partial(jax.jit,
                   static_argnames=("block_m", "block_n", "interpret"))
def matmul_tiled(x: jax.Array, w: jax.Array, *,
                 block_m: int = DEFAULT_BLOCK_M,
                 block_n: int = DEFAULT_BLOCK_N,
                 interpret: bool = True) -> jax.Array:
    """``[M, K] @ [K, N] -> [M, N]`` Pallas tiled matmul, fp32
    accumulation.  The grid tiles M x N; each grid step holds a
    ``(block_m, K)`` x ``(K, block_n)`` operand pair in VMEM."""
    return _mm(x, w, block_m, block_n, interpret)


def fused_matmul_rs(x: jax.Array, w: jax.Array, axis, *,
                    block_m: int = DEFAULT_BLOCK_M,
                    block_n: int = DEFAULT_BLOCK_N,
                    interpret: bool = True) -> jax.Array:
    """Fused ``reduce_scatter(x @ w)`` over ``axis`` (a mesh axis name
    or a row-major-folded tuple), run inside shard_map.

    ``x``: local ``[M, K_loc]``; ``w``: local ``[K_loc, N]``; returns
    ``[M/P, N]`` with device ``i`` holding row block ``i`` of the
    summed product (``lax.psum_scatter(..., tiled=True)`` semantics).
    M must be divisible by the folded axis size."""
    p = impl._axis_size(axis)
    if p == 1:
        return matmul_tiled(x, w, block_m=block_m, block_n=block_n,
                            interpret=interpret)
    m = x.shape[0]
    assert m % p == 0, (m, p)
    mb = m // p
    idx = impl._axis_index(axis)
    down = [(i, (i - 1) % p) for i in range(p)]

    def block_gemm(t: int) -> jax.Array:
        start = ((idx + t + 1) % p) * mb
        xb = lax.dynamic_slice_in_dim(x, start, mb, axis=0)
        return matmul_tiled(xb, w, block_m=block_m, block_n=block_n,
                            interpret=interpret)

    acc = block_gemm(0)
    for t in range(1, p):
        acc = lax.ppermute(acc, axis, down)
        acc = acc + block_gemm(t)
    return acc


def matmul_then_rs(x: jax.Array, w: jax.Array, axis) -> jax.Array:
    """Unfused gathered fallback: full local GEMM (fp32 accumulation),
    then the native reduce-scatter.  Bit-for-bit the semantics of
    :func:`fused_matmul_rs`, with MXU and wire time serialized."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32).astype(x.dtype)
    if impl._axis_size(axis) == 1:
        return y
    return lax.psum_scatter(y, axis, scatter_dimension=0, tiled=True)


__all__ = ["fused_matmul_rs", "matmul_then_rs", "matmul_tiled",
           "DEFAULT_BLOCK_M", "DEFAULT_BLOCK_N"]
