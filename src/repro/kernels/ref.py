"""Pure-jnp oracles for the Pallas kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def multi_add_ref(stacked: jax.Array) -> jax.Array:
    """K-way fused accumulate oracle: sum over the leading axis.

    ``stacked``: [K, N] partials -> [N].  Accumulation in float32.
    """
    return jnp.sum(stacked.astype(jnp.float32), axis=0).astype(stacked.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """Attention oracle: [B, H, S, D] x [B, Hkv, S, D] -> [B, H, S, D].

    Supports GQA (H a multiple of Hkv), causal masking, and an optional
    sliding window (RecurrentGemma-style local attention).
    """
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    qf = q.astype(jnp.float32) / jnp.sqrt(d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, block_tables: jax.Array,
                        lengths: jax.Array) -> jax.Array:
    """Block-indexed decode-attention oracle (paged KV cache).

    ``q``: [B, H, D] single-position queries; ``k_pages``/``v_pages``:
    [N, bs, Hkv, D] physical block pool; ``block_tables``: [B, M] int32
    per-request block ids (logical order); ``lengths``: [B] int32 valid
    context per request.  Returns [B, H, D].  Supports GQA.
    """
    n, bs, hkv, d = k_pages.shape
    b, h, _ = q.shape
    m = block_tables.shape[1]
    group = h // hkv
    idx = (block_tables[:, :, None] * bs
           + jnp.arange(bs)[None, None, :]).reshape(b, m * bs)
    k = k_pages.reshape(n * bs, hkv, d)[idx]          # [B, S, Hkv, D]
    v = v_pages.reshape(n * bs, hkv, d)[idx]
    kf = jnp.repeat(jnp.moveaxis(k, 1, 2).astype(jnp.float32), group,
                    axis=1)                            # [B, H, S, D]
    vf = jnp.repeat(jnp.moveaxis(v, 1, 2).astype(jnp.float32), group,
                    axis=1)
    qf = q.astype(jnp.float32) / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("bhd,bhkd->bhk", qf, kf)
    mask = jnp.arange(m * bs)[None, None, :] < lengths[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bhkd->bhd", probs, vf)
    return out.astype(q.dtype)


def fused_matmul_rs_ref(x_parts: jax.Array, w_parts: jax.Array
                        ) -> jax.Array:
    """Fused matmul + reduce-scatter oracle.

    ``x_parts``: [P, M, K_loc] per-device activations; ``w_parts``:
    [P, K_loc, N] per-device weight shards (K sharded over the axis).
    Returns [P, M/P, N]: slot ``i`` is device ``i``'s row block of the
    summed product -- ``lax.psum_scatter(x @ w, axis, tiled=True)``
    semantics.  Accumulation in float32.
    """
    p, m, _ = x_parts.shape
    n = w_parts.shape[-1]
    full = jnp.einsum("pmk,pkn->mn", x_parts.astype(jnp.float32),
                      w_parts.astype(jnp.float32))
    return full.reshape(p, m // p, n).astype(x_parts.dtype)


def selective_scan_ref(dt: jax.Array, x: jax.Array, b: jax.Array,
                       c: jax.Array, a: jax.Array, h0: jax.Array):
    """Oracle for the fused Mamba scan: plain sequential recurrence.

    dt/x: [B, S, D]; b/c: [B, S, N]; a: [D, N]; h0: [B, D, N].
    """
    dt32 = dt.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    c32 = c.astype(jnp.float32)
    a32 = a.astype(jnp.float32)

    def step(h, inputs):
        dt_t, x_t, b_t, c_t = inputs
        a_bar = jnp.exp(dt_t[:, :, None] * a32)          # [B, D, N]
        b_bar = (dt_t * x_t)[:, :, None] * b_t[:, None, :]
        h = a_bar * h + b_bar
        y_t = jnp.sum(h * c_t[:, None, :], axis=-1)      # [B, D]
        return h, y_t

    xs = (jnp.moveaxis(dt32, 1, 0), jnp.moveaxis(x32, 1, 0),
          jnp.moveaxis(b32, 1, 0), jnp.moveaxis(c32, 1, 0))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final


__all__ = ["multi_add_ref", "flash_attention_ref", "paged_attention_ref",
           "fused_matmul_rs_ref", "selective_scan_ref"]
