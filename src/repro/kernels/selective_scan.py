"""Pallas TPU kernel: fused Mamba-1 selective scan.

The dry-run shows the pure-JAX chunked scan materializing the state
expansion (a_bar/b_bar broadcasts, [B, S, d_inner, N] f32) in HBM --
~2.8 TB of traffic per layer per device on falcon-mamba train_4k, 175x
the useful activation bytes (EXPERIMENTS.md §Perf).  The CUDA reference
fuses the whole recurrence in one kernel; this is the TPU-native
equivalent: the state h lives in a VMEM scratch tile and the recurrence

    h_t = exp(dt_t * A) * h_t-1 + (dt_t * B_t) x_t
    y_t = <h_t, C_t> + D * x_t

streams over sequence chunks with only the layer inputs/outputs touching
HBM.  Blocking: grid = (B, d_inner / block_d, S / chunk) with the
sequence dimension sequential; per-step VMEM = dt/x tiles (chunk,
block_d) + B/C tiles (chunk, N) + h scratch (block_d, N).

``h0`` enters via HBM and the final state is written back, so decode
and prefill reuse the same kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_D = 256
DEFAULT_CHUNK = 128


def _scan_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref,
                 y_ref, hout_ref, h_scr, *, chunk: int, s_steps: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)            # (block_d, N)

    def step(t, carry):
        h = carry                                  # (block_d, N)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)      # (block_d,)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)        # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        a_bar = jnp.exp(dt_t[:, None] * a)              # (block_d, N)
        b_bar = (dt_t * x_t)[:, None] * b_t[None, :]
        h = a_bar * h + b_bar
        y_t = jnp.sum(h * c_t[None, :], axis=1)         # (block_d,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(si == s_steps - 1)
    def _final():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "chunk",
                                             "interpret"))
def selective_scan(dt: jax.Array, x: jax.Array, b: jax.Array,
                   c: jax.Array, a: jax.Array, h0: jax.Array, *,
                   block_d: int = DEFAULT_BLOCK_D,
                   chunk: int = DEFAULT_CHUNK,
                   interpret: bool = True):
    """dt/x: [B, S, D]; b/c: [B, S, N]; a: [D, N]; h0: [B, D, N].
    Returns (y [B, S, D] fp32-accurate in x.dtype, h_final [B, D, N])."""
    bsz, s, d = x.shape
    n = a.shape[1]
    block_d = min(block_d, d)
    chunk = min(chunk, s)
    assert d % block_d == 0 and s % chunk == 0, (d, block_d, s, chunk)
    s_steps = s // chunk
    grid = (bsz, d // block_d, s_steps)

    kernel = functools.partial(_scan_kernel, chunk=chunk, s_steps=s_steps)
    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((block_d, n), lambda i, j, k: (j, 0)),
            pl.BlockSpec((1, block_d, n), lambda i, j, k: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, block_d, n), lambda i, j, k: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, b, c, a, h0)
    return y, h_final


# ---------------------------------------------------------------------- #
# backward kernel (flash-style): the forward saves only chunk-boundary
# states; the backward recomputes h within each chunk (forward sub-pass
# in VMEM) and then runs the reverse adjoint recurrence
#
#     dh_t = dy_t c_t^T + a_{t+1} * dh_{t+1}
#     ddt_t = sum_n [ (dh_t*h_{t-1}*a_t) A + dh_t b_t x_t ]
#     dx_t  = sum_n dh_t dt_t b_t ;  db_t = sum_d dh_t dt_t x_t
#     dc_t  = sum_d h_t dy_t     ;  dA   = sum_t (dh_t*h_{t-1}*a_t) dt_t
#
# Per-D-block partials of db/dc (reduced over D) are emitted into a
# [B, n_dblocks, S, N] buffer and summed outside the kernel.
# ---------------------------------------------------------------------- #
def _scan_fwd_ckpt_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref,
                          y_ref, hout_ref, hck_ref, h_scr, *,
                          chunk: int, s_steps: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    hck_ref[0, 0] = h_scr[...]          # state at the chunk START
    a = a_ref[...].astype(jnp.float32)

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        h = jnp.exp(dt_t[:, None] * a) * h \
            + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1).astype(
            y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(si == s_steps - 1)
    def _final():
        hout_ref[0] = h.astype(hout_ref.dtype)


def _scan_bwd_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, hck_ref, dy_ref,
                     dhf_ref, ddt_ref, dx_ref, db_ref, dc_ref, da_ref,
                     dh0_ref, dh_scr, h_hist, *, chunk: int, s_steps: int):
    si = pl.program_id(2)            # reversed: si=0 is the LAST chunk

    @pl.when(si == 0)
    def _init():
        dh_scr[...] = dhf_ref[0].astype(jnp.float32)
        da_ref[0] = jnp.zeros_like(da_ref[0])

    a = a_ref[...].astype(jnp.float32)

    # forward recompute within the chunk, storing h history in VMEM
    def fwd(t, h):
        h_hist[t] = h                # h_{t-1} (state BEFORE step t)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        return (jnp.exp(dt_t[:, None] * a) * h
                + (dt_t * x_t)[:, None] * b_t[None, :])

    h_start = hck_ref[0, 0]
    _ = jax.lax.fori_loop(0, chunk, fwd, h_start)

    def bwd(i, carry):
        dh, da_acc = carry
        t = chunk - 1 - i
        dt_t = dt_ref[0, t, :].astype(jnp.float32)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        dy_t = dy_ref[0, t, :].astype(jnp.float32)
        h_prev = h_hist[t]
        a_t = jnp.exp(dt_t[:, None] * a)
        h_t = a_t * h_prev + (dt_t * x_t)[:, None] * b_t[None, :]
        # dh_t := contribution from y_t + carried adjoint
        dh_t = dy_t[:, None] * c_t[None, :] + dh
        dc_ref[0, 0, t, :] = jnp.sum(h_t * dy_t[:, None], axis=0).astype(
            dc_ref.dtype)
        g_a = dh_t * h_prev * a_t            # d/d(log a) * a
        ddt_ref[0, t, :] = (jnp.sum(g_a * a, axis=1)
                            + jnp.sum(dh_t * b_t[None, :], axis=1) * x_t
                            ).astype(ddt_ref.dtype)
        dx_ref[0, t, :] = (jnp.sum(dh_t * b_t[None, :], axis=1) * dt_t
                           ).astype(dx_ref.dtype)
        db_ref[0, 0, t, :] = jnp.sum(dh_t * (dt_t * x_t)[:, None],
                                     axis=0).astype(db_ref.dtype)
        da_acc = da_acc + g_a * dt_t[:, None]
        dh = a_t * dh_t                      # adjoint to h_{t-1}
        return dh, da_acc

    dh, da_acc = jax.lax.fori_loop(
        0, chunk, bwd, (dh_scr[...], da_ref[0].astype(jnp.float32)))
    dh_scr[...] = dh
    da_ref[0] = da_acc.astype(da_ref.dtype)

    @pl.when(si == s_steps - 1)
    def _final():
        dh0_ref[0] = dh.astype(dh0_ref.dtype)


def _fwd_with_ckpt(dt, x, b, c, a, h0, block_d, chunk, interpret):
    bsz, s, d = x.shape
    n = a.shape[1]
    s_steps = s // chunk
    grid = (bsz, d // block_d, s_steps)
    kernel = functools.partial(_scan_fwd_ckpt_kernel, chunk=chunk,
                               s_steps=s_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((block_d, n), lambda i, j, k: (j, 0)),
            pl.BlockSpec((1, block_d, n), lambda i, j, k: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, block_d, n), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((1, 1, block_d, n), lambda i, j, k: (i, k, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
            jax.ShapeDtypeStruct((bsz, s_steps, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, b, c, a, h0)


def _bwd_call(dt, x, b, c, a, hck, dy, dh_final, block_d, chunk,
              interpret):
    bsz, s, d = x.shape
    n = a.shape[1]
    s_steps = s // chunk
    nb = d // block_d
    grid = (bsz, nb, s_steps)
    kernel = functools.partial(_scan_bwd_kernel, chunk=chunk,
                               s_steps=s_steps)
    rev = lambda k, ss=s_steps: ss - 1 - k
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d),
                         lambda i, j, k: (i, rev(k), j)),
            pl.BlockSpec((1, chunk, block_d),
                         lambda i, j, k: (i, rev(k), j)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, rev(k), 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, k: (i, rev(k), 0)),
            pl.BlockSpec((block_d, n), lambda i, j, k: (j, 0)),
            pl.BlockSpec((1, 1, block_d, n),
                         lambda i, j, k: (i, rev(k), j, 0)),
            pl.BlockSpec((1, chunk, block_d),
                         lambda i, j, k: (i, rev(k), j)),
            pl.BlockSpec((1, block_d, n), lambda i, j, k: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d),
                         lambda i, j, k: (i, rev(k), j)),
            pl.BlockSpec((1, chunk, block_d),
                         lambda i, j, k: (i, rev(k), j)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda i, j, k: (i, j, rev(k), 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda i, j, k: (i, j, rev(k), 0)),
            pl.BlockSpec((1, block_d, n), lambda i, j, k: (i, j, 0)),
            pl.BlockSpec((1, block_d, n), lambda i, j, k: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),   # ddt
            jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),   # dx
            jax.ShapeDtypeStruct((bsz, nb, s, n), jnp.float32),  # db part
            jax.ShapeDtypeStruct((bsz, nb, s, n), jnp.float32),  # dc part
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),   # dA (per b)
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),   # dh0
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32),
                        pltpu.VMEM((chunk, block_d, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, b, c, a, hck, dy, dh_final)
    ddt, dx, db_p, dc_p, da_b, dh0 = outs
    return (ddt, dx, db_p.sum(axis=1), dc_p.sum(axis=1),
            da_b.sum(axis=0), dh0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def selective_scan_trainable(dt, x, b, c, a, h0, block_d=DEFAULT_BLOCK_D,
                             chunk=DEFAULT_CHUNK, interpret=True):
    """Differentiable fused scan: forward saves only chunk-boundary
    states; backward recomputes within chunks (flash-style)."""
    y, h_final, _ = _fwd_with_ckpt(dt, x, b, c, a, h0, block_d, chunk,
                                   interpret)
    return y, h_final


def _ss_fwd(dt, x, b, c, a, h0, block_d, chunk, interpret):
    y, h_final, hck = _fwd_with_ckpt(dt, x, b, c, a, h0, block_d, chunk,
                                     interpret)
    return (y, h_final), (dt, x, b, c, a, hck)


def _ss_bwd(block_d, chunk, interpret, res, grads):
    dt, x, b, c, a, hck = res
    dy, dh_final = grads
    ddt, dx, db, dc, da, dh0 = _bwd_call(
        dt, x, b, c, a, hck, dy.astype(jnp.float32),
        dh_final.astype(jnp.float32), block_d, chunk, interpret)
    return (ddt.astype(dt.dtype), dx.astype(x.dtype), db.astype(b.dtype),
            dc.astype(c.dtype), da.astype(a.dtype), dh0)


selective_scan_trainable.defvjp(_ss_fwd, _ss_bwd)


__all__ = ["selective_scan", "selective_scan_trainable",
           "DEFAULT_BLOCK_D", "DEFAULT_CHUNK"]
