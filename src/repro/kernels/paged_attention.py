"""Pallas TPU kernel: block-indexed decode attention over a paged KV
cache (vLLM-style PagedAttention, retargeted to the repo's serving
subsystem).

The physical cache is a pool of fixed-size blocks ``[N, bs, Hkv, D]``;
each request owns a *block table* of pool indices.  The kernel never
materializes the gathered per-request context: the block table and the
per-request lengths are **scalar-prefetched** so the BlockSpec index map
can DMA exactly the blocks a request references, one block per grid
step, with an online-softmax accumulator carried in VMEM scratch.

Blocking: grid = (B, Hkv, M) with M = blocks-per-request sequential so
the running max/denominator/accumulator scratch carries across a
request's blocks.  Per-step VMEM working set is

    q tile (group, d) + k block (bs, d) + v block (bs, d) + acc f32

where group = H/Hkv (the GQA query group that shares one KV head).
Blocks past a request's length are skipped entirely via ``pl.when``
(short requests in a long-max-len batch cost only their own blocks).

The pure-jnp oracle is ``repro.kernels.ref.paged_attention_ref``; the
serving decode path (`repro.models.paged`) uses the gathered-jnp
fallback off-TPU and this kernel on TPU (``ops.paged_attention``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = float("-inf")


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, block_size: int,
                  blocks_per_seq: int):
    b = pl.program_id(0)
    bi = pl.program_id(2)

    @pl.when(bi == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, m_scr.dtype)
        l_scr[...] = jnp.zeros(l_scr.shape, l_scr.dtype)
        acc_scr[...] = jnp.zeros(acc_scr.shape, acc_scr.dtype)

    length = lengths_ref[b]

    @pl.when(bi * block_size < length)
    def _update():
        q = q_ref[0].astype(jnp.float32) * scale       # (group, d)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, d)
        v = v_ref[0, :, 0].astype(jnp.float32)         # (bs, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        k_ids = bi * block_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = k_ids < length
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_scr[...][:, :1]                     # (group, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(jnp.where(mask, s - safe_m, _NEG_INF))
        corr = jnp.where(jnp.isfinite(m_prev),
                         jnp.exp(m_prev - safe_m), 0.0)
        l_new = l_scr[...][:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p, v)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(bi == blocks_per_seq - 1)
    def _finalize():
        l = l_scr[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """[B, H, D] x pool [N, bs, Hkv, D] block-indexed decode attention.

    ``block_tables``: [B, M] int32 pool indices in logical order;
    ``lengths``: [B] int32 valid tokens per request.  Returns [B, H, D].
    """
    b, h, d = q.shape
    n, bs, hkv, dk = k_pages.shape
    assert d == dk and h % hkv == 0, (q.shape, k_pages.shape)
    group = h // hkv
    m = block_tables.shape[1]
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_paged_kernel, scale=scale, block_size=bs,
                               blocks_per_seq=m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, m),
        in_specs=[
            pl.BlockSpec((1, group, d),
                         lambda bb, hh, ii, tables, lens: (bb, hh, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda bb, hh, ii, tables, lens:
                         (tables[bb, ii], 0, hh, 0)),
            pl.BlockSpec((1, bs, 1, d),
                         lambda bb, hh, ii, tables, lens:
                         (tables[bb, ii], 0, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, d),
                               lambda bb, hh, ii, tables, lens: (bb, hh, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 128), jnp.float32),   # running max
            pltpu.VMEM((group, 128), jnp.float32),   # running denom
            pltpu.VMEM((group, d), jnp.float32),     # accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)


__all__ = ["paged_attention"]
