"""AdamW with sharded states + LR schedules (cosine, WSD, linear).

Pure JAX (no optax dependency).  Optimizer state inherits the parameter
sharding tree, so FSDP keeps m/v sharded across the data axis -- the
ZeRO-style memory split the big configs need.

WSD (warmup-stable-decay) is MiniCPM's schedule (arXiv:2404.06395) and is
selected by that architecture's training recipe.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array
    master: Any = None   # fp32 master copy (mixed-precision training)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_weights: bool = False   # fp32 master params (bf16 training);
                                   # masters inherit the param sharding
    schedule: str = "cosine"       # cosine | wsd | linear | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_fraction: float = 0.1    # WSD: fraction of steps in decay phase
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    if cfg.schedule == "cosine":
        mult = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        decay_start = 1.0 - cfg.decay_fraction
        in_decay = jnp.clip((t - decay_start) / cfg.decay_fraction, 0.0, 1.0)
        mult = 1.0 - (1.0 - cfg.min_lr_ratio) * in_decay
    elif cfg.schedule == "linear":
        mult = 1.0 - (1.0 - cfg.min_lr_ratio) * t
    else:
        mult = jnp.asarray(1.0)
    return cfg.lr * warm * mult


def init_state(params, master_weights: bool = False) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    master = None
    if master_weights:
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32),
                      master=master)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState
                  ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)
    use_master = state.master is not None

    def upd(p, g, m, v, w32):
        """p: model-dtype param; w32: fp32 master (== p when disabled)."""
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * w32
        w32_new = w32 - lr * step
        return w32_new.astype(p.dtype), m2, v2, w32_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_w = (jax.tree.leaves(state.master) if use_master
              else [p.astype(jnp.float32) for p in flat_p])
    new_p, new_m, new_v, new_w = [], [], [], []
    for p, g, m, v, w in zip(flat_p, flat_g, flat_m, flat_v, flat_w):
        p2, m2, v2, w2 = upd(p, g, m, v, w)
        new_p.append(p2), new_m.append(m2), new_v.append(v2)
        new_w.append(w2)
    params2 = jax.tree.unflatten(treedef, new_p)
    state2 = AdamWState(
        mu=jax.tree.unflatten(treedef, new_m),
        nu=jax.tree.unflatten(treedef, new_v), count=count,
        master=jax.tree.unflatten(treedef, new_w) if use_master else None)
    return params2, state2, {"grad_norm": gnorm, "lr": lr}


__all__ = ["AdamWConfig", "AdamWState", "init_state", "apply_updates",
           "lr_at", "global_norm"]
