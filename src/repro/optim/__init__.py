from repro.optim.adamw import (AdamWConfig, AdamWState, apply_updates,
                               global_norm, init_state, lr_at)

__all__ = ["AdamWConfig", "AdamWState", "apply_updates", "global_norm",
           "init_state", "lr_at"]
