"""Parallelism rules: DP / FSDP / TP / EP / SP as PartitionSpec trees.

Mesh axes: ``("data", "model")`` single-pod (16 x 16) and
``("pod", "data", "model")`` multi-pod (2 x 16 x 16).

* TP ("model"): attention heads / FFN hidden / experts / SSM inner dim.
* FSDP ("data"): every parameter's non-TP matrix dim is additionally
  sharded over the data axis (ZeRO-3 style); optimizer states inherit.
* DP ("pod","data"): batch dims of activations; gradients reduce over
  these axes (reduce-scatter under FSDP; the paper's two-phase hierarchy
  governs the pod-level stage -- see repro.collectives).
* EP ("model"): MoE expert dim.
* Replicated: norms, small vectors.

Non-divisible dims (e.g. 56 heads over 16-way model axis, odd vocabs)
are allowed: GSPMD pads.  The padding waste is visible in the roofline's
MODEL_FLOPS/HLO_FLOPs ratio and is one of the hillclimb levers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    fsdp: bool = True              # shard params over the data axis too
    data_axes: Tuple[str, ...] = ("data",)   # DP axes for activations
    model_axis: str = "model"
    fsdp_axis: Optional[str] = "data"
    axis_sizes: Tuple[Tuple[str, int], ...] = (("data", 1), ("model", 1))
    # hillclimb levers
    shard_vocab_model: bool = True
    replicate_small_below: int = 1 << 16  # params smaller than this stay
                                          # replicated

    def axis_size(self, axis) -> int:
        sizes = dict(self.axis_sizes)
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= sizes.get(a, 1)
            return n
        return sizes.get(axis, 1)

    def divides(self, axis, dim: int) -> bool:
        n = self.axis_size(axis)
        return n > 0 and dim % n == 0

    def grad_sync_axes(self) -> Tuple[str, ...]:
        """The DP axes an explicit gradient sync must reduce over --
        the axis tuple ``GradSyncConfig`` / the collective planner
        consume (outermost first, size-1 axes dropped)."""
        return tuple(a for a in self.data_axes if self.axis_size(a) > 1)


def for_mesh(mesh: Mesh, fsdp: bool = True) -> ShardingPolicy:
    axes = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    sizes = tuple(zip(mesh.axis_names, mesh.devices.shape))
    return ShardingPolicy(fsdp=fsdp, data_axes=data_axes,
                          fsdp_axis="data" if fsdp else None,
                          axis_sizes=sizes)


def grad_sync_axes_for_mesh(mesh: Mesh) -> Tuple[str, ...]:
    """DP axis tuple a mesh implies for explicit gradient sync."""
    return for_mesh(mesh).grad_sync_axes()


# last-key -> spec over the *trailing* dims (leading stacked dims -> None)
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "embed": ("model", "fsdp"),
    "head": ("fsdp", "model"),
    "wq": ("fsdp", "model"), "wk": ("fsdp", "model"), "wv": ("fsdp", "model"),
    "wo": ("model", "fsdp"),
    "x_wq": ("fsdp", "model"), "x_wk": ("fsdp", "model"),
    "x_wv": ("fsdp", "model"), "x_wo": ("model", "fsdp"),
    "wg": ("fsdp", "model"), "wu": ("fsdp", "model"), "wd": ("model", "fsdp"),
    # router stays replicated: the shard_map EP path consumes it whole
    # (3.7 MB on arctic -- negligible)
    "eg": ("model", "fsdp", None), "eu": ("model", "fsdp", None),
    "ed": ("model", None, "fsdp"),
    "in_proj": ("fsdp", "model"),
    "conv_w": (None, "model"),
    "x_proj": ("model", None),
    "dt_w": (None, "model"),
    "dt_b": ("model",),
    "a_log": ("model", None),
    "d_skip": ("model",),
    "out_proj": ("model", "fsdp"),
    "w_x": ("fsdp", "model"), "w_y": ("fsdp", "model"),
    "w_a": ("model", None, None), "w_i": ("model", None, None),
    "lam": ("model",),
    "out": ("model", "fsdp"),
}


def _resolve(axis: Optional[str], policy: ShardingPolicy) -> Optional[str]:
    if axis == "fsdp":
        return policy.fsdp_axis if policy.fsdp else None
    if axis == "model":
        return policy.model_axis
    return axis


def spec_for_param(path: Tuple[Any, ...], shape: Tuple[int, ...],
                   policy: ShardingPolicy) -> P:
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = str(keys[-1]) if keys else ""
    size = 1
    for s in shape:
        size *= s
    rule = _PARAM_RULES.get(name)
    if rule is None or size < policy.replicate_small_below:
        return P()
    if name == "embed" and not policy.shard_vocab_model:
        rule = (None, "fsdp")
    trailing = list(_resolve(a, policy) for a in rule)
    lead = [None] * (len(shape) - len(trailing))
    if len(trailing) > len(shape):     # e.g. vectors in reduced configs
        trailing = trailing[-len(shape):]
        lead = []
    dims = lead + trailing
    # divisibility: jit input shardings must divide evenly.  Drop axes
    # that don't; for vocab-carrying params try combining remaining axes
    # on the d_model dim instead (odd vocabs: minicpm, whisper).
    for i, ax in enumerate(dims):
        if ax is not None and not policy.divides(ax, shape[i]):
            dims[i] = None
            if name in ("embed", "head"):
                other = 1 - (i - len(lead))  # the non-vocab trailing dim
                j = len(lead) + other
                combo = tuple(a for a in (dims[j], ax) if a is not None)
                flat: list = []
                for a in combo:
                    flat.extend(a if isinstance(a, tuple) else (a,))
                combo = tuple(dict.fromkeys(flat))
                if combo and policy.divides(combo, shape[j]):
                    dims[j] = combo if len(combo) > 1 else combo[0]
    return P(*dims)


def param_sharding_tree(params_or_specs, mesh: Mesh,
                        policy: Optional[ShardingPolicy] = None):
    """Map a params pytree (arrays or ShapeDtypeStructs) to NamedShardings."""
    if policy is None:
        policy = for_mesh(mesh)

    def fn(path, leaf):
        spec = spec_for_param(path, leaf.shape, policy)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(fn, params_or_specs)


# ---------------------------------------------------------------------- #
# activation / batch / cache specs
# ---------------------------------------------------------------------- #
def _dp_spec(policy: ShardingPolicy, batch_dim: int):
    """Largest prefix of the DP axes that divides the batch dim."""
    dp = policy.data_axes
    while dp and not policy.divides(dp, batch_dim):
        dp = dp[1:] if policy.divides(dp[1:], batch_dim) else dp[:-1]
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def batch_sharding_specs(policy: ShardingPolicy, batch_shapes: Dict[str, Any]
                         ) -> Dict[str, P]:
    """P(dp, None, ...) per batch entry, dropping DP when indivisible
    (e.g. the global_batch=1 long_500k cell)."""
    out: Dict[str, P] = {}
    for k, v in batch_shapes.items():
        shape = v.shape
        dp = _dp_spec(policy, shape[0]) if shape else None
        out[k] = P(dp, *([None] * (len(shape) - 1)))
    return out


def labels_spec(policy: ShardingPolicy) -> P:
    dp = policy.data_axes
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    return P(dp_spec, None)


def _kv_cache_spec(policy: ShardingPolicy, shape) -> P:
    """[L, B, S, KV, hd]: prefer kv-head TP; fall back to sequence
    sharding (SP) when kv heads don't divide the model axis."""
    m = policy.model_axis
    _, b, s, kv, _ = shape
    dp = _dp_spec(policy, b)
    if policy.divides(m, kv):
        return P(None, dp, None, m, None)
    if policy.divides(m, s):
        return P(None, dp, m, None, None)
    return P(None, dp, None, None, None)


def cache_specs(cfg: ArchConfig, policy: ShardingPolicy, cache_shapes):
    """PartitionSpecs matching an init_cache pytree (shapes required for
    divisibility decisions)."""
    m = policy.model_axis
    specs: Dict[str, P] = {}
    for key, leaf in cache_shapes.items():
        shape = leaf.shape
        if key == "pos":
            specs[key] = P()
        elif key in ("k", "v", "enc_k", "enc_v"):
            specs[key] = _kv_cache_spec(policy, shape)
        elif key == "conv":       # [L, B, K-1, di]
            dp = _dp_spec(policy, shape[1])
            mm = m if policy.divides(m, shape[3]) else None
            specs[key] = P(None, dp, None, mm)
        elif key == "h":           # ssm [L,B,di,N] / hybrid [L,B,lru]
            dp = _dp_spec(policy, shape[1])
            mm = m if policy.divides(m, shape[2]) else None
            specs[key] = P(*((None, dp, mm) + (None,) * (len(shape) - 3)))
        else:
            specs[key] = P(*([None] * len(shape)))
    return specs


def logits_spec(policy: ShardingPolicy) -> P:
    dp = policy.data_axes
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    return P(dp_spec, None, policy.model_axis)


__all__ = [
    "ShardingPolicy", "for_mesh", "grad_sync_axes_for_mesh",
    "spec_for_param", "param_sharding_tree",
    "batch_specs", "labels_spec", "cache_specs", "logits_spec",
]
