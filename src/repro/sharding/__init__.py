from repro.sharding.rules import (ShardingPolicy, batch_sharding_specs,
                                  cache_specs, for_mesh, labels_spec,
                                  logits_spec, param_sharding_tree,
                                  spec_for_param)

__all__ = ["ShardingPolicy", "batch_sharding_specs", "cache_specs",
           "for_mesh", "labels_spec", "logits_spec", "param_sharding_tree",
           "spec_for_param"]
