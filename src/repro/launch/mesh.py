"""Production mesh definitions.

A TPU v5e pod is modeled as a 16 x 16 = 256-chip (data, model) mesh; the
multi-pod deployment adds a leading "pod" axis (2 x 16 x 16 = 512 chips).
Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Small mesh for CPU integration tests (8 virtual devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


__all__ = ["make_production_mesh", "make_debug_mesh"]
