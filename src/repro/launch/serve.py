"""Serving CLI: a thin driver over ``repro.serving`` (paged KV cache +
true continuous batching; CPU-scale here, the dry-run proves the
production shapes).

Requests arrive with different prompts and lengths; the scheduler
admits and retires them *every decode step* -- a short request frees
its slot mid-flight and a queued request takes it over while longer
requests keep decoding.  With ``--dp`` the slot rows are striped over
all local devices and per-shard sampled tokens are assembled with the
CollectiveEngine's cached model-driven allgather, so serve traffic
exercises the same dispatch layer as gradient sync.

The legacy names (``BatchedServer``, ``Request``) are the serving
subsystem's classes re-exported; the old static wave-batcher is gone.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.models.frontend import vision_patches
from repro.obs import cli as obs_cli
from repro.serving import (ContinuousBatchingServer as BatchedServer,
                           Request, SamplingParams)
from repro.serving.telemetry import ttft_low_confidence


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="content-addressed CoW sharing of prompt-prefix "
                         "KV blocks across requests (--no-prefix-cache "
                         "recomputes every prompt)")
    ap.add_argument("--shared-prompts", type=int, default=0, metavar="K",
                    help="draw each request's prompt prefix from K shared "
                         "system prompts (0 = fully distinct prompts); "
                         "exercises the prefix cache")
    ap.add_argument("--dp", action="store_true",
                    help="stripe the slot rows over all local devices "
                         "and route token sync through the "
                         "CollectiveEngine")
    obs_cli.add_obs_args(ap)
    args = ap.parse_args()
    obs_cli.begin(args.trace, args.obs_report, args.metrics_out)

    cfg = get_config(args.arch).reduced()
    from repro.models import supports_paged
    if not supports_paged(cfg):
        ap.error(
            f"--arch {args.arch} (family {cfg.family!r}) is not servable "
            f"yet: the paged KV cache covers dense/moe decoder families "
            f"(constant-state families keep the dense training cache)")
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = None
    batch = args.batch
    if args.dp:
        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev,), ("data",))
        if batch % ndev:
            batch += -batch % ndev
            print(f"[serve] rounding batch to {batch} "
                  f"(dp axis = {ndev} devices)")
    max_len = args.prompt_len + args.new_tokens + cfg.frontend_tokens + \
        args.block_size
    server = BatchedServer(cfg, params, batch, max_len=max_len, mesh=mesh,
                           block_size=args.block_size,
                           prefill_chunk=args.prefill_chunk,
                           top_k=args.top_k,
                           prefix_cache=args.prefix_cache)
    rng = np.random.default_rng(0)
    shared = [rng.integers(0, cfg.vocab_size,
                           size=max(args.prompt_len - 4, 1)).astype(np.int32)
              for _ in range(args.shared_prompts)]
    t0 = time.time()
    for rid in range(args.requests):
        soft = None
        if cfg.frontend == "vision":
            soft = vision_patches(jax.random.PRNGKey(rid), cfg, 1)
        if shared:
            # shared system prompt + short per-request suffix
            prompt = np.concatenate(
                [shared[rid % len(shared)],
                 rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)])
        else:
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=args.prompt_len).astype(np.int32)
        server.submit(Request(
            rid=rid,
            prompt=prompt,
            max_new_tokens=args.new_tokens,
            sampling=SamplingParams(temperature=args.temperature),
            soft_emb=soft))
    results = server.run()
    dt = time.time() - t0
    snap = server.snapshot()
    total = sum(len(v) for v in results.values())
    print(f"[serve] {len(results)} requests, {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s)")
    lc = (f" (low confidence, n={snap.ttft_samples})"
          if ttft_low_confidence(snap) else "")
    print(f"[serve] ttft p50={snap.ttft_p50_ms:.0f}ms "
          f"p99={snap.ttft_p99_ms:.0f}ms{lc} | decode steps "
          f"{snap.decode_steps} | prefill chunks {snap.prefill_chunks} | "
          f"preemptions {snap.preemptions} | peak kv occupancy "
          f"{snap.kv_peak_occupancy:.2f}")
    print(f"[serve] prefix cache: "
          f"{'on' if args.prefix_cache else 'off'} | prefill tokens "
          f"computed {snap.prefill_tokens_computed} | cached "
          f"{snap.cached_prefix_tokens} "
          f"({snap.cached_token_fraction:.0%}) | evictions "
          f"{snap.prefix_evictions} | kv blocks live "
          f"{snap.kv_blocks_live} / evictable {snap.kv_blocks_evictable}")
    for rid in sorted(results)[:3]:
        print(f"  req {rid}: {results[rid][:8]}...")
    if mesh is not None:
        with mesh:
            obs_cli.finish(args.trace, args.obs_report, args.metrics_out,
                           mesh=mesh, telemetry_snapshot=snap,
                           label="serve")
    else:
        obs_cli.finish(args.trace, args.obs_report, args.metrics_out,
                       telemetry_snapshot=snap, label="serve")


if __name__ == "__main__":
    main()
