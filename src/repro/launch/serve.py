"""Serving driver: batched prefill + decode with a continuous-batching
request queue (CPU-scale; the dry-run proves the production shapes).

Requests arrive with different prompts; the scheduler packs them into a
fixed batch, prefills, then decodes tokens step by step, retiring
finished requests and admitting queued ones into freed slots.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill
from repro.models.frontend import audio_frames, vision_patches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Fixed-batch continuous decoder over the functional model API."""

    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.queue: Deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * batch_size
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b))
        self._decode = jax.jit(
            lambda p, c, b: decode_step(p, cfg, c, b))
        self.key = jax.random.PRNGKey(seed)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_batch(self, reqs: List[Request]):
        s = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            toks[i, s - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            batch["frames"] = audio_frames(self.key, self.cfg,
                                           len(reqs), s)
        if self.cfg.frontend == "vision":
            batch["soft_emb"] = vision_patches(self.key, self.cfg,
                                               len(reqs))
        return self._prefill(self.params, batch)

    def run(self, max_steps: int = 512) -> Dict[int, List[int]]:
        """Serve until queue + active drain (or max_steps)."""
        results: Dict[int, List[int]] = {}
        while self.queue or any(self.active):
            # admit up to `batch` requests (simple static batching per
            # wave; slots refill between waves)
            wave: List[Request] = []
            while self.queue and len(wave) < self.batch:
                wave.append(self.queue.popleft())
            if not wave:
                break
            logits, cache = self._prefill_batch(wave)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            for _ in range(max_steps):
                live = [r for r in wave if not r.done]
                if not live:
                    break
                for i, r in enumerate(wave):
                    if not r.done:
                        r.out.append(int(next_tok[i]))
                        if len(r.out) >= r.max_new_tokens:
                            r.done = True
                logits, cache = self._decode(
                    self.params, cache, {"tokens": next_tok[:, None]})
                next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(
                    jnp.int32)
            for r in wave:
                results[r.rid] = r.out
        return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params, args.batch, max_len=256)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        server.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens))
    results = server.run()
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    print(f"[serve] {len(results)} requests, {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s)")
    for rid in sorted(results)[:3]:
        print(f"  req {rid}: {results[rid][:8]}...")


if __name__ == "__main__":
    main()
