"""Serving CLI: a thin driver over ``repro.serving`` (paged KV cache +
true continuous batching; CPU-scale here, the dry-run proves the
production shapes).

Requests arrive with different prompts and lengths; the scheduler
admits and retires them *every decode step* -- a short request frees
its slot mid-flight and a queued request takes it over while longer
requests keep decoding.  With ``--dp`` the slot rows are striped over
all local devices and per-shard sampled tokens are assembled with the
CollectiveEngine's cached model-driven allgather, so serve traffic
exercises the same dispatch layer as gradient sync.

With ``--replicas N`` the driver stands up a multi-replica fleet
behind a telemetry-driven router (``--router``): requests pass
admission control (``--queue-cap`` / ``--tenant-rate``), are routed on
the replicas' load signals, and the replicas step in deterministic
lockstep waves.  ``--arrival bursty`` stamps Markov-modulated Poisson
arrival waves on the trace instead of submitting everything up front.

The legacy names (``BatchedServer``, ``Request``) are the serving
subsystem's classes re-exported; the old static wave-batcher is gone.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.models.frontend import vision_patches
from repro.obs import cli as obs_cli
from repro.serving import (ContinuousBatchingServer as BatchedServer,
                           Request, SamplingParams)
from repro.serving.telemetry import ttft_low_confidence


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="content-addressed CoW sharing of prompt-prefix "
                         "KV blocks across requests (--no-prefix-cache "
                         "recomputes every prompt)")
    ap.add_argument("--shared-prompts", type=int, default=0, metavar="K",
                    help="draw each request's prompt prefix from K shared "
                         "system prompts (0 = fully distinct prompts); "
                         "exercises the prefix cache")
    ap.add_argument("--dp", action="store_true",
                    help="stripe the slot rows over all local devices "
                         "and route token sync through the "
                         "CollectiveEngine")
    from repro.serving.fleet import ARRIVAL_MODES, ROUTER_POLICIES
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="serve behind a fleet of N replicas in "
                         "lockstep waves (1 = single server)")
    ap.add_argument("--router", default="round_robin",
                    choices=ROUTER_POLICIES,
                    help="fleet routing policy (used when --replicas "
                         "> 1 or other fleet flags are set)")
    ap.add_argument("--queue-cap", type=int, default=None, metavar="Q",
                    help="fleet-wide queued-request cap; arrivals above "
                         "the cap are rejected with a retry-after hint")
    ap.add_argument("--tenant-rate", type=float, default=None,
                    metavar="TOK",
                    help="per-tenant token-bucket refill "
                         "(prompt+output tokens per wave)")
    ap.add_argument("--tenant-burst", type=float, default=None,
                    metavar="TOK",
                    help="per-tenant bucket capacity (default 8x rate)")
    ap.add_argument("--arrival", default="fixed", choices=ARRIVAL_MODES,
                    help="arrival process for the request trace "
                         "(bursty = Markov-modulated Poisson)")
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="mean arrivals per wave in the calm state")
    obs_cli.add_obs_args(ap)
    args = ap.parse_args()
    obs_cli.begin(args.trace, args.obs_report, args.metrics_out)

    cfg = get_config(args.arch).reduced()
    from repro.models import supports_paged
    if not supports_paged(cfg):
        ap.error(
            f"--arch {args.arch} (family {cfg.family!r}) is not servable "
            f"yet: the paged KV cache covers dense/moe decoder families "
            f"(constant-state families keep the dense training cache)")
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = None
    batch = args.batch
    if args.dp:
        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev,), ("data",))
        if batch % ndev:
            batch += -batch % ndev
            print(f"[serve] rounding batch to {batch} "
                  f"(dp axis = {ndev} devices)")
    max_len = args.prompt_len + args.new_tokens + cfg.frontend_tokens + \
        args.block_size
    fleet_mode = (args.replicas > 1 or args.arrival != "fixed"
                  or args.queue_cap is not None
                  or args.tenant_rate is not None)
    rng = np.random.default_rng(0)
    shared = [rng.integers(0, cfg.vocab_size,
                           size=max(args.prompt_len - 4, 1)).astype(np.int32)
              for _ in range(args.shared_prompts)]

    def make_request(rid):
        soft = None
        if cfg.frontend == "vision":
            soft = vision_patches(jax.random.PRNGKey(rid), cfg, 1)
        tenant = "solo"
        if shared:
            # shared system prompt + short per-request suffix
            tenant = f"tenant-{rid % len(shared)}"
            prompt = np.concatenate(
                [shared[rid % len(shared)],
                 rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)])
        else:
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=args.prompt_len).astype(np.int32)
        return tenant, Request(
            rid=rid,
            prompt=prompt,
            max_new_tokens=args.new_tokens,
            sampling=SamplingParams(temperature=args.temperature),
            soft_emb=soft)

    if fleet_mode:
        results, snap = _run_fleet(args, cfg, params, batch, max_len, mesh,
                                   make_request)
    else:
        server = BatchedServer(cfg, params, batch, max_len=max_len,
                               mesh=mesh, block_size=args.block_size,
                               prefill_chunk=args.prefill_chunk,
                               top_k=args.top_k,
                               prefix_cache=args.prefix_cache)
        t0 = time.time()
        for rid in range(args.requests):
            server.submit(make_request(rid)[1])
        results = server.run()
        dt = time.time() - t0
        snap = server.snapshot()
        total = sum(len(v) for v in results.values())
        print(f"[serve] {len(results)} requests, {total} tokens in "
              f"{dt:.1f}s ({total / dt:.1f} tok/s)")
        lc = (f" (low confidence, n={snap.ttft_samples})"
              if ttft_low_confidence(snap) else "")
        print(f"[serve] ttft p50={snap.ttft_p50_ms:.0f}ms "
              f"p99={snap.ttft_p99_ms:.0f}ms{lc} | decode steps "
              f"{snap.decode_steps} | prefill chunks "
              f"{snap.prefill_chunks} | preemptions {snap.preemptions} | "
              f"peak kv occupancy {snap.kv_peak_occupancy:.2f}")
        print(f"[serve] prefix cache: "
              f"{'on' if args.prefix_cache else 'off'} | prefill tokens "
              f"computed {snap.prefill_tokens_computed} | cached "
              f"{snap.cached_prefix_tokens} "
              f"({snap.cached_token_fraction:.0%}) | evictions "
              f"{snap.prefix_evictions} | kv blocks live "
              f"{snap.kv_blocks_live} / evictable "
              f"{snap.kv_blocks_evictable}")
    for rid in sorted(results)[:3]:
        print(f"  req {rid}: {results[rid][:8]}...")
    tel_snap = snap if not fleet_mode else None
    if mesh is not None:
        with mesh:
            obs_cli.finish(args.trace, args.obs_report, args.metrics_out,
                           mesh=mesh, telemetry_snapshot=tel_snap,
                           label="serve")
    else:
        obs_cli.finish(args.trace, args.obs_report, args.metrics_out,
                       telemetry_snapshot=tel_snap, label="serve")


def _run_fleet(args, cfg, params, batch, max_len, mesh, make_request):
    """Fleet path: wave-stamped arrivals -> admission -> router ->
    lockstep replicas.  Returns (results, FleetSnapshot)."""
    from repro.serving.fleet import (AdmissionConfig, FleetServer,
                                     arrival_waves, export_fleet_stats)
    admission = AdmissionConfig(queue_cap=args.queue_cap,
                                tenant_rate=args.tenant_rate,
                                tenant_burst=args.tenant_burst)
    fleet = FleetServer(cfg, params, args.replicas, batch, max_len,
                        router=args.router, admission=admission,
                        mesh=mesh, block_size=args.block_size,
                        prefill_chunk=args.prefill_chunk,
                        top_k=args.top_k,
                        prefix_cache=args.prefix_cache)
    waves = arrival_waves(args.requests, args.arrival,
                          rng=np.random.default_rng(1),
                          rate=args.arrival_rate)
    arrivals = []
    for rid in range(args.requests):
        tenant, req = make_request(rid)
        arrivals.append((waves[rid], tenant, req))
    t0 = time.time()
    results, rejections = fleet.run_trace(arrivals)
    dt = time.time() - t0
    snap = fleet.snapshot()
    total = sum(len(v) for v in results.values())
    print(f"[fleet] {args.replicas} replicas | router {args.router} | "
          f"arrival {args.arrival} | {len(results)} requests, {total} "
          f"tokens in {dt:.1f}s ({total / dt:.1f} tok/s)")
    print(f"[fleet] waves {snap.waves} | routed {list(snap.routed)} | "
          f"admitted {snap.admitted} | rejected {snap.rejected} "
          f"({dict(snap.rejected_by_reason)}) | below-cap rejects "
          f"{snap.rejected_below_cap}")
    print(f"[fleet] fleet prefill computed "
          f"{snap.prefill_tokens_computed} | cached "
          f"{snap.cached_prefix_tokens} "
          f"({snap.cached_token_fraction:.0%}) | per-replica queue "
          f"depth max {list(snap.queue_depth_max)}")
    for i, rs in enumerate(snap.replicas):
        qw = (f"{rs.queue_wait_p50_ms:.0f}ms"
              if rs.queue_wait_p50_ms is not None else "n/a")
        print(f"[fleet]   replica {i}: decode steps {rs.decode_steps} | "
              f"prefill computed {rs.prefill_tokens_computed} | cached "
              f"{rs.cached_prefix_tokens} | queue wait p50 {qw}")
    if rejections:
        r = rejections[0]
        print(f"[fleet]   first rejection: rid {r.rid} ({r.reason}) "
              f"retry after {r.retry_after_waves} waves")
    export_fleet_stats(fleet)
    return results, snap


if __name__ == "__main__":
    main()
