"""Serving driver: batched prefill + decode with a continuous-batching
request queue (CPU-scale; the dry-run proves the production shapes).

Requests arrive with different prompts; the scheduler packs them into a
fixed batch, prefills, then decodes tokens step by step, retiring
finished requests and admitting queued ones into freed slots.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.collectives.api import get_engine
from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill
from repro.models.frontend import audio_frames, vision_patches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Fixed-batch continuous decoder over the functional model API."""

    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 seed: int = 0, mesh: Optional[Mesh] = None,
                 dp_axis: str = "data", engine=None):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_len = max_len
        self.queue: Deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * batch_size
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b))
        self._decode = jax.jit(
            lambda p, c, b: decode_step(p, cfg, c, b))
        self.key = jax.random.PRNGKey(seed)
        # data-parallel serving: requests striped over `dp_axis`; the
        # scheduler needs the *global* token vector to retire/admit, so
        # per-shard argmaxes are assembled with the engine's cached
        # model-driven allgather -- serve-path collective traffic flows
        # through the same dispatch layer as gradient sync.
        self.mesh = mesh
        self.dp_axis = dp_axis
        self._engine = engine
        self._gather_tokens = None
        if mesh is not None:
            if batch_size % mesh.shape[dp_axis] != 0:
                raise ValueError(
                    f"batch {batch_size} not divisible by dp axis "
                    f"{mesh.shape[dp_axis]}")
            self._engine = engine or get_engine()
            eng = self._engine
            # argmax runs on the *local* logits shard; the engine's
            # allgather is what makes the result global -- the collective
            # carries genuinely shard-local tokens, as a multi-host DP
            # serve path requires
            self._gather_tokens = jax.jit(shard_map(
                lambda lg: eng.allgather_inside(
                    jnp.argmax(lg, axis=-1).astype(jnp.int32), dp_axis),
                mesh=mesh, in_specs=P(dp_axis), out_specs=P(),
                check_rep=False))

    def _next_tokens(self, logits_last: jax.Array) -> jax.Array:
        """Greedy sample; in DP mode allgather the shard tokens so every
        host-side scheduling decision sees the full batch."""
        if self._gather_tokens is not None:
            return self._gather_tokens(logits_last)
        return jnp.argmax(logits_last, axis=-1).astype(jnp.int32)

    def _place(self, batch):
        if self.mesh is None:
            return batch
        sh = NamedSharding(self.mesh, P(self.dp_axis))
        return {k: jax.device_put(v, sh) for k, v in batch.items()}

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_batch(self, reqs: List[Request]):
        s = max(len(r.prompt) for r in reqs)
        n = len(reqs)
        if self.mesh is not None:
            # waves can be smaller than the configured batch (queue
            # draining); pad to a dp-divisible row count so the sharded
            # placement and token allgather stay well-formed.  Padded
            # rows decode garbage nobody reads.
            dp = self.mesh.shape[self.dp_axis]
            n += (-n) % dp
        toks = np.zeros((n, s), np.int32)
        for i, r in enumerate(reqs):
            toks[i, s - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            batch["frames"] = audio_frames(self.key, self.cfg, n, s)
        if self.cfg.frontend == "vision":
            batch["soft_emb"] = vision_patches(self.key, self.cfg, n)
        return self._prefill(self.params, self._place(batch))

    def run(self, max_steps: int = 512) -> Dict[int, List[int]]:
        """Serve until queue + active drain (or max_steps)."""
        results: Dict[int, List[int]] = {}
        while self.queue or any(self.active):
            # admit up to `batch` requests (simple static batching per
            # wave; slots refill between waves)
            wave: List[Request] = []
            while self.queue and len(wave) < self.batch:
                wave.append(self.queue.popleft())
            if not wave:
                break
            logits, cache = self._prefill_batch(wave)
            next_tok = self._next_tokens(logits[:, -1])
            for _ in range(max_steps):
                live = [r for r in wave if not r.done]
                if not live:
                    break
                for i, r in enumerate(wave):
                    if not r.done:
                        r.out.append(int(next_tok[i]))
                        if len(r.out) >= r.max_new_tokens:
                            r.done = True
                logits, cache = self._decode(
                    self.params, cache, {"tokens": next_tok[:, None]})
                next_tok = self._next_tokens(logits[:, 0])
            for r in wave:
                results[r.rid] = r.out
        return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--dp", action="store_true",
                    help="stripe the batch over all local devices and "
                         "route token sync through the CollectiveEngine")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = None
    if args.dp:
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    server = BatchedServer(cfg, params, args.batch, max_len=256, mesh=mesh)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        server.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens))
    results = server.run()
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    print(f"[serve] {len(results)} requests, {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s)")
    for rid in sorted(results)[:3]:
        print(f"  req {rid}: {results[rid][:8]}...")


if __name__ == "__main__":
    main()
