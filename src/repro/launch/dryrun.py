import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init).  Each cell:

    with mesh:
        lowered = jax.jit(step, in_shardings=...).lower(**input_specs)
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

and writes a JSON artifact under var/dryrun/ that EXPERIMENTS.md's
Dry-run and Roofline sections (and benchmarks/roofline_report.py) read.

Usage:
    python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    python -m repro.launch.dryrun --all --mesh pod
Hillclimb knobs: --microbatches N --no-fsdp --no-remat --tag <name>
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, all_configs, cell_is_runnable, get_config
from repro.configs import base
from repro.launch import roofline as rl
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig
from repro.sharding import rules
from repro.train.state import abstract_train_state, train_state_shardings
from repro.train.step import make_decode_step, make_prefill_step, make_train_step

ART_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..", "var",
                 "dryrun"))


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_shardings(mesh, policy, batch_specs: Dict[str, Any]):
    specs = rules.batch_sharding_specs(policy, batch_specs)
    return {k: NamedSharding(mesh, s) for k, s in specs.items()}


def _mem_dict(mem) -> Dict[str, float]:
    if mem is None:
        return {}
    fields = ["generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"]
    out = {}
    for f in fields:
        try:
            v = getattr(mem, f, None)
            if v is not None:
                out[f] = float(v)
        except Exception:
            pass
    return out


def _cost_dict(cost) -> Dict[str, float]:
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    try:
        return {str(k): float(v) for k, v in dict(cost).items()
                if isinstance(v, (int, float))}
    except Exception:
        return {}


def _lower_cell(cfg, shape, mesh, policy, microbatches, remat, unroll):
    """Build + lower one cell's step function.  Returns the lowered
    computation."""
    if shape.step == "train":
        state_specs = abstract_train_state(cfg)
        state_sh = train_state_shardings(state_specs, mesh, policy)
        batch_specs = sp.train_input_specs(cfg, shape)
        batch_sh = _batch_shardings(mesh, policy, batch_specs)
        step = make_train_step(cfg, AdamWConfig(),
                               microbatches=microbatches, remat=remat,
                               unroll=unroll)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
        return jitted.lower(state_specs, batch_specs)
    if shape.step == "prefill":
        param_specs_ = tf.param_specs(cfg)
        param_sh = rules.param_sharding_tree(param_specs_, mesh, policy)
        batch_specs = sp.prefill_input_specs(cfg, shape)
        batch_sh = _batch_shardings(mesh, policy, batch_specs)
        step = make_prefill_step(cfg, unroll=unroll)
        jitted = jax.jit(step, in_shardings=(param_sh, batch_sh))
        return jitted.lower(param_specs_, batch_specs)
    # decode
    param_specs_ = tf.param_specs(cfg)
    param_sh = rules.param_sharding_tree(param_specs_, mesh, policy)
    cache_specs_ = sp.decode_cache_specs(cfg, shape)
    cache_sh = _named(mesh, rules.cache_specs(cfg, policy, cache_specs_))
    batch_specs = sp.decode_input_specs(cfg, shape)
    batch_sh = _batch_shardings(mesh, policy, batch_specs)
    step = make_decode_step(cfg, unroll=unroll)
    jitted = jax.jit(step, in_shardings=(param_sh, cache_sh, batch_sh),
                     donate_argnums=(1,))
    return jitted.lower(param_specs_, cache_specs_, batch_specs)


def _measure(cfg, shape, mesh, policy, microbatches, remat) -> Dict[str, float]:
    """Compile a reduced-depth UNROLLED variant and harvest per-device
    FLOPs / bytes / collective bytes (exact per-op accounting).  Inner
    scans (attention KV chunks, SSM chunks) are unrolled too, so loop
    carries -- which TPU aliases in place -- don't get charged as copy
    traffic by the CPU-backend cost analysis."""
    from repro.models import layers as model_layers
    model_layers.set_inner_unroll(True)
    try:
        lowered = _lower_cell(cfg, shape, mesh, policy, microbatches,
                              remat, unroll=True)
        compiled = lowered.compile()
    finally:
        model_layers.set_inner_unroll(False)
    cost = _cost_dict(compiled.cost_analysis())
    coll = rl.parse_collective_bytes(compiled.as_text())
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "collective_bytes": rl.collective_total(coll),
        "collectives": coll,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str = "pod",
             microbatches: int = 1, fsdp: bool = True, remat: bool = True,
             unroll: bool = True, tag: str = "", save: bool = True,
             extra_notes: str = "", levers: Dict[str, Any] | None = None
             ) -> Dict[str, Any]:
    """One dry-run cell.

    Structure: (1) the FULL config is lowered + compiled with scan over
    layers -- this is the multi-pod dry-run proof and provides the memory
    analysis; (2) because HloCostAnalysis counts loop bodies once, exact
    FLOPs/bytes/collectives are measured on 1- and 2-layer-unit UNROLLED
    variants and extrapolated linearly (layers are homogeneous, so the
    per-unit slope is exact)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if levers:
        cfg = _dc.replace(cfg, **levers)
    shape = SHAPES[shape_name]
    runnable, why = cell_is_runnable(cfg, shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "microbatches": microbatches, "fsdp": fsdp, "remat": remat,
        "tag": tag, "notes": extra_notes,
    }
    if not runnable:
        record.update({"status": "skipped", "reason": why})
        return _finish(record, save)

    multi_pod = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    record["n_devices"] = int(mesh.devices.size)
    policy = rules.for_mesh(mesh, fsdp=fsdp)

    with mesh:
        # ---- (1) full-config compile (scan): the dry-run proof ----
        t0 = time.time()
        lowered = _lower_cell(cfg, shape, mesh, policy, microbatches,
                              remat, unroll=False)
        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)
        try:
            record["memory"] = _mem_dict(compiled.memory_analysis())
        except Exception as e:  # pragma: no cover
            record["memory_analysis_error"] = str(e)
        record["cost_scan_counted_once"] = _cost_dict(
            compiled.cost_analysis())
        record["hlo_bytes"] = len(compiled.as_text())

        # ---- (2) exact cost accounting via 1/2-unit unrolled builds ----
        units = base.layer_units(cfg)
        u1, u2 = (1, 2) if units >= 2 else (units, units)
        t2 = time.time()
        m1 = _measure(base.with_layer_units(cfg, u1), shape, mesh, policy,
                      microbatches, remat)
        m2 = (m1 if u2 == u1 else
              _measure(base.with_layer_units(cfg, u2), shape, mesh,
                       policy, microbatches, remat))
        record["measure_s"] = round(time.time() - t2, 2)

        def extrap(key):
            if u2 == u1:
                return m2[key]
            slope = (m2[key] - m1[key]) / (u2 - u1)
            return m2[key] + (units - u2) * slope

        flops_dev = extrap("flops")
        bytes_dev = extrap("bytes")
        coll_dev = extrap("collective_bytes")
        record["measure_points"] = {
            "units": [u1, u2], "full_units": units,
            "flops": [m1["flops"], m2["flops"]],
            "bytes": [m1["bytes"], m2["bytes"]],
            "collective_bytes": [m1["collective_bytes"],
                                 m2["collective_bytes"]],
        }
        record["collectives_u2"] = m2["collectives"]

    record["cost"] = {"flops": flops_dev, "bytes accessed": bytes_dev}
    record["collective_bytes_per_device"] = coll_dev
    mf = rl.model_flops(cfg, shape)
    terms = rl.roofline_terms(flops_dev, bytes_dev, coll_dev,
                              record["n_devices"], mf)
    record["roofline"] = terms.as_dict()
    record["status"] = "ok"
    return _finish(record, save)


def _finish(record: Dict[str, Any], save: bool) -> Dict[str, Any]:
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        tag = f"__{record['tag']}" if record.get("tag") else ""
        path = os.path.join(
            ART_DIR,
            f"{record['arch']}__{record['shape']}__{record['mesh']}{tag}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        record["artifact"] = path
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--scan", action="store_true",
                    help="scan over layers instead of unrolling (faster "
                         "compile, but HloCostAnalysis counts loop bodies "
                         "once)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--moe-ep", action="store_true",
                    help="shard_map expert-parallel MoE dispatch")
    ap.add_argument("--attn-bf16", action="store_true",
                    help="bf16 attention probabilities")
    ap.add_argument("--logits-bf16", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list(all_configs()) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    for arch, shape in cells:
        tag = f"__{args.tag}" if args.tag else ""
        path = os.path.join(ART_DIR, f"{arch}__{shape}__{args.mesh}{tag}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {arch} x {shape} ({args.mesh}) exists")
            continue
        print(f"[cell] {arch} x {shape} ({args.mesh}) ...", flush=True)
        try:
            levers = {}
            if args.moe_ep:
                levers["moe_shardmap_ep"] = True
            if args.attn_bf16:
                levers["attn_probs_bf16"] = True
            if args.logits_bf16:
                levers["logits_bf16"] = True
            rec = run_cell(arch, shape, args.mesh,
                           microbatches=args.microbatches,
                           fsdp=not args.no_fsdp, remat=not args.no_remat,
                           tag=args.tag, levers=levers)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"  ok: compile={rec['compile_s']}s "
                      f"compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s "
                      f"collective={r['collective_s']:.4f}s "
                      f"dominant={r['dominant']} "
                      f"frac={r['roofline_fraction']:.3f}", flush=True)
            else:
                print(f"  {rec['status']}: {rec.get('reason','')}", flush=True)
        except Exception:
            print(f"  FAILED:\n{traceback.format_exc()}", flush=True)
            rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "tag": args.tag, "status": "failed",
                   "error": traceback.format_exc()}
            _finish(rec, True)


if __name__ == "__main__":
    main()
