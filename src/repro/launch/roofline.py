"""Roofline-term derivation from compiled dry-run artifacts.

    compute term    = FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HBM_bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device / link_bw

The compiled module is the post-SPMD per-device program, so
``cost_analysis()`` FLOPs/bytes and the HLO collective operand sizes are
*per-device* quantities; dividing by per-chip peaks gives seconds
directly (equivalent to the global/(chips*peak) form in the task spec).

Hardware constants: TPU v5e -- 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def parse_collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result-shape bytes of every collective op in (post-SPMD) HLO.

    Returns {op_kind: {"bytes": b, "count": n}}.
    """
    out: Dict[str, Dict[str, float]] = {
        k: {"bytes": 0.0, "count": 0} for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        rhs = rhs.strip()
        for op in COLLECTIVE_OPS:
            # match ` = <shape(s)> op-name(' with optional `-start` /
            # `-done`.  `=` must be in the shape class: big tuple
            # results carry `/*index=5*/` comments (e.g. the 8-operand
            # all-to-all), and `(` is excluded, so the lazy match still
            # cannot cross into an op's operand list.
            m = re.match(
                r"^(\(?[\w\[\],{}\s/#*=]*?\)?)\s*%?" + op + r"(-start)?\(",
                rhs)
            if m:
                if m.group(2):  # async start: count here, skip the -done
                    shapes_src = m.group(1)
                else:
                    shapes_src = m.group(1)
                b = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(shapes_src))
                out[op]["bytes"] += b
                out[op]["count"] += 1
                break
    return out


def collective_total(parsed: Dict[str, Dict[str, float]]) -> float:
    return sum(v["bytes"] for v in parsed.values())


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_global: float
    hlo_flops_global: float
    n_devices: int = 1

    @property
    def dominant(self) -> str:
        parts = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(parts, key=parts.get)

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs -- catches remat/redundancy/padding."""
        if self.hlo_flops_global <= 0:
            return float("nan")
        return self.model_flops_global / self.hlo_flops_global

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute seconds / bottleneck seconds (== achievable MFU
        if the dominant term were perfectly overlapped with the rest)."""
        if self.total_s <= 0:
            return float("nan")
        useful_s = self.model_flops_global / (self.n_devices * PEAK_FLOPS)
        return useful_s / self.total_s

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_global": self.model_flops_global,
            "hlo_flops_global": self.hlo_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_terms(flops_per_device: float, hbm_bytes_per_device: float,
                   collective_bytes_per_device: float, n_devices: int,
                   model_flops_global: float) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_device / PEAK_FLOPS,
        memory_s=hbm_bytes_per_device / HBM_BW,
        collective_s=collective_bytes_per_device / LINK_BW,
        model_flops_global=model_flops_global,
        hlo_flops_global=flops_per_device * n_devices,
        n_devices=n_devices,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n_active = cfg.active_param_count()
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


__all__ = [
    "PEAK_FLOPS", "HBM_BW", "LINK_BW", "COLLECTIVE_OPS",
    "parse_collective_bytes", "collective_total", "RooflineTerms",
    "roofline_terms", "model_flops",
]
