"""Launch layer: mesh, dry-run, roofline, train/serve drivers.

NOTE: repro.launch.dryrun must be imported/run as the FIRST jax-touching
module of its process (it sets XLA_FLAGS); this package init deliberately
does not import it.
"""

from repro.launch.mesh import make_debug_mesh, make_production_mesh

__all__ = ["make_debug_mesh", "make_production_mesh"]
