"""ShapeDtypeStruct stand-ins for every model input, per (arch x shape).

The dry-run lowers against these -- weak-type-correct, shardable, no
device allocation.  ``step_kind`` decides what a cell lowers:

  train_4k      -> train_step(state, batch)
  prefill_32k   -> prefill(params, batch)
  decode_32k / long_500k -> decode_step(params, cache, batch)

Whisper conventions (backbone-only spec, see DESIGN.md): prefill runs the
encoder over ``seq_len`` frames with a 448-token decoder prompt; decode
uses a ``seq_len`` self-attention cache and a 1500-frame cross cache.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as tf

WHISPER_DECODER_PROMPT = 448
WHISPER_DECODE_CROSS_LEN = 1500


def _token_spec(b: int, s: int):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def train_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        n_img = cfg.frontend_tokens
        specs["soft_emb"] = jax.ShapeDtypeStruct(
            (b, n_img, cfg.d_model), cfg.activation_dtype)
        s_text = s - n_img
    else:
        s_text = s
    specs["tokens"] = _token_spec(b, s_text)
    specs["labels"] = _token_spec(b, s_text)
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), cfg.activation_dtype)
    return specs


def prefill_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), cfg.activation_dtype)
        specs["tokens"] = _token_spec(b, WHISPER_DECODER_PROMPT)
        return specs
    if cfg.frontend == "vision":
        n_img = cfg.frontend_tokens
        specs["soft_emb"] = jax.ShapeDtypeStruct(
            (b, n_img, cfg.d_model), cfg.activation_dtype)
        specs["tokens"] = _token_spec(b, s - n_img)
        return specs
    specs["tokens"] = _token_spec(b, s)
    return specs


def decode_cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    enc_len = WHISPER_DECODE_CROSS_LEN if cfg.family == "encdec" else 0
    fn = functools.partial(tf.init_cache, cfg, b, s, enc_len)
    return jax.eval_shape(fn)


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    return {"tokens": _token_spec(shape.global_batch, 1)}


__all__ = [
    "train_input_specs", "prefill_input_specs", "decode_cache_specs",
    "decode_input_specs", "WHISPER_DECODER_PROMPT",
    "WHISPER_DECODE_CROSS_LEN",
]
