"""Training driver: data pipeline -> sharded train loop -> checkpoints,
with preemption safety and straggler telemetry wired in.

CPU-scale usage (see examples/train_100m.py for the end-to-end run):

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real fleet the same driver runs under `jax.distributed.initialize`
with the production mesh; the dry-run (repro.launch.dryrun) is the
scale-proof for those configurations.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.obs import cli as obs_cli
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.models import init_params
from repro.models.frontend import audio_frames, vision_patches
from repro.optim.adamw import AdamWConfig
from repro.runtime import PreemptionGuard, StragglerDetector
from repro.train.state import init_train_state
from repro.train.step import GradSyncConfig, make_train_step


def make_dp_mesh():
    """(pod, data) mesh over every visible device: the hierarchical DP
    topology the collective planner plans for.  Two virtual pods when
    the device count splits evenly, a single pod otherwise."""
    nd = len(jax.devices())
    pod = 2 if nd >= 4 and nd % 2 == 0 else 1
    return jax.make_mesh((pod, nd // pod), ("pod", "data"))


def build_batch(cfg, data_batch, key):
    batch = {"tokens": jnp.asarray(data_batch["tokens"]),
             "labels": jnp.asarray(data_batch["labels"])}
    b, s = batch["tokens"].shape
    if cfg.family == "encdec":
        batch["frames"] = audio_frames(key, cfg, b, s)
    if cfg.frontend == "vision":
        batch["soft_emb"] = vision_patches(key, cfg, b)
    return batch


def install_fabric_topology(spec: str):
    """Parse a ``--fabric`` spec (``pod=slow,data=fast`` or a JSON
    path) and install an engine with those per-axis constants as the
    process default, so every engine-routed collective -- grad sync,
    serve -- is planned against the declared link speeds."""
    from repro.core.model import TPU_V5E_AXIS, parse_fabric_topology
    from repro.collectives.api import set_engine
    from repro.collectives.engine import CollectiveEngine

    topo = parse_fabric_topology(spec)
    engine = CollectiveEngine(fabric=topo)
    set_engine(engine)
    # call sites ask for the stock default fabric; pin the topology
    # engine under that key too, or a spec that overrides `default`
    # would print its topology and then never price anything
    set_engine(engine, fabric=TPU_V5E_AXIS)
    return topo


def run(arch: str, steps: int, batch_size: int, seq_len: int,
        reduced: bool = True, ckpt_dir: str | None = None,
        ckpt_every: int = 50, lr: float = 3e-4, microbatches: int = 1,
        log_every: int = 10, resume: bool = True, dp: bool = False,
        grad_sync_mode: str = "allreduce", fused: bool = False,
        fabric_spec: str | None = None,
        moe_ep: str | None = None, num_experts: int | None = None,
        trace: str | None = None, obs_report: bool = False,
        metrics_out: str | None = None):
    obs_cli.begin(trace, obs_report, metrics_out)
    if fabric_spec:
        topo = install_fabric_topology(fabric_spec)
        print(f"[train] fabric topology: {topo.describe()}")
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    if num_experts is not None or moe_ep is not None:
        import dataclasses
    if num_experts is not None:
        cfg = dataclasses.replace(cfg, num_experts=num_experts)
    if moe_ep is not None:
        if cfg.family != "moe":
            raise SystemExit(f"--moe-ep needs an MoE architecture; "
                             f"{arch} is family={cfg.family!r}")
        cfg = dataclasses.replace(cfg, moe_ep=True,
                                  moe_ep_algorithm=moe_ep)
        print(f"[train] expert-parallel MoE dispatch: "
              f"all_to_all[{moe_ep}]")
    if fused or cfg.fused_tp:
        from repro.models.layers import set_fused_tp
        set_fused_tp(True)
        print("[train] fused matmul+reduce-scatter executor enabled")
    schedule = "wsd" if arch == "minicpm-2b" else "cosine"
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(steps // 20, 1),
                          total_steps=steps, schedule=schedule)

    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    state = init_train_state(params)
    data = SyntheticLMDataset(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len,
        global_batch=batch_size))

    start_step = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=2, async_save=True)
        if resume and mgr.committed_steps():
            start_step, state, meta = mgr.restore(state)
            print(f"[train] resumed from step {start_step}")

    mesh = None
    grad_sync = None
    batch_sharding = None
    if dp:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.sharding.rules import grad_sync_axes_for_mesh
        mesh = make_dp_mesh()
        axes = grad_sync_axes_for_mesh(mesh)
        grad_sync = GradSyncConfig(mesh=mesh, axes=axes,
                                   mode=grad_sync_mode, fused=fused)
        n_dp = 1
        for a in axes:
            n_dp *= mesh.shape[a]
        if axes and batch_size % n_dp == 0:
            batch_sharding = NamedSharding(
                mesh, P(axes if len(axes) > 1 else axes[0]))
        elif n_dp > 1:
            print(f"[train] WARNING: batch {batch_size} not divisible "
                  f"by DP world {n_dp}; batch stays replicated (no DP "
                  f"speedup, sync path still exercised)")
        print(f"[train] dp mesh {dict(mesh.shape)} grad-sync axes "
              f"{axes} mode={grad_sync_mode}"
              + (" fused" if fused else ""))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=microbatches,
                                      grad_sync=grad_sync))
    guard = PreemptionGuard(install=True)
    stragglers = StragglerDetector()
    host = f"host{jax.process_index()}"
    losses = []
    t_start = time.time()
    for step in range(start_step, steps):
        t0 = time.time()
        batch = build_batch(cfg, data.batch(step), jax.random.fold_in(key,
                                                                      step))
        if batch_sharding is not None:
            batch = {k: jax.device_put(v, batch_sharding)
                     for k, v in batch.items()}
        if mesh is not None:
            with mesh:
                state, metrics = step_fn(state, batch)
        else:
            state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        stragglers.record(host, time.time() - t0)
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step={step} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"dt={time.time() - t0:.2f}s", flush=True)
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, state, metadata={"loss": loss},
                     block=False)
        if guard.should_stop:
            print("[train] preemption requested: checkpointing and "
                  "exiting")
            if mgr:
                mgr.save(step + 1, state, metadata={"loss": loss})
            break
    if mgr:
        mgr.save(steps, state, metadata={"loss": losses[-1]})
        mgr.wait()
    print(f"[train] done: {len(losses)} steps in "
          f"{time.time() - t_start:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if mesh is not None:
        with mesh:
            obs_cli.finish(trace, obs_report, metrics_out, mesh=mesh,
                           label="train")
    else:
        obs_cli.finish(trace, obs_report, metrics_out, label="train")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--dp", action="store_true",
                    help="hierarchical (pod, data) DP over all devices; "
                         "gradient sync through the collective planner")
    ap.add_argument("--grad-sync", choices=("allreduce", "fsdp"),
                    default="allreduce",
                    help="engine sync shape under --dp: bucketed "
                         "allreduce or the FSDP RS/AG pair")
    ap.add_argument("--fused", action="store_true",
                    help="route the grad sync (and TP projections, "
                         "when a model axis exists) through the "
                         "engine's fused matmul+reduce-scatter "
                         "executor (kernels/fused_matmul_rs.py)")
    ap.add_argument("--fabric", default=None, metavar="SPEC",
                    help="heterogeneous fabric topology: "
                         "'pod=slow,data=fast' (presets or link_bw "
                         "multipliers) or a path to a JSON topology "
                         "file; the planner prices each mesh axis "
                         "with its declared link constants")
    ap.add_argument("--moe-ep", nargs="?", const="auto", default=None,
                    metavar="ALGO",
                    help="route MoE expert dispatch/combine through "
                         "explicit all-to-all (models/moe_ep.py): "
                         "'lax' = bare single-shot baseline, else an "
                         "engine algorithm or plan shape ('auto', "
                         "'hierarchical', 'ring', ...; default auto)")
    ap.add_argument("--experts", type=int, default=None,
                    help="override num_experts (e.g. to tile the "
                         "8-virtual-device EP world under --reduced)")
    obs_cli.add_obs_args(ap)
    args = ap.parse_args()
    run(args.arch, args.steps, args.batch, args.seq, reduced=args.reduced,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, lr=args.lr,
        microbatches=args.microbatches, dp=args.dp,
        grad_sync_mode=args.grad_sync, fused=args.fused,
        fabric_spec=args.fabric,
        moe_ep=args.moe_ep, num_experts=args.experts,
        trace=args.trace, obs_report=args.obs_report,
        metrics_out=args.metrics_out)


if __name__ == "__main__":
    main()
