"""Token sampling for the serving path: greedy / temperature / top-k.

Keys are derived per *request token*, not per batch step:
``fold_in(server_key, sample_id)`` with ``sample_id`` unique to
(request, position).  Sampling is therefore invariant to scheduling --
the same request emits the same tokens whether it runs alone, in a full
batch, or sharded over a DP axis (the ids travel with the rows), which
is what lets the DP-vs-local serving equivalence test hold for
stochastic sampling too.

``temperature <= 0`` means greedy, per row; ``top_k`` is per row too
(0 = full vocabulary), so mixed batches are one jitted call with
temperature/top-k arrays riding alongside the rows.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration."""
    temperature: float = 0.0    # <= 0: greedy
    top_k: int = 0              # 0: server default (or full vocab)


def sample_tokens(logits: jax.Array, sample_ids: jax.Array,
                  temperatures: jax.Array, key: jax.Array,
                  top_ks: jax.Array | int = 0) -> jax.Array:
    """logits [B, V] -> tokens [B] int32.

    Rows with temperature <= 0 take the argmax; others sample from
    softmax(logits / T) restricted to their top-k logits when their
    ``top_ks`` entry is > 0 (scalar top_ks broadcasts to the batch).
    """
    logits = logits.astype(jnp.float32)
    b, v = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if isinstance(top_ks, int):
        # static k: resolve at trace time (k == 0 skips the sort)
        if 0 < top_ks < v:
            kth = jnp.sort(logits, axis=-1)[:, -top_ks][:, None]
            logits = jnp.where(logits >= kth, logits, -jnp.inf)
    else:
        # per-row k-th largest as the cutoff; k == 0 disables the filter
        k = jnp.clip(top_ks.astype(jnp.int32), 0, v)
        ordered = jnp.sort(logits, axis=-1)                # ascending
        kth = jnp.take_along_axis(
            ordered, jnp.maximum(v - k, 0)[:, None], axis=-1)  # [B, 1]
        kth = jnp.where((k > 0)[:, None], kth, -jnp.inf)
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    scaled = logits / jnp.maximum(temperatures, 1e-6)[:, None]

    def one(sid, row):
        return jax.random.categorical(jax.random.fold_in(key, sid), row)

    sampled = jax.vmap(one)(sample_ids, scaled).astype(jnp.int32)
    return jnp.where(temperatures > 0, sampled, greedy)


__all__ = ["SamplingParams", "sample_tokens"]
