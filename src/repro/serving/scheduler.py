"""Continuous-batching scheduler: admit and retire every decode step.

Pure host-side bookkeeping (no jax): the scheduler owns the slot array,
the request queue, and the block accounting; the ``Server`` executes the
plan it produces.  Policies:

* **Iteration-level scheduling** -- finished requests release their
  slot + blocks at the top of every step and queued requests are
  admitted into freed slots *in the same step* (no waves, no padding
  rows decoding garbage: idle slots are masked to the scratch block).
* **Chunked prefill** -- admitted requests stream their prompt in
  fixed-size chunks, at most ``prefill_per_step`` chunks per iteration
  while decode is active (long prompts never stall token emission);
  when nothing is decoding, the full idle capacity prefills.
* **Out-of-blocks preemption** -- when a running request cannot get a
  block to grow its context, the latest-admitted active request is
  preempted vLLM-recompute-style: its blocks are released and it is
  re-queued at the front with ``prompt + generated`` as the new prompt
  context.  Sampling keys are per (request, position), so the replay
  reuses the keys of the original run: greedy replays are token-exact;
  stochastic replays match up to the fp32-level agreement between the
  prefill and decode attention paths (a draw sitting exactly on a
  categorical boundary could differ).
* **Prefix caching** (``prefix_cache=``) -- admission matches the
  longest cached run of full prompt blocks (hash-chained content keys,
  ``serving/prefix_cache.py``), takes shared references on the matched
  physical blocks, and starts chunked prefill at the first uncached
  token.  A *full* hit drops back one token -- the final prompt
  position is recomputed so first-step logits exist -- and since that
  write lands in the last matched (shared, immutable) block, the block
  is **copied-on-write**: admission allocates a private replacement and
  queues a ``(src, dst)`` pool copy the server executes before any
  prefill of the step.  Preemption and retirement ``decref`` rather
  than free, so shared blocks survive their first owner and park on
  the evictable LRU at refcount 0.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, List, Optional, Tuple

import numpy as np

from repro.serving.blocks import BlockAllocator, BlockTable, BlockUsage
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import SamplingParams

QUEUED = "queued"
PREFILLING = "prefilling"
RUNNING = "running"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request.

    The leading fields match the legacy ``launch.serve.Request`` wire
    format (rid, prompt, max_new_tokens, out, done); the rest is
    scheduler-managed runtime state.
    """
    rid: int
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    sampling: SamplingParams = SamplingParams()
    soft_emb: Optional[Any] = None      # [1, n_soft, D] vision embeddings

    state: str = QUEUED
    table: Optional[BlockTable] = None
    ctx_len: int = 0                    # positions in cache (incl. soft)
    prefilled: int = 0                  # replay tokens already cached
    cached_prefix_tokens: int = 0       # skipped via prefix cache (this
                                        # admission; server reads after
                                        # admit and accumulates)
    _chain_keys: List[bytes] = dataclasses.field(default_factory=list)
    _cache_upto: int = 0                # table blocks already inserted
    arrival_t: float = 0.0
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    admit_step: Optional[int] = None
    finish_step: Optional[int] = None
    _admit_seq: int = -1

    @property
    def n_soft(self) -> int:
        return 0 if self.soft_emb is None else int(self.soft_emb.shape[1])

    @property
    def replay_tokens(self) -> np.ndarray:
        """Prompt context to (re)prefill: prompt plus anything already
        generated (recompute-style preemption resumes through here)."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out, np.int32)])


@dataclasses.dataclass(frozen=True)
class PrefillChunk:
    req: Request
    start: int      # offset into replay_tokens
    length: int     # valid tokens this chunk (<= prefill_chunk)


class Scheduler:
    def __init__(self, batch_size: int, allocator: BlockAllocator,
                 max_blocks_per_seq: int, prefill_chunk: int,
                 prefill_per_step: int = 1,
                 prefix_cache: Optional[PrefixCache] = None):
        self.batch_size = batch_size
        self.allocator = allocator
        self.max_blocks_per_seq = max_blocks_per_seq
        self.prefill_chunk = prefill_chunk
        self.prefill_per_step = prefill_per_step
        self.prefix_cache = prefix_cache
        #: pending copy-on-write pool copies (src block, dst block) the
        #: server must execute before the step's first prefill
        self.cow_copies: List[Tuple[int, int]] = []
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: Deque[Request] = deque()
        self._admit_seq = 0

    # ------------------------------------------------------------------ #
    def validate(self, req: Request) -> None:
        if len(req.prompt) == 0:
            # the first sampled token comes from the last *token*
            # position of the prefill; an empty prompt has none
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens < 1")
        total = req.n_soft + len(req.prompt) + req.max_new_tokens
        max_tokens = self.max_blocks_per_seq * self.allocator.block_size
        if total > max_tokens:
            raise ValueError(
                f"request {req.rid}: {total} tokens exceeds max_len "
                f"{max_tokens}")
        if self.allocator.blocks_for(total) > self.allocator.capacity:
            raise ValueError(
                f"request {req.rid}: needs "
                f"{self.allocator.blocks_for(total)} blocks, pool has "
                f"{self.allocator.capacity}")

    def submit(self, req: Request, now: Optional[float] = None) -> None:
        self.validate(req)
        req.arrival_t = time.monotonic() if now is None else now
        req.state = QUEUED
        self.queue.append(req)

    # ------------------------------------------------------------------ #
    def has_work(self) -> bool:
        return bool(self.queue) or any(self.slots)

    def active(self) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def running(self) -> List[Tuple[int, Request]]:
        """Decodable rows: RUNNING and not already done (a request can
        finish at prefill time and must not decode before retiring)."""
        return [(i, r) for i, r in self.active()
                if r.state == RUNNING and not r.done]

    def any_running(self) -> bool:
        return bool(self.running())

    def context_lens(self) -> List[int]:
        return [r.ctx_len for _, r in self.active()]

    def block_usage(self) -> List[BlockUsage]:
        """Per-request (block ids, context length) pairs for unique-
        block fragmentation accounting under prefix sharing."""
        return [(r.table.blocks, r.ctx_len) for _, r in self.active()]

    # ------------------------------------------------------------------ #
    def retire_finished(self) -> List[Request]:
        """Free slots + blocks of done requests (called every step)."""
        out = []
        for i, req in enumerate(self.slots):
            if req is not None and req.done:
                req.table.release()
                req.state = FINISHED
                self.slots[i] = None
                out.append(req)
        return out

    def _try_admit(self, req: Request) -> Optional[BlockTable]:
        """All-or-nothing block grant for one request, sharing the
        longest cached prefix run first.  On grant, ``req.prefilled`` /
        ``ctx_len`` start past the shared tokens (prefill resumes at
        the first uncached token); on a full hit the final token is
        recomputed, with the last matched block replaced copy-on-write
        (the recompute writes into it).  Failure restores the cache
        references it took."""
        replay = req.replay_tokens
        bs = self.allocator.block_size
        need_total = max(
            self.allocator.blocks_for(req.n_soft + len(replay)), 1)
        matched: List[int] = []
        keys: List[bytes] = []
        cow_src: Optional[int] = None
        if self.prefix_cache is not None and req.n_soft == 0:
            keys = self.prefix_cache.keys_for(replay)
            matched = self.prefix_cache.match(keys)
            if matched and len(matched) * bs == len(replay):
                cow_src = matched[-1]
        got = self.allocator.alloc(
            need_total - len(matched) + (1 if cow_src is not None else 0))
        if got is None:
            for blk in matched:
                self.allocator.decref(blk)
            return None
        table = BlockTable(self.allocator)
        if cow_src is not None:
            # full hit: got[0] is the private replacement for the last
            # matched block; the pool copy runs before the recompute
            # chunk writes position len(replay)-1 into it
            self.cow_copies.append((cow_src, got[0]))
            self.allocator.decref(cow_src)
            table.blocks = matched[:-1] + got
            cached = len(replay) - 1
        else:
            table.blocks = matched + got
            cached = len(matched) * bs
        req._chain_keys = keys
        req._cache_upto = len(matched)
        req.cached_prefix_tokens = cached
        req.prefilled = cached
        req.ctx_len = cached            # cacheable requests have n_soft=0
        return table

    def admit(self, step: int) -> List[Request]:
        """FCFS-fill free slots from the queue; all-or-nothing block
        grants keep admission atomic.  Stops at the first request that
        does not fit (no starvation of large requests)."""
        admitted = []
        for i in range(self.batch_size):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue[0]
            table = self._try_admit(req)
            if table is None:
                break
            self.queue.popleft()
            req.table = table
            req.state = PREFILLING
            req.admit_step = step if req.admit_step is None else \
                req.admit_step
            req._admit_seq = self._admit_seq
            self._admit_seq += 1
            self.slots[i] = req
            admitted.append(req)
        return admitted

    def drain_cow_copies(self) -> List[Tuple[int, int]]:
        """Pending (src, dst) pool copies from this step's admissions;
        the server must apply them before any prefill runs."""
        out, self.cow_copies = self.cow_copies, []
        return out

    def note_prefilled(self, req: Request) -> None:
        """Register the request's freshly fully-written blocks in the
        prefix cache (called after each executed prefill chunk)."""
        if self.prefix_cache is None or not req._chain_keys:
            return
        upto = min(req.prefilled // self.allocator.block_size,
                   len(req._chain_keys))
        for i in range(req._cache_upto, upto):
            self.prefix_cache.insert(req._chain_keys[i],
                                     req.table.blocks[i])
        req._cache_upto = max(req._cache_upto, upto)

    def prefill_plan(self) -> List[PrefillChunk]:
        """Next prompt chunks: ``prefill_per_step`` while decode is
        live, otherwise the whole idle batch prefills."""
        budget = (self.prefill_per_step if self.any_running()
                  else self.batch_size)
        plan = []
        pref = [r for _, r in self.active() if r.state == PREFILLING]
        pref.sort(key=lambda r: r._admit_seq)
        for req in pref[:budget]:
            replay = req.replay_tokens
            n = min(self.prefill_chunk, len(replay) - req.prefilled)
            plan.append(PrefillChunk(req, req.prefilled, n))
        return plan

    # ------------------------------------------------------------------ #
    def _preempt(self, req: Request) -> None:
        """Recompute-style: release the blocks (decref -- shared and
        cached ones survive for the replay to re-match), re-queue at
        the front."""
        req.table.release()
        req.table = None
        req.state = QUEUED
        req.ctx_len = 0
        req.prefilled = 0
        req.cached_prefix_tokens = 0
        req._chain_keys = []
        req._cache_upto = 0
        for i, r in enumerate(self.slots):
            if r is req:
                self.slots[i] = None
        self.queue.appendleft(req)

    def grow_for_decode(self) -> List[Request]:
        """Ensure every running request has a slot for its next token,
        preempting the latest-admitted active request on exhaustion."""
        preempted = []
        for _, req in self.running():
            # an earlier row's growth may have preempted this one
            # (state left RUNNING only while it still owns its slot)
            while req.state == RUNNING and not req.done and \
                    not req.table.ensure_capacity(req.ctx_len + 1):
                # done-but-unretired requests are not preemptible: a
                # replay would generate past max_new_tokens (their
                # blocks free at the next retire anyway)
                victims = [r for _, r in self.active()
                           if r.state in (PREFILLING, RUNNING)
                           and not r.done]
                victim = max(victims, key=lambda r: r._admit_seq)
                self._preempt(victim)
                preempted.append(victim)
                if victim is req:
                    break
        return preempted


__all__ = ["Request", "PrefillChunk", "Scheduler",
           "QUEUED", "PREFILLING", "RUNNING", "FINISHED"]
