"""Continuous-batching scheduler: admit and retire every decode step.

Pure host-side bookkeeping (no jax): the scheduler owns the slot array,
the request queue, and the block accounting; the ``Server`` executes the
plan it produces.  Policies:

* **Iteration-level scheduling** -- finished requests release their
  slot + blocks at the top of every step and queued requests are
  admitted into freed slots *in the same step* (no waves, no padding
  rows decoding garbage: idle slots are masked to the scratch block).
* **Chunked prefill** -- admitted requests stream their prompt in
  fixed-size chunks, at most ``prefill_per_step`` chunks per iteration
  while decode is active (long prompts never stall token emission);
  when nothing is decoding, the full idle capacity prefills.
* **Out-of-blocks preemption** -- when a running request cannot get a
  block to grow its context, the latest-admitted active request is
  preempted vLLM-recompute-style: its blocks are freed and it is
  re-queued at the front with ``prompt + generated`` as the new prompt
  context.  Sampling keys are per (request, position), so the replay
  reuses the keys of the original run: greedy replays are token-exact;
  stochastic replays match up to the fp32-level agreement between the
  prefill and decode attention paths (a draw sitting exactly on a
  categorical boundary could differ).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, List, Optional, Tuple

import numpy as np

from repro.serving.blocks import BlockAllocator, BlockTable
from repro.serving.sampling import SamplingParams

QUEUED = "queued"
PREFILLING = "prefilling"
RUNNING = "running"
FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request.

    The leading fields match the legacy ``launch.serve.Request`` wire
    format (rid, prompt, max_new_tokens, out, done); the rest is
    scheduler-managed runtime state.
    """
    rid: int
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    sampling: SamplingParams = SamplingParams()
    soft_emb: Optional[Any] = None      # [1, n_soft, D] vision embeddings

    state: str = QUEUED
    table: Optional[BlockTable] = None
    ctx_len: int = 0                    # positions in cache (incl. soft)
    prefilled: int = 0                  # replay tokens already cached
    arrival_t: float = 0.0
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    admit_step: Optional[int] = None
    finish_step: Optional[int] = None
    _admit_seq: int = -1

    @property
    def n_soft(self) -> int:
        return 0 if self.soft_emb is None else int(self.soft_emb.shape[1])

    @property
    def replay_tokens(self) -> np.ndarray:
        """Prompt context to (re)prefill: prompt plus anything already
        generated (recompute-style preemption resumes through here)."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out, np.int32)])


@dataclasses.dataclass(frozen=True)
class PrefillChunk:
    req: Request
    start: int      # offset into replay_tokens
    length: int     # valid tokens this chunk (<= prefill_chunk)


class Scheduler:
    def __init__(self, batch_size: int, allocator: BlockAllocator,
                 max_blocks_per_seq: int, prefill_chunk: int,
                 prefill_per_step: int = 1):
        self.batch_size = batch_size
        self.allocator = allocator
        self.max_blocks_per_seq = max_blocks_per_seq
        self.prefill_chunk = prefill_chunk
        self.prefill_per_step = prefill_per_step
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: Deque[Request] = deque()
        self._admit_seq = 0

    # ------------------------------------------------------------------ #
    def validate(self, req: Request) -> None:
        if len(req.prompt) == 0:
            # the first sampled token comes from the last *token*
            # position of the prefill; an empty prompt has none
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens < 1")
        total = req.n_soft + len(req.prompt) + req.max_new_tokens
        max_tokens = self.max_blocks_per_seq * self.allocator.block_size
        if total > max_tokens:
            raise ValueError(
                f"request {req.rid}: {total} tokens exceeds max_len "
                f"{max_tokens}")
        if self.allocator.blocks_for(total) > self.allocator.capacity:
            raise ValueError(
                f"request {req.rid}: needs "
                f"{self.allocator.blocks_for(total)} blocks, pool has "
                f"{self.allocator.capacity}")

    def submit(self, req: Request, now: Optional[float] = None) -> None:
        self.validate(req)
        req.arrival_t = time.monotonic() if now is None else now
        req.state = QUEUED
        self.queue.append(req)

    # ------------------------------------------------------------------ #
    def has_work(self) -> bool:
        return bool(self.queue) or any(self.slots)

    def active(self) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def running(self) -> List[Tuple[int, Request]]:
        """Decodable rows: RUNNING and not already done (a request can
        finish at prefill time and must not decode before retiring)."""
        return [(i, r) for i, r in self.active()
                if r.state == RUNNING and not r.done]

    def any_running(self) -> bool:
        return bool(self.running())

    def context_lens(self) -> List[int]:
        return [r.ctx_len for _, r in self.active()]

    # ------------------------------------------------------------------ #
    def retire_finished(self) -> List[Request]:
        """Free slots + blocks of done requests (called every step)."""
        out = []
        for i, req in enumerate(self.slots):
            if req is not None and req.done:
                req.table.release()
                req.state = FINISHED
                self.slots[i] = None
                out.append(req)
        return out

    def admit(self, step: int) -> List[Request]:
        """FCFS-fill free slots from the queue; all-or-nothing block
        grants keep admission atomic.  Stops at the first request that
        does not fit (no starvation of large requests)."""
        admitted = []
        for i in range(self.batch_size):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue[0]
            table = BlockTable(self.allocator)
            need = self.allocator.blocks_for(
                req.n_soft + len(req.replay_tokens))
            if not table.grow(max(need, 1)):
                break
            self.queue.popleft()
            req.table = table
            req.state = PREFILLING
            req.ctx_len = 0
            req.prefilled = 0
            req.admit_step = step if req.admit_step is None else \
                req.admit_step
            req._admit_seq = self._admit_seq
            self._admit_seq += 1
            self.slots[i] = req
            admitted.append(req)
        return admitted

    def prefill_plan(self) -> List[PrefillChunk]:
        """Next prompt chunks: ``prefill_per_step`` while decode is
        live, otherwise the whole idle batch prefills."""
        budget = (self.prefill_per_step if self.any_running()
                  else self.batch_size)
        plan = []
        pref = [r for _, r in self.active() if r.state == PREFILLING]
        pref.sort(key=lambda r: r._admit_seq)
        for req in pref[:budget]:
            replay = req.replay_tokens
            n = min(self.prefill_chunk, len(replay) - req.prefilled)
            plan.append(PrefillChunk(req, req.prefilled, n))
        return plan

    # ------------------------------------------------------------------ #
    def _preempt(self, req: Request) -> None:
        """Recompute-style: drop the cache, re-queue at the front."""
        req.table.release()
        req.table = None
        req.state = QUEUED
        req.ctx_len = 0
        req.prefilled = 0
        for i, r in enumerate(self.slots):
            if r is req:
                self.slots[i] = None
        self.queue.appendleft(req)

    def grow_for_decode(self) -> List[Request]:
        """Ensure every running request has a slot for its next token,
        preempting the latest-admitted active request on exhaustion."""
        preempted = []
        for _, req in self.running():
            # an earlier row's growth may have preempted this one
            # (state left RUNNING only while it still owns its slot)
            while req.state == RUNNING and not req.done and \
                    not req.table.ensure_capacity(req.ctx_len + 1):
                # done-but-unretired requests are not preemptible: a
                # replay would generate past max_new_tokens (their
                # blocks free at the next retire anyway)
                victims = [r for _, r in self.active()
                           if r.state in (PREFILLING, RUNNING)
                           and not r.done]
                victim = max(victims, key=lambda r: r._admit_seq)
                self._preempt(victim)
                preempted.append(victim)
                if victim is req:
                    break
        return preempted


__all__ = ["Request", "PrefillChunk", "Scheduler",
           "QUEUED", "PREFILLING", "RUNNING", "FINISHED"]
