"""The serving engine: paged KV cache + continuous batching over the
CollectiveEngine.

``ContinuousBatchingServer`` glues the host-side ``Scheduler`` to the
jitted paged model entry points (``repro.models.paged``):

* one jitted **prefill-chunk** program ([1, chunk] tokens, so every
  prompt length reuses the same executable),
* one jitted **decode** program over the full slot array ([B, 1]),
  idle slots masked to the scratch block,
* one jitted **sample(+gather)** program.

Data-parallel serving (``mesh=``) stripes the slot rows over the DP
axis.  Every host-side scheduling decision needs the *global* token
vector, so per-shard sampled tokens are assembled with the
CollectiveEngine's cached model-driven allgather -- the serve path
generates real per-step collective traffic through the same dispatch
layer as gradient sync (no bare ``jax.lax`` collectives anywhere in
this package).  Sampling keys travel with the rows (per
(request, position) ids), so DP and single-device serving emit
identical tokens.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.collectives.api import get_engine
from repro.models.paged import (copy_blocks, decode_step_paged,
                                forward_paged, init_pages, supports_paged)
from repro.serving.blocks import BlockAllocator
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import (PrefillChunk, Request, Scheduler,
                                     RUNNING)
from repro.serving.telemetry import Telemetry, TelemetrySnapshot

#: sample-id stride per request; bounds max_new_tokens per request.
#: ids wrap modulo 2^31 (int32 PRNG fold-in data), so key reuse across
#: requests is possible every 2^31/stride rids -- a statistical, not a
#: correctness, concern (determinism only needs ids to be a pure
#: function of (rid, position))
_SAMPLE_STRIDE = 1 << 20
_SAMPLE_MOD = 1 << 31


@dataclasses.dataclass(frozen=True)
class StepOutcome:
    """What one scheduler iteration did (the unit a fleet drives in
    lockstep waves)."""
    finished: Dict[int, List[int]]      # rid -> tokens retired this step
    decoded: bool                       # a decode batch launched
    progressed: bool                    # False = nothing active (drained)


class ContinuousBatchingServer:
    """Paged-cache continuous-batching server over the functional
    model API (the legacy ``BatchedServer`` constructor signature)."""

    def __init__(self, cfg, params, batch_size: int, max_len: int,
                 seed: int = 0, mesh: Optional[Mesh] = None,
                 dp_axis: str = "data", engine=None, *,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 prefill_chunk: int = 32, prefill_per_step: int = 1,
                 top_k: int = 0, use_kernel: Optional[bool] = None,
                 prefix_cache: bool = True):
        if not supports_paged(cfg):
            raise NotImplementedError(
                f"serving supports dense/moe decoder families, not "
                f"{cfg.family!r}")
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.max_blocks_per_seq = -(-max_len // block_size)
        if num_blocks is None:
            num_blocks = batch_size * self.max_blocks_per_seq + 1
        self.allocator = BlockAllocator(num_blocks, block_size)
        self.prefix_cache = (PrefixCache(self.allocator) if prefix_cache
                             else None)
        self.scheduler = Scheduler(batch_size, self.allocator,
                                   self.max_blocks_per_seq, prefill_chunk,
                                   prefill_per_step,
                                   prefix_cache=self.prefix_cache)
        self.telemetry = Telemetry()
        self.top_k = top_k          # default for requests with top_k=0
        self.key = jax.random.PRNGKey(seed)
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        self._step = 0

        self.mesh = mesh
        self.dp_axis = dp_axis
        self._engine = engine
        self.pages = init_pages(cfg, num_blocks, block_size)
        if mesh is not None:
            if batch_size % mesh.shape[dp_axis] != 0:
                raise ValueError(
                    f"batch {batch_size} not divisible by dp axis "
                    f"{mesh.shape[dp_axis]}")
            self._engine = engine or get_engine()
            self._row_sharding = NamedSharding(mesh, P(dp_axis))
            # replicate the block pool across the DP shards up front so
            # every program runs on the mesh from the first call
            self.pages = jax.device_put(self.pages, NamedSharding(mesh, P()))

        key = self.key

        def _prefill(params, pages, tokens, bt, ctx, new_len, soft=None):
            batch = {"tokens": tokens}
            if soft is not None:
                batch["soft_emb"] = soft
            return forward_paged(params, cfg, pages, batch, bt, ctx,
                                 new_len, use_kernel=False)

        # the page pool is dead after each call (run() reassigns it), so
        # donate it where the backend supports donation -- decode then
        # updates the cache in place instead of copying the whole pool
        donate = (1,) if jax.default_backend() != "cpu" else ()
        self._prefill_fn = jax.jit(_prefill, donate_argnums=donate)
        # copy-on-write pool copies (whole blocks src -> dst); donated
        # like the other pool-threading programs
        self._copy_fn = jax.jit(copy_blocks,
                                donate_argnums=(0,) if donate else ())
        self._decode_fn = jax.jit(
            lambda p, pg, t, b, c: decode_step_paged(
                p, cfg, pg, {"tokens": t}, b, c, use_kernel=use_kernel),
            donate_argnums=donate)
        self._sample_fn = jax.jit(
            lambda lg, sid, tmp, tk: sample_tokens(lg, sid, tmp, key, tk))
        # batches with no top-k row skip the cutoff sort (trace-time 0)
        self._sample_notopk_fn = jax.jit(
            lambda lg, sid, tmp: sample_tokens(lg, sid, tmp, key, 0))
        # all-greedy batches (the common case) skip the sampling math
        greedy = lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32)
        self._greedy_fn = jax.jit(greedy)
        self._sample_gather_fn = None
        self._sample_notopk_gather_fn = None
        self._greedy_gather_fn = None
        self.assembly_decision = None
        self.assembly_regime = None
        if mesh is not None:
            eng = self._engine
            # Decode token assembly gathers one int32 per sequence over
            # the DP axis -- a few hundred bytes, firmly below the
            # latency/bandwidth crossover.  Precompute the planner's
            # decision once so operators can see which side of the
            # crossover the serving hot path landed on; the engine
            # stamps the same choice on every span as ``regime=``.
            n_dp = mesh.shape[dp_axis]
            dec = eng.select("allgather", batch_size * 4, n_dp,
                             fabric=eng.topology.for_axis(dp_axis))
            self.assembly_decision = dec
            self.assembly_regime = ("latency" if dec.algorithm == "oneshot"
                                    else "bandwidth")

            def _gathered(fn):
                # per-shard tokens assembled by the engine's cached
                # model-driven allgather
                def local(lg, *rest):
                    return eng.allgather_inside(fn(lg, *rest), dp_axis)
                return local

            row_specs = (P(dp_axis),) * 4
            self._sample_gather_fn = jax.jit(shard_map(
                _gathered(lambda lg, sid, tmp, tk:
                          sample_tokens(lg, sid, tmp, key, tk)),
                mesh=mesh, in_specs=row_specs, out_specs=P(),
                check_rep=False))
            self._sample_notopk_gather_fn = jax.jit(shard_map(
                _gathered(lambda lg, sid, tmp:
                          sample_tokens(lg, sid, tmp, key, 0)),
                mesh=mesh, in_specs=row_specs[:3], out_specs=P(),
                check_rep=False))
            self._greedy_gather_fn = jax.jit(shard_map(
                _gathered(greedy), mesh=mesh, in_specs=P(dp_axis),
                out_specs=P(), check_rep=False))

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req, now=self.telemetry.now())
        self.telemetry.record_submit()

    def snapshot(self) -> TelemetrySnapshot:
        return self.telemetry.snapshot(
            queue_depth=len(self.scheduler.queue),
            active=len(self.scheduler.active()),
            allocator=self.allocator,
            block_usage=self.scheduler.block_usage())

    # ------------------------------------------------------------------ #
    def _sample_rows(self, logits: jax.Array, reqs: List[Request],
                     rows: List[int], gathered: bool) -> np.ndarray:
        """logits [B, V] -> host tokens [B]; per-(request, position)
        keys make the result independent of slot placement and DP."""
        b = logits.shape[0]
        sids = np.zeros((b,), np.int32)
        temps = np.zeros((b,), np.float32)
        topks = np.zeros((b,), np.int32)
        for row, req in zip(rows, reqs):
            sids[row] = (req.rid * _SAMPLE_STRIDE
                         + len(req.out)) % _SAMPLE_MOD
            temps[row] = req.sampling.temperature
            topks[row] = req.sampling.top_k or self.top_k
        if not np.any(temps > 0):       # all-greedy hot path: argmax only
            fn = self._greedy_gather_fn if gathered else self._greedy_fn
            return np.asarray(fn(logits))
        if not np.any(topks > 0):       # no cutoff sort needed
            fn = (self._sample_notopk_gather_fn if gathered
                  else self._sample_notopk_fn)
            return np.asarray(fn(logits, jnp.asarray(sids),
                                 jnp.asarray(temps)))
        fn = self._sample_gather_fn if gathered else self._sample_fn
        return np.asarray(fn(logits, jnp.asarray(sids), jnp.asarray(temps),
                             jnp.asarray(topks)))

    def _append_token(self, req: Request, token: int) -> None:
        req.out.append(int(token))
        self.telemetry.record_tokens(1)
        if req.first_token_t is None:
            req.first_token_t = self.telemetry.now()
            self.telemetry.record_first_token(req.arrival_t)
        if len(req.out) >= req.max_new_tokens:
            req.done = True
            req.finish_t = self.telemetry.now()
            req.finish_step = self._step
            self.telemetry.record_finish()

    # ------------------------------------------------------------------ #
    def _run_prefill_chunk(self, chunk: PrefillChunk) -> None:
        req, start, n = chunk.req, chunk.start, chunk.length
        replay = req.replay_tokens
        tokens = np.zeros((1, self.prefill_chunk), np.int32)
        tokens[0, :n] = replay[start:start + n]
        bt = np.zeros((1, self.max_blocks_per_seq), np.int32)
        bt[0, :len(req.table.blocks)] = req.table.blocks
        ctx = np.asarray([req.ctx_len], np.int32)
        new_len = np.asarray([n], np.int32)
        if start == 0 and req.soft_emb is not None:
            logits, self.pages = self._prefill_fn(
                self.params, self.pages, tokens, bt, ctx, new_len,
                req.soft_emb)
            req.ctx_len += req.n_soft
        else:
            logits, self.pages = self._prefill_fn(
                self.params, self.pages, tokens, bt, ctx, new_len)
        req.prefilled += n
        req.ctx_len += n
        self.telemetry.record_prefill_tokens(n)
        self.scheduler.note_prefilled(req)
        if req.prefilled == len(replay):
            # prompt fully cached: the chunk's last valid position
            # yields this request's next token (its first, unless it
            # was preempted mid-decode and replayed)
            req.state = RUNNING
            tok = self._sample_rows(logits[:, n - 1], [req], [0],
                                    gathered=False)
            self._append_token(req, int(tok[0]))

    def _run_decode(self) -> None:
        running = self.scheduler.running()
        rows = [i for i, _ in running]
        reqs = [r for _, r in running]
        tokens = np.zeros((self.batch, 1), np.int32)
        bt = np.zeros((self.batch, self.max_blocks_per_seq), np.int32)
        ctx = np.zeros((self.batch,), np.int32)
        for i, req in running:
            tokens[i, 0] = req.out[-1]
            bt[i, :len(req.table.blocks)] = req.table.blocks
            ctx[i] = req.ctx_len
        args = [jnp.asarray(tokens), jnp.asarray(bt), jnp.asarray(ctx)]
        if self.mesh is not None:
            args = [jax.device_put(a, self._row_sharding) for a in args]
        logits, self.pages = self._decode_fn(self.params, self.pages, *args)
        toks = self._sample_rows(logits[:, 0], reqs, rows,
                                 gathered=self.mesh is not None)
        for i, req in running:
            req.ctx_len += 1
            self._append_token(req, int(toks[i]))

    # ------------------------------------------------------------------ #
    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def step_once(self) -> StepOutcome:
        """One scheduler iteration: retire, admit, apply CoW copies,
        prefill chunks, decode (at most one batch).  The unit
        ``run()`` loops over and a fleet drives in lockstep waves; a
        stalled scheduler (queued work that can never be admitted)
        raises."""
        results: Dict[int, List[int]] = {}
        for req in self.scheduler.retire_finished():
            results[req.rid] = req.out
        now = self.telemetry.now()
        for req in self.scheduler.admit(self._step):
            self.telemetry.record_queue_wait(now - req.arrival_t)
            if req.cached_prefix_tokens:
                self.telemetry.record_cached_prefix(
                    req.cached_prefix_tokens)
        cows = self.scheduler.drain_cow_copies()
        if cows:
            # private replacements for shared blocks about to be
            # written; must land before this step's prefill chunks
            src = jnp.asarray([s for s, _ in cows], jnp.int32)
            dst = jnp.asarray([d for _, d in cows], jnp.int32)
            self.pages = self._copy_fn(self.pages, src, dst)
        if not self.scheduler.active():
            if self.scheduler.queue:
                raise RuntimeError(
                    "serving stalled: queued request cannot be "
                    "admitted (KV block pool too small?)")
            return StepOutcome(results, decoded=False, progressed=False)
        plan = self.scheduler.prefill_plan()
        for chunk in plan:
            self._run_prefill_chunk(chunk)
        decoded = False
        if self.scheduler.any_running():
            for _ in self.scheduler.grow_for_decode():
                self.telemetry.record_preemption()
            if self.scheduler.any_running():
                self._run_decode()
                decoded = True
        self.telemetry.record_step(decoded=decoded,
                                   prefill_chunks=len(plan),
                                   kv_occupancy=self.allocator.occupancy,
                                   queue_depth=len(self.scheduler.queue))
        self._step += 1
        if not plan and not decoded and not any(
                r.done for r in self.scheduler.slots if r):
            raise RuntimeError("scheduler made no progress")
        return StepOutcome(results, decoded=decoded, progressed=True)

    def run(self, max_steps: Optional[int] = None) -> Dict[int, List[int]]:
        """Serve until queue + slots drain, or ``max_steps`` decode
        iterations when given (default: drain -- total decode work is
        bounded by the submitted max_new_tokens, and a stalled scheduler
        raises).  Returns {rid: generated tokens} (partial outputs
        included when a step budget ends first)."""
        results: Dict[int, List[int]] = {}
        decode_steps = 0
        if max_steps is None:
            max_steps = float("inf")
        while self.scheduler.has_work():
            out = self.step_once()
            results.update(out.finished)
            if not out.progressed:
                break       # drained
            decode_steps += int(out.decoded)
            if decode_steps >= max_steps:
                break
        for req in self.scheduler.retire_finished():
            results[req.rid] = req.out
        # step budget exhausted: report partial generations
        for _, req in self.scheduler.active():
            results.setdefault(req.rid, req.out)
        for req in self.scheduler.queue:
            results.setdefault(req.rid, req.out)
        return results


__all__ = ["ContinuousBatchingServer", "Request", "StepOutcome"]
