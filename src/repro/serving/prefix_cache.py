"""Content-addressed prefix cache over the paged KV block pool.

Requests that share a prompt prefix (system prompts under multi-tenant
traffic) share the *physical* KV blocks holding it instead of
recomputing them: the block-table indirection already lets one physical
block appear in many logical tables, so sharing is pure host-side
bookkeeping -- no kernel or model change.

**Addressing.**  Block ``i`` of a token stream is addressed by a hash
chain at block granularity::

    key_i = H(key_{i-1}, token_ids[i*bs : (i+1)*bs])

so a key commits to the *entire* prefix through block ``i``, not just
the block's own tokens -- two streams sharing key_i share every token
up to ``(i+1)*bs``.  Only *full* blocks are cacheable: a partial tail
block is still mutable (decode appends into it) and is never shared.

**Lifecycle.**  ``match`` walks the chain and returns the longest run
of resident blocks, taking one reference on each (reviving evictable
blocks).  ``insert`` registers a fully-written block under its key;
first writer wins -- a concurrent duplicate keeps its private copy
uncached.  When a block's refcount drops to zero it parks on the
allocator's evictable LRU (content retained) and is reclaimed only
under pool pressure; the allocator's evict hook removes the mapping
here, so the map never dangles.  Evicting a chain-interior block
orphans its descendants (the chain walk stops early); they age out of
the LRU naturally.

Vision requests (``soft_emb``) are not cached: their prefix content is
not a pure function of token ids.
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Sequence

import numpy as np

from repro.serving.blocks import BlockAllocator

#: chain seed; bump when the key schema changes
_CHAIN_SEED = b"repro-prefix-cache-v1"


def chain_keys(tokens: Sequence[int], block_size: int) -> List[bytes]:
    """Hash-chain keys for every *full* block of ``tokens``."""
    keys: List[bytes] = []
    prev = _CHAIN_SEED
    toks = np.asarray(tokens, np.int32)
    for i in range(len(toks) // block_size):
        h = hashlib.blake2b(prev, digest_size=16)
        h.update(toks[i * block_size:(i + 1) * block_size].tobytes())
        prev = h.digest()
        keys.append(prev)
    return keys


class PrefixCache:
    """key -> physical block map over a refcounted ``BlockAllocator``."""

    def __init__(self, allocator: BlockAllocator):
        self.allocator = allocator
        self.block_size = allocator.block_size
        self._map: Dict[bytes, int] = {}
        allocator.evict_hook = self._on_evict
        self.hits = 0           # blocks served from cache
        self.misses = 0         # chain lookups that stopped the walk
        self.inserts = 0

    def __len__(self) -> int:
        return len(self._map)

    @property
    def evictions(self) -> int:
        return self.allocator.evictions

    def _on_evict(self, block: int, key: bytes) -> None:
        # eviction reclaims the block for new content: drop the mapping
        # (the block may have been re-inserted under a newer key since,
        # so only remove an exact match)
        if self._map.get(key) == block:
            del self._map[key]

    def keys_for(self, tokens: Sequence[int]) -> List[bytes]:
        return chain_keys(tokens, self.block_size)

    def probe(self, keys: Sequence[bytes]) -> int:
        """Resident-prefix length in *blocks* without taking references
        or touching the hit/miss counters -- the read-only prediction a
        fleet router uses to score replicas.  A block counted here may
        still be evicted before the request lands (the prediction is a
        routing hint, not a reservation)."""
        n = 0
        for key in keys:
            if key not in self._map:
                break
            n += 1
        return n

    def match(self, keys: Sequence[bytes]) -> List[int]:
        """Longest cached prefix of the key chain; every returned block
        has one reference taken on behalf of the caller (so a
        concurrent admission cannot evict it)."""
        out: List[int] = []
        for key in keys:
            blk = self._map.get(key)
            if blk is None:
                self.misses += 1
                break
            self.allocator.ref(blk)
            out.append(blk)
        self.hits += len(out)
        return out

    def insert(self, key: bytes, block: int) -> bool:
        """Register a fully-written live block under ``key``.  Returns
        False (leaving the block private) when the key is already
        mapped -- first writer wins."""
        if key in self._map:
            return False
        self._map[key] = block
        self.allocator.register_cached(block, key)
        self.inserts += 1
        return True


__all__ = ["PrefixCache", "chain_keys"]
