"""Multi-replica serving fleet: telemetry-driven routing + admission.

Components (see README "Serving fleet"):

* ``replica``   -- one ``ContinuousBatchingServer`` + its cheap
                   ``load_signal()`` (queue depth, live/evictable KV
                   split, in-flight prefill tokens, TTFT EWMA)
* ``router``    -- pluggable deterministic policies: ``round_robin``,
                   ``least_queue``, ``cost`` (modeled admission cost
                   for the uncached suffix), ``prefix_affinity``
* ``admission`` -- fleet queue cap with reject + retry-after, and
                   per-tenant token-bucket rate limiting (wave-clocked)
* ``fleet``     -- ``FleetServer`` lockstep orchestration
                   (submit -> route -> step -> drain) + registry export
* ``trace``     -- wave-stamped arrival generation (fixed / poisson /
                   bursty MMPP), shared with the benches and the CLI
"""

from repro.serving.fleet.admission import (REJECT_QUEUE_FULL,
                                           REJECT_RATE_LIMITED,
                                           AdmissionConfig,
                                           AdmissionController, Rejection)
from repro.serving.fleet.fleet import (DEFAULT_TENANT, FleetServer,
                                       FleetSnapshot, export_fleet_stats)
from repro.serving.fleet.replica import LoadSignal, Replica
from repro.serving.fleet.router import (ROUTER_POLICIES, CostRouter,
                                        LeastQueueRouter,
                                        PrefixAffinityRouter,
                                        RoundRobinRouter, Router,
                                        make_router)
from repro.serving.fleet.trace import ARRIVAL_MODES, arrival_waves

__all__ = [
    "ARRIVAL_MODES", "AdmissionConfig", "AdmissionController",
    "CostRouter", "DEFAULT_TENANT", "FleetServer", "FleetSnapshot",
    "LeastQueueRouter", "LoadSignal", "PrefixAffinityRouter",
    "REJECT_QUEUE_FULL", "REJECT_RATE_LIMITED",
    "ROUTER_POLICIES", "Rejection", "Replica", "RoundRobinRouter",
    "Router", "arrival_waves", "export_fleet_stats", "make_router",
]
