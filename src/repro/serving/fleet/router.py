"""Pluggable fleet routing policies.

Every policy is a deterministic pure function of the request and the
replicas' load signals (ties resolve to the lowest replica index), so a
fleet run is replayable and the bench counters gate bitwise:

* ``round_robin``     -- replica-oblivious cycling; the baseline the
                         model-driven policies must beat.
* ``least_queue``     -- send the request where the least prefill
                         compute is already committed (queued +
                         in-flight prompt tokens, backlog tie-break);
                         bounds per-replica prefill imbalance.
* ``cost``            -- score each replica by *modeled admission
                         cost*: the roofline-priced prefill seconds for
                         the request's **uncached suffix** on that
                         replica (hash-chain probe of its prefix cache
                         predicts the cached prefix length) plus the
                         prefill seconds already committed there.  The
                         serving-layer analogue of the paper's
                         model-driven algorithm selection: dispatch on
                         predicted cost, not a blind heuristic.
* ``prefix_affinity`` -- pin each hash-chain prefix (tenant / shared
                         system prompt) to the replica holding its
                         blocks, so the fleet-wide cached-token
                         fraction approaches the single-replica one
                         instead of diluting 1/N under oblivious
                         routing.  Falls back to least-committed-work
                         for never-seen prefixes and records the pin.

``make_router(policy, cfg)`` builds one; policies are stateful (the
round-robin cursor, the affinity pin map) but never consult wall
clocks or RNGs.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.launch.roofline import PEAK_FLOPS
from repro.serving.fleet.replica import LoadSignal, Replica
from repro.serving.scheduler import Request


def _argmin(scores: Sequence[float]) -> int:
    """Lowest-index argmin (deterministic tie-break)."""
    best = 0
    for i, s in enumerate(scores):
        if s < scores[best]:
            best = i
    return best


class Router:
    """Base policy: ``route`` returns the target replica index."""

    name = "base"

    def route(self, req: Request, replicas: List[Replica],
              signals: List[LoadSignal]) -> int:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._next = 0

    def route(self, req, replicas, signals) -> int:
        i = self._next % len(replicas)
        self._next += 1
        return i


class LeastQueueRouter(Router):
    name = "least_queue"

    def route(self, req, replicas, signals) -> int:
        return _argmin([(s.pending_prefill_tokens, s.backlog, s.replica)
                        for s in signals])


class CostRouter(Router):
    """Modeled admission cost in seconds, per replica::

        cost(r) = p_tok * (uncached_suffix_tokens(req, r)
                           + pending_prefill_tokens(r))

    with ``p_tok = 2 * active_params / PEAK_FLOPS`` (the roofline
    inference-compute price per token).  The uncached suffix is
    predicted from the replica's prefix cache by probing the request's
    hash-chain keys -- the same content addressing admission will use,
    so the prediction only errs when blocks are evicted in between.
    """

    name = "cost"

    def __init__(self, cfg):
        self.price_per_token_s = 2.0 * cfg.active_param_count() / PEAK_FLOPS
        #: modeled cost of each routed request (seconds), for telemetry
        self.last_costs: List[float] = []

    def admission_cost_s(self, req: Request, replica: Replica,
                         signal: LoadSignal,
                         keys: Optional[List[bytes]] = None) -> float:
        cached = replica.predicted_cached_tokens(req.prompt, keys)
        uncached = max(len(req.prompt) - cached, 0)
        return self.price_per_token_s * (
            uncached + signal.pending_prefill_tokens)

    def route(self, req, replicas, signals) -> int:
        keys = replicas[0].chain_keys(req.prompt)
        costs = [self.admission_cost_s(req, r, s, keys)
                 for r, s in zip(replicas, signals)]
        self.last_costs = costs
        return _argmin([(c, s.replica) for c, s in zip(costs, signals)])


class PrefixAffinityRouter(Router):
    """Route a hash-chain prefix to the replica that owns its blocks.

    The pin is keyed by the *first* chain key (one full block of
    prompt), so every request opening with the same system prompt lands
    on the same replica even while the first one is still queued and
    nothing is inserted in the cache yet -- the burst case oblivious
    routing loses.  Unpinned prefixes go to the replica with the
    longest predicted cached run (if any), else to the least committed
    prefill work; either way the choice is recorded as the pin.
    """

    name = "prefix_affinity"

    def __init__(self):
        self._pin: Dict[bytes, int] = {}

    def route(self, req, replicas, signals) -> int:
        keys = replicas[0].chain_keys(req.prompt)
        pin_key = keys[0] if keys else None
        if pin_key is not None:
            pinned = self._pin.get(pin_key)
            if pinned is not None and pinned < len(replicas):
                return pinned
        cached = [r.predicted_cached_tokens(req.prompt, keys)
                  for r in replicas]
        if max(cached, default=0) > 0:
            choice = _argmin([(-c, s.replica)
                              for c, s in zip(cached, signals)])
        else:
            choice = _argmin([(s.pending_prefill_tokens, s.backlog,
                               s.replica) for s in signals])
        if pin_key is not None:
            self._pin[pin_key] = choice
        return choice


ROUTER_POLICIES = ("round_robin", "least_queue", "cost", "prefix_affinity")


def make_router(policy: str, cfg=None) -> Router:
    """Build a router by policy name (``cfg`` required for ``cost``)."""
    if policy == "round_robin":
        return RoundRobinRouter()
    if policy == "least_queue":
        return LeastQueueRouter()
    if policy == "cost":
        if cfg is None:
            raise ValueError("cost router needs the model config to "
                             "price prefill compute")
        return CostRouter(cfg)
    if policy == "prefix_affinity":
        return PrefixAffinityRouter()
    raise ValueError(f"unknown router policy {policy!r}; "
                     f"choose from {ROUTER_POLICIES}")


__all__ = ["Router", "RoundRobinRouter", "LeastQueueRouter", "CostRouter",
           "PrefixAffinityRouter", "ROUTER_POLICIES", "make_router"]
