"""Arrival-time generation for serving traces.

ROADMAP: "benchmark under skewed, bursty multi-tenant traces, not
uniform arrivals".  This module owns the arrival clock -- in *waves*
(fleet scheduler iterations), the logical time base that keeps every
downstream counter deterministic for a fixed seed:

* ``fixed``   -- everything arrives at wave 0 (the legacy
                 submit-all-up-front behavior committed baselines
                 assume).
* ``poisson`` -- independent arrivals at ``rate`` requests/wave.
* ``bursty``  -- a 2-state Markov-modulated Poisson process: a calm
                 state at ``rate`` and a burst state at
                 ``burst_factor * rate``, switching with geometric
                 dwell times.  Bursts are what make admission control
                 and telemetry-driven routing earn their keep; a plain
                 Poisson stream rarely fills a queue cap.

Shared by ``benchmarks/serve_bench.py`` / ``benchmarks/fleet_bench.py``
(via ``make_trace(arrival=...)``) and the ``launch/serve.py``
``--arrival`` flag.  Draws come from a dedicated ``numpy`` Generator so
the prompt-content RNG stream of existing traces is untouched (fixed
baselines stay green).
"""

from __future__ import annotations

from typing import List

import numpy as np

ARRIVAL_MODES = ("fixed", "poisson", "bursty")


def arrival_waves(n: int, mode: str = "fixed", *,
                  rng: np.random.Generator = None,
                  rate: float = 2.0, burst_factor: float = 8.0,
                  p_enter_burst: float = 0.1,
                  p_exit_burst: float = 0.3) -> List[int]:
    """Non-decreasing arrival waves for ``n`` requests.

    ``rate`` is the calm-state mean arrivals per wave; ``bursty`` mode
    multiplies it by ``burst_factor`` while in the burst state and
    switches states with the given per-wave probabilities (mean dwell
    ``1/p``).  Requests are assigned to waves in submission order.
    """
    if mode not in ARRIVAL_MODES:
        raise ValueError(f"unknown arrival mode {mode!r}; choose from "
                         f"{ARRIVAL_MODES}")
    if mode == "fixed" or n == 0:
        return [0] * n
    if rng is None:
        raise ValueError(f"arrival mode {mode!r} needs a seeded rng")
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    waves: List[int] = []
    wave = 0
    burst = False
    while len(waves) < n:
        lam = rate * (burst_factor if burst else 1.0)
        k = int(rng.poisson(lam))
        waves.extend([wave] * min(k, n - len(waves)))
        if mode == "bursty":
            if burst:
                burst = rng.random() >= p_exit_burst
            else:
                burst = rng.random() < p_enter_burst
        wave += 1
    return waves


__all__ = ["ARRIVAL_MODES", "arrival_waves"]
