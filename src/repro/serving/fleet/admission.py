"""Fleet admission control: queue cap + per-tenant token buckets.

Backpressure lives at the front end, before routing: a request the
fleet cannot absorb is **rejected with a retry-after hint** instead of
growing an unbounded queue (graceful shed under burst).  Two gates:

* **Fleet queue cap** -- when the fleet-wide *queued* depth (requests
  waiting for a slot, summed over replicas; admitted work is already
  paid for) has reached ``queue_cap``, new arrivals are shed.  The
  retry-after hint is the number of admissions that must happen before
  the depth drops below the cap -- in waves, the fleet's logical
  clock, so the hint is deterministic for a fixed trace.
* **Per-tenant token bucket** -- each tenant refills ``tenant_rate``
  tokens per wave up to a burst capacity; a request costs its prompt
  plus requested output tokens.  A tenant bursting past its budget is
  rejected with the waves-until-refill hint while other tenants keep
  being admitted (per-tenant isolation, not global shed).

Both clocks are *waves* (scheduler iterations), not wall time: the
controller's decisions replay bitwise for a fixed trace, which is what
lets ``fleet_bench`` gate "zero rejects below the cap" as a
deterministic counter (``rejected_below_cap``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.serving.scheduler import Request

REJECT_QUEUE_FULL = "queue_full"
REJECT_RATE_LIMITED = "rate_limited"


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    #: max fleet-wide queued (not yet admitted) requests; None = uncapped
    queue_cap: Optional[int] = None
    #: token-bucket refill per tenant per wave (prompt + output tokens);
    #: None disables rate limiting
    tenant_rate: Optional[float] = None
    #: bucket capacity (burst allowance); defaults to 8x the rate
    tenant_burst: Optional[float] = None

    def burst(self) -> float:
        if self.tenant_burst is not None:
            return self.tenant_burst
        return 8.0 * (self.tenant_rate or 0.0)


@dataclasses.dataclass(frozen=True)
class Rejection:
    rid: int
    tenant: str
    reason: str                 # REJECT_QUEUE_FULL | REJECT_RATE_LIMITED
    retry_after_waves: int      # earliest wave offset worth retrying at
    wave: int                   # when the rejection happened


class AdmissionController:
    """Wave-clocked backpressure in front of the router."""

    def __init__(self, config: AdmissionConfig):
        self.config = config
        self._level: Dict[str, float] = {}      # tenant -> bucket level
        self._last_wave: Dict[str, int] = {}
        self.rejections: List[Rejection] = []
        self.admitted = 0
        self.rejected_by_reason: Dict[str, int] = {
            REJECT_QUEUE_FULL: 0, REJECT_RATE_LIMITED: 0}
        #: queue-full rejections issued while the fleet queue was below
        #: the cap.  Structurally zero -- the gate only fires at
        #: ``depth >= cap`` -- but exported and benched as a counter so
        #: the "rejections only above the cap" contract is *asserted*,
        #: not assumed.
        self.rejected_below_cap = 0

    # ------------------------------------------------------------------ #
    @staticmethod
    def request_tokens(req: Request) -> float:
        """What a request costs against its tenant's budget."""
        return float(len(req.prompt) + req.max_new_tokens)

    def _bucket(self, tenant: str, wave: int) -> float:
        cfg = self.config
        level = self._level.get(tenant, cfg.burst())
        delta = wave - self._last_wave.get(tenant, wave)
        if delta > 0 and cfg.tenant_rate:
            level = min(cfg.burst(), level + cfg.tenant_rate * delta)
        self._level[tenant] = level
        self._last_wave[tenant] = wave
        return level

    def _reject(self, req: Request, tenant: str, reason: str,
                retry_after: int, wave: int,
                fleet_queue_depth: int) -> Rejection:
        rej = Rejection(req.rid, tenant, reason,
                        retry_after_waves=retry_after, wave=wave)
        self.rejections.append(rej)
        self.rejected_by_reason[reason] += 1
        # audit the shed contract: with a cap configured and headroom
        # left, nothing should be shed (token-bucket rejections count
        # too when rate limiting is off -- the bench runs it that way)
        if self.config.queue_cap is not None and \
                fleet_queue_depth < self.config.queue_cap:
            self.rejected_below_cap += 1
        return rej

    # ------------------------------------------------------------------ #
    def admit(self, req: Request, tenant: str, *, fleet_queue_depth: int,
              wave: int) -> Optional[Rejection]:
        """Gate one arrival.  Returns None on admit (tenant budget
        deducted) or the :class:`Rejection` to surface to the client."""
        cfg = self.config
        if cfg.queue_cap is not None and \
                fleet_queue_depth >= cfg.queue_cap:
            # hint: admissions needed before depth drops below the cap
            retry = fleet_queue_depth - cfg.queue_cap + 1
            return self._reject(req, tenant, REJECT_QUEUE_FULL,
                                retry, wave, fleet_queue_depth)
        if cfg.tenant_rate:
            cost = self.request_tokens(req)
            level = self._bucket(tenant, wave)
            if level < cost:
                retry = math.ceil((cost - level) / cfg.tenant_rate)
                return self._reject(req, tenant, REJECT_RATE_LIMITED,
                                    retry, wave, fleet_queue_depth)
            self._level[tenant] = level - cost
        self.admitted += 1
        return None

    @property
    def rejected(self) -> int:
        return len(self.rejections)


__all__ = ["AdmissionConfig", "AdmissionController", "Rejection",
           "REJECT_QUEUE_FULL", "REJECT_RATE_LIMITED"]
