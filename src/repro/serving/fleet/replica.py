"""One fleet member: a ``ContinuousBatchingServer`` plus the cheap
load signal the router scores it by.

``load_signal()`` is pure host-side bookkeeping over state the server
already maintains (scheduler queue/slots, block allocator counters,
telemetry sample lists) -- no new per-step work is added to the serving
loop.  The TTFT EWMA folds in only the samples recorded since the last
call, so repeated polling stays O(new samples).

``predicted_cached_tokens()`` probes the replica's prefix cache with
the request's hash-chain keys *without* taking references: it is the
router's estimate of how much prefill compute this replica would skip,
not a reservation (the blocks can still be evicted before admission).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.scheduler import PREFILLING, Request
from repro.serving.server import ContinuousBatchingServer, StepOutcome

#: EWMA weight of a new TTFT sample (~ last 10 samples dominate).
TTFT_EWMA_ALPHA = 0.2


@dataclasses.dataclass(frozen=True)
class LoadSignal:
    """Point-in-time routing view of one replica (all cheap reads)."""
    replica: int
    queue_depth: int                # requests waiting for a slot
    active: int                     # requests holding a slot
    running: int                    # rows decoding this wave
    queued_prefill_tokens: int      # prompt tokens waiting in the queue
    inflight_prefill_tokens: int    # admitted but not yet prefilled
    kv_blocks_live: int             # refcount >= 1 (true load)
    kv_blocks_evictable: int        # refcount-0 cached (reclaimable)
    kv_blocks_free: int
    ttft_ewma_s: Optional[float]    # None until a first token lands
    queue_wait_p50_ms: Optional[float]

    @property
    def pending_prefill_tokens(self) -> int:
        """Prefill compute already committed to this replica."""
        return self.queued_prefill_tokens + self.inflight_prefill_tokens

    @property
    def backlog(self) -> int:
        """Requests this replica owes work to (queued + active)."""
        return self.queue_depth + self.active


class Replica:
    """Wraps one ``ContinuousBatchingServer`` for fleet membership."""

    def __init__(self, index: int, server: ContinuousBatchingServer):
        self.index = index
        self.server = server
        self._ttft_ewma: Optional[float] = None
        self._ttft_seen = 0

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        self.server.submit(req)

    def has_work(self) -> bool:
        return self.server.has_work()

    def step(self) -> StepOutcome:
        return self.server.step_once()

    def results(self) -> Dict[int, List[int]]:
        """Drain-time partials (mirrors the tail of ``server.run``)."""
        out: Dict[int, List[int]] = {}
        for req in self.server.scheduler.retire_finished():
            out[req.rid] = req.out
        for _, req in self.server.scheduler.active():
            out.setdefault(req.rid, req.out)
        for req in self.server.scheduler.queue:
            out.setdefault(req.rid, req.out)
        return out

    # ------------------------------------------------------------------ #
    def _fold_ttft(self) -> Optional[float]:
        samples = self.server.telemetry.ttft_s
        for x in samples[self._ttft_seen:]:
            self._ttft_ewma = (x if self._ttft_ewma is None else
                               (1 - TTFT_EWMA_ALPHA) * self._ttft_ewma
                               + TTFT_EWMA_ALPHA * x)
        self._ttft_seen = len(samples)
        return self._ttft_ewma

    def load_signal(self) -> LoadSignal:
        sched = self.server.scheduler
        alloc = self.server.allocator
        tel = self.server.telemetry
        active = sched.active()
        inflight = sum(
            len(r.replay_tokens) - r.prefilled
            for _, r in active if r.state == PREFILLING)
        qwait = tel.queue_wait_s
        return LoadSignal(
            replica=self.index,
            queue_depth=len(sched.queue),
            active=len(active),
            running=len(sched.running()),
            queued_prefill_tokens=sum(
                len(r.replay_tokens) for r in sched.queue),
            inflight_prefill_tokens=inflight,
            kv_blocks_live=alloc.num_used,
            kv_blocks_evictable=alloc.num_evictable,
            kv_blocks_free=alloc.num_free,
            ttft_ewma_s=self._fold_ttft(),
            queue_wait_p50_ms=(float(np.percentile(qwait, 50)) * 1e3
                               if qwait else None),
        )

    # ------------------------------------------------------------------ #
    def chain_keys(self, prompt: Sequence[int]) -> List[bytes]:
        cache = self.server.prefix_cache
        if cache is None:
            return []
        return cache.keys_for(np.asarray(prompt, np.int32))

    def predicted_cached_tokens(self, prompt: Sequence[int],
                                keys: Optional[List[bytes]] = None) -> int:
        """Prompt tokens this replica would serve from its prefix
        cache if the request were admitted right now (0 without a
        cache).  ``keys`` short-circuits rehashing when the caller
        already chained them (block size is fleet-uniform)."""
        cache = self.server.prefix_cache
        if cache is None:
            return 0
        if keys is None:
            keys = self.chain_keys(prompt)
        return cache.probe(keys) * cache.block_size


__all__ = ["LoadSignal", "Replica", "TTFT_EWMA_ALPHA"]
