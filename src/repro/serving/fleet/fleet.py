"""``FleetServer``: N serving replicas in lockstep waves behind a
telemetry-driven router with admission control.

The scale-out rung above one ``ContinuousBatchingServer``: a front-end
that **admits** (queue cap + per-tenant token buckets,
``admission.py``), **routes** (pluggable policies over each replica's
``load_signal()``, ``router.py``), and **steps** every replica one
scheduler iteration per wave (``Replica.step``, the extracted
``server.step_once``).  Waves are the fleet's logical clock: replicas
are stepped in index order, routing is a pure function of load
signals, and arrival times are wave-stamped -- so a fixed trace
replays bitwise and every fleet counter can gate in CI.

Determinism contract: with greedy sampling, per-request token streams
are **bitwise identical across fleet sizes** under any deterministic
routing policy -- each slot row's logits depend only on its own paged
context and sampling keys are per ``(rid, position)``, so *where* a
request lands (replica, slot, batch neighbors) never changes *what* it
generates.  ``tests/test_fleet.py`` asserts ``--replicas 1`` vs N.

Metrics flow through ``repro.obs.registry``: per-replica gauges carry
a ``replica`` label, rejection counters a ``tenant`` label (which is
why label-value escaping in the exposition format matters).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple, Union

from repro.serving.fleet.admission import (AdmissionConfig,
                                           AdmissionController, Rejection)
from repro.serving.fleet.replica import Replica
from repro.serving.fleet.router import Router, make_router
from repro.serving.scheduler import Request
from repro.serving.server import ContinuousBatchingServer
from repro.serving.telemetry import TelemetrySnapshot

DEFAULT_TENANT = "default"


@dataclasses.dataclass(frozen=True)
class FleetSnapshot:
    """Fleet-aggregate view + the per-replica snapshots behind it."""
    waves: int
    n_replicas: int
    replicas: Tuple[TelemetrySnapshot, ...]
    routed: Tuple[int, ...]             # requests sent to each replica
    submitted: int                      # offered to admission
    admitted: int
    rejected: int
    rejected_by_reason: Dict[str, int]
    rejected_below_cap: int
    # fleet-wide prefix-cache effectiveness (the tentpole headline:
    # affinity routing keeps this near the single-replica fraction)
    prefill_tokens_computed: int
    cached_prefix_tokens: int
    cached_token_fraction: float
    tokens_out: int
    queue_depth_max: Tuple[int, ...]    # per replica, over the history


class FleetServer:
    """Drive N ``ContinuousBatchingServer`` replicas in lockstep."""

    def __init__(self, cfg, params, n_replicas: int, batch_size: int,
                 max_len: int, *, router: Union[str, Router] = "round_robin",
                 admission: Optional[AdmissionConfig] = None,
                 seed: int = 0, mesh=None, dp_axis: str = "data",
                 engine=None, **server_kw):
        if n_replicas < 1:
            raise ValueError(f"need >= 1 replica, got {n_replicas}")
        self.cfg = cfg
        # every replica gets the same seed: sampling keys are per
        # (rid, position) off the server key, so a request draws the
        # same tokens whichever replica it lands on (the fleet-size
        # determinism contract)
        self.replicas = [
            Replica(i, ContinuousBatchingServer(
                cfg, params, batch_size, max_len, seed=seed, mesh=mesh,
                dp_axis=dp_axis, engine=engine, **server_kw))
            for i in range(n_replicas)]
        self.router = (router if isinstance(router, Router)
                       else make_router(router, cfg))
        self.admission = AdmissionController(admission or AdmissionConfig())
        self.wave = 0
        self.submitted = 0
        self.tokens_out: Dict[int, int] = {}
        self.routed = [0] * n_replicas
        self.routed_replica: Dict[int, int] = {}    # rid -> replica

    # ------------------------------------------------------------------ #
    def fleet_queue_depth(self) -> int:
        """Requests waiting for a slot, fleet-wide (what the admission
        cap bounds; admitted in-flight work is not re-counted)."""
        return sum(len(r.server.scheduler.queue) for r in self.replicas)

    def submit(self, req: Request, tenant: str = DEFAULT_TENANT
               ) -> Optional[Rejection]:
        """Admit -> route -> enqueue one request.  Returns None when
        accepted, else the :class:`Rejection` (with its retry-after
        hint in waves) -- the request was *not* enqueued."""
        self.submitted += 1
        rej = self.admission.admit(
            req, tenant, fleet_queue_depth=self.fleet_queue_depth(),
            wave=self.wave)
        if rej is not None:
            return rej
        signals = [r.load_signal() for r in self.replicas]
        i = self.router.route(req, self.replicas, signals)
        if not 0 <= i < len(self.replicas):
            raise ValueError(f"router {self.router.name!r} returned "
                             f"replica {i} of {len(self.replicas)}")
        self.replicas[i].submit(req)
        self.routed[i] += 1
        self.routed_replica[req.rid] = i
        return None

    # ------------------------------------------------------------------ #
    def run_wave(self) -> Dict[int, List[int]]:
        """Step every replica one scheduler iteration (index order)
        and advance the wave clock.  Returns requests finished this
        wave ({rid: tokens})."""
        finished: Dict[int, List[int]] = {}
        for rep in self.replicas:
            if rep.has_work():
                finished.update(rep.step().finished)
        self.wave += 1
        return finished

    def has_work(self) -> bool:
        return any(r.has_work() for r in self.replicas)

    def run(self, max_waves: Optional[int] = None) -> Dict[int, List[int]]:
        """Drain every replica (or stop after ``max_waves``); returns
        {rid: generated tokens} including partials at a wave budget."""
        results: Dict[int, List[int]] = {}
        if max_waves is None:
            max_waves = float("inf")
        waves = 0
        while self.has_work() and waves < max_waves:
            results.update(self.run_wave())
            waves += 1
        for rep in self.replicas:
            for rid, toks in rep.results().items():
                results.setdefault(rid, toks)
        return results

    def run_trace(self, arrivals: Iterable[Tuple[int, str, Request]],
                  max_waves: Optional[int] = None
                  ) -> Tuple[Dict[int, List[int]], List[Rejection]]:
        """Serve a wave-stamped arrival trace: ``(wave, tenant,
        request)`` triples in non-decreasing wave order.  Each wave
        first submits everything due, then steps the fleet; idle waves
        (drained replicas, future arrivals) still tick the clock.
        Returns (results, rejections)."""
        pending = deque(sorted(arrivals, key=lambda a: a[0]))
        results: Dict[int, List[int]] = {}
        rejections: List[Rejection] = []
        if max_waves is None:
            max_waves = float("inf")
        waves = 0
        while (pending or self.has_work()) and waves < max_waves:
            while pending and pending[0][0] <= self.wave:
                _, tenant, req = pending.popleft()
                rej = self.submit(req, tenant)
                if rej is not None:
                    rejections.append(rej)
            results.update(self.run_wave())
            waves += 1
        for rep in self.replicas:
            for rid, toks in rep.results().items():
                results.setdefault(rid, toks)
        return results, rejections

    # ------------------------------------------------------------------ #
    def snapshot(self) -> FleetSnapshot:
        snaps = tuple(r.server.snapshot() for r in self.replicas)
        computed = sum(s.prefill_tokens_computed for s in snaps)
        cached = sum(s.cached_prefix_tokens for s in snaps)
        total = computed + cached
        return FleetSnapshot(
            waves=self.wave,
            n_replicas=len(self.replicas),
            replicas=snaps,
            routed=tuple(self.routed),
            submitted=self.submitted,
            admitted=self.admission.admitted,
            rejected=self.admission.rejected,
            rejected_by_reason=dict(self.admission.rejected_by_reason),
            rejected_below_cap=self.admission.rejected_below_cap,
            prefill_tokens_computed=computed,
            cached_prefix_tokens=cached,
            cached_token_fraction=(cached / total if total else 0.0),
            tokens_out=sum(s.tokens_out for s in snaps),
            queue_depth_max=tuple(s.queue_depth_max for s in snaps),
        )


def export_fleet_stats(fleet: FleetServer, registry=None):
    """Mirror a fleet's aggregate + per-replica state into a
    :class:`repro.obs.MetricsRegistry` (the process-wide one by
    default).  Per-replica gauges carry a ``replica`` label; rejection
    counts a ``tenant`` label (tenant ids are label values -- the
    exposition escaping path).  Returns the registry."""
    from repro.obs import registry as obs_registry
    from repro.serving.telemetry import export_to_registry
    reg = registry if registry is not None else obs_registry.REGISTRY
    snap = fleet.snapshot()

    def g(name, value, help_, labels=None):
        if value is None:
            return
        reg.gauge(name, labels=labels, help=help_).set(float(value))

    g("fleet_waves", snap.waves, "lockstep waves driven")
    g("fleet_replicas", snap.n_replicas, "serving replicas")
    g("fleet_submitted", snap.submitted, "requests offered to admission")
    g("fleet_admitted", snap.admitted, "requests past admission control")
    g("fleet_rejected", snap.rejected, "requests shed by admission")
    g("fleet_rejected_below_cap", snap.rejected_below_cap,
      "rejections issued with queue headroom left (contract: 0)")
    g("fleet_tokens_out", snap.tokens_out, "tokens generated fleet-wide")
    g("fleet_prefill_tokens_computed", snap.prefill_tokens_computed,
      "prompt tokens computed fleet-wide")
    g("fleet_cached_prefix_tokens", snap.cached_prefix_tokens,
      "prompt tokens served from prefix caches fleet-wide")
    g("fleet_cached_token_fraction", snap.cached_token_fraction,
      "fleet-wide cached / (cached + computed) prefill tokens")
    for reason, n in sorted(snap.rejected_by_reason.items()):
        g("fleet_rejected_by_reason", n, "rejections per reason",
          labels={"reason": reason})
    by_tenant: Dict[str, int] = {}
    for rej in fleet.admission.rejections:
        by_tenant[rej.tenant] = by_tenant.get(rej.tenant, 0) + 1
    for tenant, n in sorted(by_tenant.items()):
        g("fleet_rejected_by_tenant", n, "rejections per tenant",
          labels={"tenant": tenant})
    for i, s in enumerate(snap.replicas):
        export_to_registry(s, reg, prefix=f"fleet_replica_{i}")
        g("fleet_routed", snap.routed[i], "requests routed per replica",
          labels={"replica": str(i)})
        g("fleet_replica_queue_depth_max", s.queue_depth_max,
          "max queued depth per replica", labels={"replica": str(i)})
    return reg


__all__ = ["DEFAULT_TENANT", "FleetServer", "FleetSnapshot",
           "export_fleet_stats"]
