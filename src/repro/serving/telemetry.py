"""Serving telemetry: TTFT, throughput, queue depth, KV occupancy.

The server records events as they happen; ``snapshot()`` freezes them
into an immutable dataclass (the thing a metrics exporter would ship).
Percentiles are computed at snapshot time from the raw TTFT samples.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TelemetrySnapshot:
    """Point-in-time view of the serving loop."""
    elapsed_s: float
    steps: int                  # scheduler iterations
    decode_steps: int
    prefill_chunks: int
    submitted: int
    finished: int
    preemptions: int
    queue_depth: int
    active: int
    tokens_out: int
    tok_per_s: float            # generated tokens / elapsed
    ttft_p50_ms: Optional[float]
    ttft_p99_ms: Optional[float]
    kv_blocks_total: int
    kv_blocks_used: int
    kv_occupancy: float
    kv_peak_occupancy: float
    kv_internal_frag_slots: int
    ttft_samples: int = 0       # how many TTFTs back the percentiles
    # -- prefix cache ------------------------------------------------- #
    # live = refcount >= 1 (true load); evictable = refcount-0 cached
    # blocks kept resident (cache pressure, reclaimable on demand)
    kv_blocks_live: int = 0
    kv_blocks_evictable: int = 0
    prefill_tokens_computed: int = 0
    cached_prefix_tokens: int = 0
    cached_token_fraction: float = 0.0
    prefix_evictions: int = 0
    # -- admission / router signals ----------------------------------- #
    # submit -> admit latency percentiles (the head-of-line wait a
    # router's load signal should see, not just instantaneous depth)
    queue_wait_p50_ms: Optional[float] = None
    queue_wait_samples: int = 0
    # per-step queued-depth history rollups (deterministic for a fixed
    # trace: steps are logical, not wall clock)
    queue_depth_max: int = 0
    queue_depth_history: Tuple[int, ...] = ()


class Telemetry:
    """Mutable collector behind the snapshot."""

    def __init__(self, clock=time.monotonic):
        self._clock = clock
        self.t0 = clock()
        self.steps = 0
        self.decode_steps = 0
        self.prefill_chunks = 0
        self.submitted = 0
        self.finished = 0
        self.preemptions = 0
        self.tokens_out = 0
        self.prefill_tokens_computed = 0
        self.cached_prefix_tokens = 0
        self.peak_kv_occupancy = 0.0
        self.ttft_s: List[float] = []
        self.queue_wait_s: List[float] = []
        self.queue_depth_history: List[int] = []

    def record_submit(self) -> None:
        self.submitted += 1

    def record_first_token(self, arrival_t: float) -> None:
        self.ttft_s.append(self._clock() - arrival_t)

    def record_tokens(self, n: int) -> None:
        self.tokens_out += n

    def record_prefill_tokens(self, n: int) -> None:
        """Prompt tokens actually computed by a prefill chunk."""
        self.prefill_tokens_computed += n

    def record_cached_prefix(self, n: int) -> None:
        """Prompt tokens served from the prefix cache at admission."""
        self.cached_prefix_tokens += n

    def record_finish(self) -> None:
        self.finished += 1

    def record_preemption(self) -> None:
        self.preemptions += 1

    def record_queue_wait(self, wait_s: float) -> None:
        """Submit -> admit latency of one admitted request."""
        self.queue_wait_s.append(wait_s)

    def record_step(self, *, decoded: bool, prefill_chunks: int,
                    kv_occupancy: float = 0.0,
                    queue_depth: Optional[int] = None) -> None:
        self.steps += 1
        self.decode_steps += int(decoded)
        self.prefill_chunks += prefill_chunks
        self.peak_kv_occupancy = max(self.peak_kv_occupancy, kv_occupancy)
        if queue_depth is not None:
            self.queue_depth_history.append(int(queue_depth))

    def now(self) -> float:
        return self._clock()

    def snapshot(self, *, queue_depth: int, active: int, allocator,
                 block_usage: List) -> TelemetrySnapshot:
        elapsed = max(self._clock() - self.t0, 1e-9)
        ttft = np.asarray(self.ttft_s, np.float64)
        qwait = np.asarray(self.queue_wait_s, np.float64)
        prefill_total = self.prefill_tokens_computed + \
            self.cached_prefix_tokens
        return TelemetrySnapshot(
            elapsed_s=elapsed,
            steps=self.steps,
            decode_steps=self.decode_steps,
            prefill_chunks=self.prefill_chunks,
            submitted=self.submitted,
            finished=self.finished,
            preemptions=self.preemptions,
            queue_depth=queue_depth,
            active=active,
            tokens_out=self.tokens_out,
            tok_per_s=self.tokens_out / elapsed,
            ttft_p50_ms=(float(np.percentile(ttft, 50)) * 1e3
                         if ttft.size else None),
            ttft_p99_ms=(float(np.percentile(ttft, 99)) * 1e3
                         if ttft.size else None),
            ttft_samples=int(ttft.size),
            kv_blocks_total=allocator.capacity,
            kv_blocks_used=allocator.num_used,
            kv_occupancy=allocator.occupancy,
            kv_peak_occupancy=max(self.peak_kv_occupancy,
                                  allocator.occupancy),
            kv_internal_frag_slots=allocator.internal_fragmentation(
                block_usage),
            kv_blocks_live=allocator.num_used,
            kv_blocks_evictable=allocator.num_evictable,
            prefill_tokens_computed=self.prefill_tokens_computed,
            cached_prefix_tokens=self.cached_prefix_tokens,
            cached_token_fraction=(self.cached_prefix_tokens /
                                   prefill_total if prefill_total else 0.0),
            prefix_evictions=allocator.evictions,
            queue_wait_p50_ms=(float(np.percentile(qwait, 50)) * 1e3
                               if qwait.size else None),
            queue_wait_samples=int(qwait.size),
            queue_depth_max=(max(self.queue_depth_history)
                             if self.queue_depth_history else 0),
            queue_depth_history=tuple(self.queue_depth_history),
        )


#: below this many TTFT samples the percentiles are statistically
#: shaky; exporters keep them but mark them low-confidence.
TTFT_LOW_CONFIDENCE = 20


def ttft_low_confidence(snap: TelemetrySnapshot) -> bool:
    """True when the snapshot's TTFT percentiles rest on too few
    samples to trust (fewer than :data:`TTFT_LOW_CONFIDENCE`)."""
    return snap.ttft_samples < TTFT_LOW_CONFIDENCE


def export_to_registry(snap: TelemetrySnapshot, registry=None,
                       prefix: str = "serve"):
    """Mirror a snapshot into a :class:`repro.obs.MetricsRegistry`
    (the process-wide one by default).  Returns the registry.

    Percentile gauges are exported alongside ``{prefix}_ttft_samples``
    and a 0/1 ``{prefix}_ttft_low_confidence`` flag rather than being
    suppressed -- consumers decide what a thin sample base means."""
    from repro.obs import registry as obs_registry
    reg = registry if registry is not None else obs_registry.REGISTRY

    def g(name: str, value, help_: str) -> None:
        if value is None:
            return
        reg.gauge(f"{prefix}_{name}", help=help_).set(float(value))

    g("elapsed_s", snap.elapsed_s, "serving loop wall time")
    g("steps", snap.steps, "scheduler iterations")
    g("decode_steps", snap.decode_steps, "decode batches launched")
    g("prefill_chunks", snap.prefill_chunks, "prefill chunks executed")
    g("submitted", snap.submitted, "requests submitted")
    g("finished", snap.finished, "requests finished")
    g("preemptions", snap.preemptions, "requests preempted")
    g("queue_depth", snap.queue_depth, "requests waiting")
    g("active", snap.active, "requests in flight")
    g("tokens_out", snap.tokens_out, "tokens generated")
    g("tok_per_s", snap.tok_per_s, "generation throughput")
    g("ttft_p50_ms", snap.ttft_p50_ms, "median time to first token")
    g("ttft_p99_ms", snap.ttft_p99_ms, "p99 time to first token")
    g("ttft_samples", snap.ttft_samples,
      "TTFT observations behind the percentiles")
    g("ttft_low_confidence", int(ttft_low_confidence(snap)),
      f"1 when ttft_samples < {TTFT_LOW_CONFIDENCE}")
    g("kv_blocks_total", snap.kv_blocks_total, "KV pool capacity")
    g("kv_blocks_used", snap.kv_blocks_used, "KV blocks in use")
    g("kv_occupancy", snap.kv_occupancy, "KV pool occupancy")
    g("kv_peak_occupancy", snap.kv_peak_occupancy,
      "peak KV pool occupancy")
    g("kv_internal_frag_slots", snap.kv_internal_frag_slots,
      "slots lost to block-internal fragmentation")
    g("kv_blocks_live", snap.kv_blocks_live,
      "KV blocks referenced by live requests (true load)")
    g("kv_blocks_evictable", snap.kv_blocks_evictable,
      "refcount-0 cached KV blocks resident until pool pressure")
    g("prefill_tokens_computed", snap.prefill_tokens_computed,
      "prompt tokens actually computed in prefill")
    g("cached_prefix_tokens", snap.cached_prefix_tokens,
      "prompt tokens served from the prefix cache")
    g("cached_token_fraction", snap.cached_token_fraction,
      "cached / (cached + computed) prefill tokens")
    g("prefix_evictions", snap.prefix_evictions,
      "cached blocks reclaimed under pool pressure")
    g("queue_wait_p50_ms", snap.queue_wait_p50_ms,
      "median submit -> admit latency")
    g("queue_wait_samples", snap.queue_wait_samples,
      "admissions behind the queue-wait percentiles")
    g("queue_depth_max", snap.queue_depth_max,
      "max queued depth over the per-step history")
    return reg


__all__ = ["Telemetry", "TelemetrySnapshot", "TTFT_LOW_CONFIDENCE",
           "ttft_low_confidence", "export_to_registry"]
