"""Production serving subsystem: paged KV cache + continuous batching.

Components (see README "Serving"):

* ``blocks``       -- refcounted KV block allocator (evictable cached
                      tier) + per-request tables
* ``prefix_cache`` -- content-addressed (hash-chained) block sharing
                      across requests with a common prompt prefix
* ``sampling``     -- greedy / temperature / top-k token sampling
* ``scheduler``    -- per-step admit/retire, chunked prefill,
                      preemption, prefix matching + copy-on-write
* ``server``       -- jitted paged-model execution; DP token assembly
                      through the CollectiveEngine
* ``telemetry``    -- TTFT / tok/s / queue depth / KV occupancy
                      (live vs evictable) / cached-token snapshots
"""

from repro.serving.blocks import BlockAllocator, BlockTable
from repro.serving.prefix_cache import PrefixCache, chain_keys
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import PrefillChunk, Request, Scheduler
from repro.serving.server import ContinuousBatchingServer
from repro.serving.telemetry import Telemetry, TelemetrySnapshot

__all__ = [
    "BlockAllocator", "BlockTable", "ContinuousBatchingServer",
    "PrefillChunk", "PrefixCache", "Request", "SamplingParams",
    "Scheduler", "Telemetry", "TelemetrySnapshot", "chain_keys",
    "sample_tokens",
]
