"""Production serving subsystem: paged KV cache + continuous batching.

Components (see README "Serving"):

* ``blocks``    -- fixed-size KV block allocator + per-request tables
* ``sampling``  -- greedy / temperature / top-k token sampling
* ``scheduler`` -- per-step admit/retire, chunked prefill, preemption
* ``server``    -- jitted paged-model execution; DP token assembly
                   through the CollectiveEngine
* ``telemetry`` -- TTFT / tok/s / queue depth / KV occupancy snapshots
"""

from repro.serving.blocks import BlockAllocator, BlockTable
from repro.serving.sampling import SamplingParams, sample_tokens
from repro.serving.scheduler import PrefillChunk, Request, Scheduler
from repro.serving.server import ContinuousBatchingServer
from repro.serving.telemetry import Telemetry, TelemetrySnapshot

__all__ = [
    "BlockAllocator", "BlockTable", "ContinuousBatchingServer",
    "PrefillChunk", "Request", "SamplingParams", "Scheduler",
    "Telemetry", "TelemetrySnapshot", "sample_tokens",
]
