"""Fixed-size KV block allocator.

The physical cache (``repro.models.paged.init_pages``) is a pool of
``num_blocks`` blocks of ``block_size`` token slots each.  The allocator
hands out block ids; per-request ownership is a ``BlockTable`` (the
logical-order id list the model indexes with).  Block 0 is reserved as
the scratch sink for writes from padded/inactive rows and is never
allocated.

Allocation is all-or-nothing (``alloc(n)`` returns ``None`` when fewer
than n blocks are free) so the scheduler can make admit/preempt
decisions atomically.  Blocks are fixed-size, so there is no external
fragmentation; the only waste is *internal* (tail slots of a request's
last block), reported by ``internal_fragmentation``.
"""

from __future__ import annotations

from typing import List, Optional

RESERVED_BLOCKS = 1     # block 0: scratch sink for invalid writes


class BlockAllocator:
    """LIFO free-list over the physical block pool."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < RESERVED_BLOCKS + 1:
            raise ValueError(f"need > {RESERVED_BLOCKS} blocks, "
                             f"got {num_blocks}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO keeps recently-freed (cache-warm) blocks hot
        self._free: List[int] = list(range(num_blocks - 1,
                                           RESERVED_BLOCKS - 1, -1))
        self._used: set[int] = set()

    @property
    def capacity(self) -> int:
        """Allocatable blocks (pool minus the scratch block)."""
        return self.num_blocks - RESERVED_BLOCKS

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._used)

    @property
    def occupancy(self) -> float:
        return self.num_used / max(1, self.capacity)

    def blocks_for(self, num_tokens: int) -> int:
        return -(-max(num_tokens, 0) // self.block_size)

    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """n block ids, or None if fewer than n are free (no partial
        grants)."""
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        self._used.update(out)
        return out

    def free(self, blocks: List[int]) -> None:
        for blk in blocks:
            if blk not in self._used:
                raise ValueError(f"double free or foreign block {blk}")
            self._used.remove(blk)
            self._free.append(blk)

    def internal_fragmentation(self, context_lens: List[int]) -> int:
        """Allocated-but-unused token slots, given each live request's
        context length (assumes minimal block counts)."""
        waste = 0
        for n in context_lens:
            waste += self.blocks_for(n) * self.block_size - n
        return waste


class BlockTable:
    """One request's logical-order block ids."""

    def __init__(self, allocator: BlockAllocator):
        self._alloc = allocator
        self.blocks: List[int] = []

    @property
    def num_slots(self) -> int:
        return len(self.blocks) * self._alloc.block_size

    def grow(self, n_blocks: int) -> bool:
        got = self._alloc.alloc(n_blocks)
        if got is None:
            return False
        self.blocks.extend(got)
        return True

    def ensure_capacity(self, num_tokens: int) -> bool:
        need = self._alloc.blocks_for(num_tokens) - len(self.blocks)
        return need <= 0 or self.grow(need)

    def release(self) -> None:
        if self.blocks:
            self._alloc.free(self.blocks)
            self.blocks = []


__all__ = ["RESERVED_BLOCKS", "BlockAllocator", "BlockTable"]
