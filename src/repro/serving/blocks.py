"""Fixed-size KV block allocator with refcounted, evictable blocks.

The physical cache (``repro.models.paged.init_pages``) is a pool of
``num_blocks`` blocks of ``block_size`` token slots each.  The allocator
hands out block ids; per-request ownership is a ``BlockTable`` (the
logical-order id list the model indexes with).  Block 0 is reserved as
the scratch sink for writes from padded/inactive rows and is never
allocated.

Every granted block carries a **refcount** so one physical block can
appear in many logical tables (content-addressed prefix sharing,
``serving/prefix_cache.py``).  A block lives in exactly one of three
states:

* **live** -- refcount >= 1, referenced by at least one table;
* **evictable** -- refcount 0 but registered as holding cached prefix
  content (``register_cached``): it stays resident so a future request
  can revive it with ``ref``, and is reclaimed LRU-first only under
  pool pressure;
* **free** -- no content worth keeping.

Allocation is all-or-nothing over ``free + evictable`` (``alloc(n)``
returns ``None`` when fewer than n are reclaimable) so the scheduler
can make admit/preempt decisions atomically.  Blocks are fixed-size, so
there is no external fragmentation; the only waste is *internal* (tail
slots of a request's last block), reported by
``internal_fragmentation`` over *unique* physical blocks (a shared
prefix block's tail is counted once, not once per table).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

RESERVED_BLOCKS = 1     # block 0: scratch sink for invalid writes

#: one live request's block usage: (block ids in logical order, context
#: length in tokens).  A bare int is the legacy no-sharing form.
BlockUsage = Union[int, Tuple[List[int], int]]


class BlockAllocator:
    """Refcounted free-list over the physical block pool with an LRU
    evictable tier for refcount-0 cached blocks."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < RESERVED_BLOCKS + 1:
            raise ValueError(f"need > {RESERVED_BLOCKS} blocks, "
                             f"got {num_blocks}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO keeps recently-freed (cache-warm) blocks hot
        self._free: List[int] = list(range(num_blocks - 1,
                                           RESERVED_BLOCKS - 1, -1))
        self._used: set[int] = set()
        self._ref: Dict[int, int] = {}
        # refcount-0 cached blocks, LRU order (first = evict next);
        # value is the content key registered for the block
        self._evictable: "OrderedDict[int, bytes]" = OrderedDict()
        # content key while the block holds registered cache content
        # (live or evictable)
        self._cached_key: Dict[int, bytes] = {}
        #: called as hook(block, key) when an evictable block is
        #: reclaimed, so the prefix cache can drop its mapping
        self.evict_hook: Optional[Callable[[int, bytes], None]] = None
        self.evictions = 0

    @property
    def capacity(self) -> int:
        """Allocatable blocks (pool minus the scratch block)."""
        return self.num_blocks - RESERVED_BLOCKS

    @property
    def num_free(self) -> int:
        """Blocks with no retained content (excludes evictable)."""
        return len(self._free)

    @property
    def num_evictable(self) -> int:
        """Refcount-0 cached blocks resident until pool pressure."""
        return len(self._evictable)

    @property
    def num_available(self) -> int:
        """Blocks an ``alloc`` can grant: free plus evictable."""
        return len(self._free) + len(self._evictable)

    @property
    def num_used(self) -> int:
        """Live blocks (refcount >= 1)."""
        return len(self._used)

    @property
    def occupancy(self) -> float:
        return self.num_used / max(1, self.capacity)

    def blocks_for(self, num_tokens: int) -> int:
        return -(-max(num_tokens, 0) // self.block_size)

    # ------------------------------------------------------------------ #
    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """n fresh block ids at refcount 1, or None if fewer than n are
        reclaimable (no partial grants).  Free blocks are taken first;
        under pressure, evictable cached blocks are reclaimed LRU-first
        (``evict_hook`` fires per reclaimed block)."""
        if n < 0:
            raise ValueError(n)
        if n > self.num_available:
            return None
        out = []
        for _ in range(n):
            if self._free:
                blk = self._free.pop()
            else:
                blk, key = self._evictable.popitem(last=False)   # LRU
                del self._cached_key[blk]
                self.evictions += 1
                if self.evict_hook is not None:
                    self.evict_hook(blk, key)
            self._used.add(blk)
            self._ref[blk] = 1
            out.append(blk)
        return out

    def ref(self, block: int) -> None:
        """Add a reference: bump a live block, or revive an evictable
        cached block back to refcount 1 (content retained)."""
        if block in self._used:
            self._ref[block] += 1
        elif block in self._evictable:
            self._evictable.pop(block)
            self._used.add(block)
            self._ref[block] = 1
        else:
            raise ValueError(f"ref of unallocated block {block}")

    def decref(self, block: int) -> None:
        """Drop one reference.  At refcount 0 a cached block parks on
        the evictable LRU (most-recently-used end); an uncached block
        returns to the free list."""
        if block not in self._used:
            raise ValueError(f"double free or foreign block {block}")
        self._ref[block] -= 1
        if self._ref[block] > 0:
            return
        del self._ref[block]
        self._used.remove(block)
        key = self._cached_key.get(block)
        if key is not None:
            self._evictable[block] = key        # MRU end
        else:
            self._free.append(block)

    def free(self, blocks: Iterable[int]) -> None:
        """Release one reference per block (legacy bulk ``decref``)."""
        for blk in blocks:
            self.decref(blk)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    # ------------------------------------------------------------------ #
    def register_cached(self, block: int, key: bytes) -> None:
        """Mark a live block as holding immutable cached content
        addressed by ``key``; from now on refcount 0 parks it on the
        evictable LRU instead of the free list."""
        if block not in self._used:
            raise ValueError(f"register_cached of non-live block {block}")
        self._cached_key[block] = key

    def is_cached(self, block: int) -> bool:
        return block in self._cached_key

    def cached_key(self, block: int) -> Optional[bytes]:
        return self._cached_key.get(block)

    # ------------------------------------------------------------------ #
    def internal_fragmentation(self, usage: Iterable[BlockUsage]) -> int:
        """Allocated-but-unused token slots over *unique* physical
        blocks.

        Each entry is ``(block ids, context length)`` for one live
        request; a block referenced by several tables (shared prefix)
        counts its tail waste once, at the deepest fill any table gives
        it.  A bare int entry is the legacy no-sharing form (minimal
        block count assumed).
        """
        per_block: Dict[int, int] = {}
        waste = 0
        for item in usage:
            if isinstance(item, int):
                waste += self.blocks_for(item) * self.block_size - item
                continue
            blocks, n = item
            for j, blk in enumerate(blocks):
                toks = min(self.block_size, n - j * self.block_size)
                toks = max(toks, 0)
                per_block[blk] = max(per_block.get(blk, 0), toks)
        waste += sum(self.block_size - t for t in per_block.values())
        return waste


class BlockTable:
    """One request's logical-order block ids (each entry holds one
    reference; shared prefix blocks appear in many tables)."""

    def __init__(self, allocator: BlockAllocator):
        self._alloc = allocator
        self.blocks: List[int] = []

    @property
    def num_slots(self) -> int:
        return len(self.blocks) * self._alloc.block_size

    def grow(self, n_blocks: int) -> bool:
        got = self._alloc.alloc(n_blocks)
        if got is None:
            return False
        self.blocks.extend(got)
        return True

    def ensure_capacity(self, num_tokens: int) -> bool:
        need = self._alloc.blocks_for(num_tokens) - len(self.blocks)
        return need <= 0 or self.grow(need)

    def release(self) -> None:
        """Drop this table's references (``decref``, not free: shared
        prefix blocks survive their first owner, cached blocks park on
        the evictable LRU)."""
        if self.blocks:
            for blk in self.blocks:
                self._alloc.decref(blk)
            self.blocks = []


__all__ = ["RESERVED_BLOCKS", "BlockAllocator", "BlockTable",
           "BlockUsage"]
