"""Online predicted-vs-measured model-error monitoring.

The paper's methodological claim is that the cost model "predicts
performance with less than 4% error".  The repo checked that offline
(``benchmarks/table_model_error.py``); this module turns it into a
continuously monitored invariant: spans stream in, get binned by
``(op, topology, bytes-decile)``, and each bin tracks the rolling
relative error of the model's prediction against measured wall time.
A bin whose rolling error crosses the threshold (default 4%, the
paper's bound) raises a *drift* flag with the recommendation to rerun
``engine.calibrate()`` -- the model stopped describing the hardware.

Units.  Predictions are model cycles (the Fabric time base); measured
times are wall seconds.  The ratio between them is exactly what
``engine.calibrate()`` fits, so the monitor handles it the same way:
unless an explicit ``seconds_per_cycle`` is given, each bin *anchors*
its scale on the median measured/predicted ratio of its first
``min_samples`` observations, then scores later samples against that
anchor.  On a calibrated fabric the anchor matches the calibration and
the rolling error stays near zero; when the hardware drifts (or the
constants were never fitted), the error grows past the threshold and
the flag fires.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: the paper's model-error bound (Sec. 8: "less than 4% error")
DEFAULT_THRESHOLD = 0.04

BinKey = Tuple[str, str, int]


def bytes_decile(nbytes: int) -> int:
    """Decimal-decade bucket of a payload size: ``decile(B) =
    floor(log10 B)`` (0 for sub-10-byte payloads).  Sizes within one
    decade share launch/bandwidth regime closely enough to share a
    calibration anchor."""
    return max(0, int(math.log10(max(int(nbytes), 1))))


@dataclasses.dataclass
class ErrorBin:
    """Rolling predicted-vs-measured state for one (op, topo, decile)."""

    op: str
    topo: str
    decile: int
    min_samples: int
    window: int
    threshold: float
    seconds_per_cycle: Optional[float] = None
    n: int = 0
    anchor: Optional[float] = None          # fitted seconds per cycle
    _warmup: List[float] = dataclasses.field(default_factory=list)
    rel_errs: Deque[float] = dataclasses.field(default_factory=deque)

    def observe(self, predicted: float, measured_s: float) -> None:
        if predicted <= 0.0 or measured_s <= 0.0:
            return
        self.n += 1
        scale = self.seconds_per_cycle
        if scale is None:
            if self.anchor is None:
                # anchoring phase: collect ratios until the bin has
                # enough samples to fit its own time base
                self._warmup.append(measured_s / predicted)
                if len(self._warmup) >= self.min_samples:
                    self.anchor = float(np.median(self._warmup))
                    self._warmup.clear()
                return
            scale = self.anchor
        err = abs(scale * predicted - measured_s) / measured_s
        self.rel_errs.append(err)
        while len(self.rel_errs) > self.window:
            self.rel_errs.popleft()

    @property
    def scored(self) -> int:
        """Samples scored against a scale (post-anchor)."""
        return len(self.rel_errs)

    @property
    def rolling_error(self) -> Optional[float]:
        if not self.rel_errs:
            return None
        return float(np.mean(self.rel_errs))

    @property
    def drifted(self) -> bool:
        """True once the rolling error exceeds the threshold with
        enough scored samples to mean it."""
        err = self.rolling_error
        return (err is not None and self.scored >= self.min_samples
                and err > self.threshold)

    def as_dict(self) -> Dict[str, Any]:
        return {"op": self.op, "topo": self.topo, "decile": self.decile,
                "bytes_range": f"[1e{self.decile}, 1e{self.decile + 1})",
                "n": self.n, "scored": self.scored,
                "anchor_s_per_cycle": (self.seconds_per_cycle
                                       if self.seconds_per_cycle is not None
                                       else self.anchor),
                "rolling_error": self.rolling_error,
                "threshold": self.threshold,
                "drifted": self.drifted}


class ModelErrorMonitor:
    """Aggregates spans into per-(op, topology, bytes-decile) bins and
    flags drift past ``threshold``.

    ``seconds_per_cycle``: pass the known model-cycle duration (e.g.
    from a calibration fit) to score every sample directly; leave
    ``None`` to let each bin self-anchor on its first ``min_samples``
    observations (see module docstring).
    """

    def __init__(self, threshold: float = DEFAULT_THRESHOLD,
                 min_samples: int = 8, window: int = 64,
                 seconds_per_cycle: Optional[float] = None):
        self.threshold = threshold
        self.min_samples = min_samples
        self.window = window
        self.seconds_per_cycle = seconds_per_cycle
        self.bins: Dict[BinKey, ErrorBin] = {}
        self.observed = 0
        self.skipped = 0

    # ------------------------------------------------------------------ #
    def observe(self, op: str, topo: str, nbytes: int,
                predicted: float, measured_s: float) -> None:
        key = (op, topo, bytes_decile(nbytes))
        b = self.bins.get(key)
        if b is None:
            b = ErrorBin(op=op, topo=topo, decile=key[2],
                         min_samples=self.min_samples, window=self.window,
                         threshold=self.threshold,
                         seconds_per_cycle=self.seconds_per_cycle)
            self.bins[key] = b
        b.observe(predicted, measured_s)
        self.observed += 1

    def observe_span(self, span) -> bool:
        """Feed one collective span; returns False when the span lacks
        a usable (predicted, measured) pair."""
        args = getattr(span, "args", span)
        pred = args.get("predicted")
        meas = args.get("measured_s")
        if pred is None or meas is None or pred <= 0 or meas <= 0:
            self.skipped += 1
            return False
        axes = args.get("axis_sizes") or args.get("axes") or ()
        topo = "x".join(str(s) for s in axes) if not isinstance(
            axes, str) else axes
        self.observe(str(args.get("op", "?")), topo,
                     int(args.get("bytes", 0)), float(pred), float(meas))
        return True

    def observe_spans(self, spans: Sequence[Any]) -> int:
        """Feed many spans (collective-category only); returns how many
        were scored."""
        fed = 0
        for sp in spans:
            if getattr(sp, "cat", "collective") != "collective":
                continue
            fed += int(self.observe_span(sp))
        return fed

    # ------------------------------------------------------------------ #
    def drifted_bins(self) -> List[ErrorBin]:
        return [b for b in self.bins.values() if b.drifted]

    @property
    def should_recalibrate(self) -> bool:
        return bool(self.drifted_bins())

    def recommendation(self) -> Optional[str]:
        drifted = self.drifted_bins()
        if not drifted:
            return None
        worst = max(drifted, key=lambda b: b.rolling_error or 0.0)
        return (f"model error drifted past "
                f"{self.threshold * 100:.1f}% in {len(drifted)} bin(s) "
                f"(worst: {worst.op}/{worst.topo} decile {worst.decile} "
                f"at {(worst.rolling_error or 0) * 100:.1f}%) -- rerun "
                f"engine.calibrate() to refit the fabric constants")

    def report(self) -> Dict[str, Any]:
        return {
            "threshold": self.threshold,
            "observed": self.observed,
            "skipped": self.skipped,
            "bins": [self.bins[k].as_dict() for k in sorted(self.bins)],
            "drifted": len(self.drifted_bins()),
            "recommendation": self.recommendation(),
        }

    def render_table(self) -> str:
        """Per-collective error table (the ``obs_report.py`` output)."""
        header = (f"{'op':<16} {'topo':<10} {'bytes':<14} {'n':>5} "
                  f"{'scored':>6} {'rel_err':>8} {'drift':>6}")
        lines = [header, "-" * len(header)]
        for key in sorted(self.bins):
            b = self.bins[key]
            err = b.rolling_error
            err_s = f"{err * 100:7.2f}%" if err is not None else "   --  "
            lines.append(
                f"{b.op:<16} {b.topo:<10} "
                f"{'[1e%d,1e%d)' % (b.decile, b.decile + 1):<14} "
                f"{b.n:>5} {b.scored:>6} {err_s:>8} "
                f"{'DRIFT' if b.drifted else 'ok':>6}")
        if len(lines) == 2:
            lines.append("(no spans with predicted+measured pairs)")
        rec = self.recommendation()
        if rec:
            lines.append(f"!! {rec}")
        return "\n".join(lines)


__all__ = ["ModelErrorMonitor", "ErrorBin", "bytes_decile",
           "DEFAULT_THRESHOLD"]
