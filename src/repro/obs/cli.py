"""Observability flags shared by the launch drivers.

``launch/train.py`` and ``launch/serve.py`` expose the same three
flags; this module owns their lifecycle so the drivers stay thin:

* ``--trace PATH`` -- collect engine spans and export Chrome-trace
  JSON (load in ``chrome://tracing`` / Perfetto, or feed to
  ``benchmarks/obs_report.py``).
* ``--obs-report`` -- print the predicted-vs-measured model-error
  table after the run.
* ``--metrics-out PATH`` -- dump the process metrics registry
  (engine cache stats, serving telemetry when present) as JSON.

``begin()`` before the run enables tracing when any flag asks for it;
``finish()`` after the run backfills measured wall time by replaying
each unique collective signature on the mesh (the hot-path spans are
recorded at jit trace time, so they carry no wall time of their own),
then writes the requested artifacts.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

from repro.obs import trace as obs_trace
from repro.obs import registry as obs_registry
from repro.obs import model_error as obs_model_error


def add_obs_args(ap) -> None:
    """Install ``--trace`` / ``--obs-report`` / ``--metrics-out`` on an
    ``argparse`` parser."""
    ap.add_argument("--trace", default=None, metavar="PATH",
                    dest="trace",
                    help="export engine collective spans as "
                         "Chrome-trace JSON to PATH (each span carries "
                         "the chosen plan, cache status, predicted "
                         "cost, and replay-measured wall time)")
    ap.add_argument("--obs-report", action="store_true",
                    dest="obs_report",
                    help="print the predicted-vs-measured model-error "
                         "table after the run (implies span tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    dest="metrics_out",
                    help="dump the process metrics registry (engine "
                         "stats, serving telemetry) as JSON to PATH")


def wants_obs(trace: Optional[str], obs_report: bool,
              metrics_out: Optional[str]) -> bool:
    return bool(trace or obs_report or metrics_out)


def begin(trace: Optional[str] = None, obs_report: bool = False,
          metrics_out: Optional[str] = None) -> bool:
    """Enable span collection when any obs flag asks for it.  Returns
    whether observability is active (callers pass that to
    :func:`finish`)."""
    if not wants_obs(trace, obs_report, metrics_out):
        return False
    if trace or obs_report:
        obs_trace.enable_tracing(measure=True)
    return True


def finish(trace: Optional[str] = None, obs_report: bool = False,
           metrics_out: Optional[str] = None, mesh=None, engine=None,
           telemetry_snapshot: Any = None, label: str = "run",
           replay_repeats: int = 3) -> None:
    """Write the artifacts the obs flags asked for.

    ``mesh`` (when the run had one) drives the measured replay that
    backfills wall time into jit-traced spans; ``engine`` defaults to
    the process engine; ``telemetry_snapshot`` (serving) is exported
    into the registry alongside the engine stats."""
    if not wants_obs(trace, obs_report, metrics_out):
        return
    tracer = obs_trace.get_tracer()
    spans = tracer.spans
    if (trace or obs_report) and mesh is not None and spans:
        from repro.obs import replay
        measured = replay.measure_spans(spans, mesh, engine=engine,
                                        repeats=replay_repeats)
        print(f"[{label}] obs: replayed {len(measured)} unique "
              f"collective signatures for wall time")
    if trace:
        n = tracer.export_chrome(trace)
        print(f"[{label}] obs: wrote {n} spans to {trace}")
    if obs_report:
        mon = obs_model_error.ModelErrorMonitor()
        mon.observe_spans(spans)
        print(mon.render_table())
    if metrics_out:
        if engine is None:
            from repro.collectives.api import get_engine
            engine = get_engine()
        obs_registry.export_engine_stats(engine)
        if telemetry_snapshot is not None:
            from repro.serving.telemetry import export_to_registry
            export_to_registry(telemetry_snapshot)
        d = os.path.dirname(os.path.abspath(metrics_out))
        os.makedirs(d, exist_ok=True)
        with open(metrics_out, "w") as f:
            json.dump(obs_registry.REGISTRY.export_json(), f, indent=2,
                      sort_keys=True)
        print(f"[{label}] obs: wrote metrics registry to {metrics_out}")


__all__ = ["add_obs_args", "wants_obs", "begin", "finish"]
