"""Collective observability: span tracing, model-error monitoring, and
a unified metrics registry.

Three pieces, one evidence surface:

* :mod:`repro.obs.trace` -- every engine collective emits a structured
  span (op, axes, bytes, chosen plan, cache status, predicted cost,
  measured wall time) exportable as Chrome-trace/Perfetto JSON.
* :mod:`repro.obs.model_error` -- an online aggregator binning spans
  by (op, topology, bytes-decile) and flagging drift of predicted vs
  measured time past the paper's 4% bound, with a recalibration
  recommendation.
* :mod:`repro.obs.registry` -- counters/gauges/histograms with
  Prometheus-text and JSON exporters; the engine's cache stats, the
  serving telemetry, and the bench counters all export through it.

Enable at runtime via ``launch/train.py --trace`` /
``launch/serve.py --trace`` (plus ``--obs-report`` for the error
table and ``--metrics-out`` for the registry dump), or
programmatically::

    from repro import obs
    obs.enable_tracing(measure=True)
    ... run engine collectives ...
    obs.get_tracer().export_chrome("trace.json")
"""

from repro.obs.registry import (Counter, Gauge, Histogram,   # noqa: F401
                                MetricsRegistry, REGISTRY,
                                EXPORT_SCHEMA, validate_export,
                                export_engine_stats)
from repro.obs.trace import (Span, Tracer, TRACE_SCHEMA,     # noqa: F401
                             CAT_COLLECTIVE, CAT_PHASE,
                             get_tracer, set_tracer, enable_tracing,
                             disable_tracing, load_chrome_trace,
                             collective_spans, validate_spans)
from repro.obs.model_error import (ModelErrorMonitor,        # noqa: F401
                                   ErrorBin, bytes_decile,
                                   DEFAULT_THRESHOLD)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "EXPORT_SCHEMA", "validate_export", "export_engine_stats",
    "Span", "Tracer", "TRACE_SCHEMA", "CAT_COLLECTIVE", "CAT_PHASE",
    "get_tracer", "set_tracer", "enable_tracing", "disable_tracing",
    "load_chrome_trace", "collective_spans", "validate_spans",
    "ModelErrorMonitor", "ErrorBin", "bytes_decile", "DEFAULT_THRESHOLD",
]
