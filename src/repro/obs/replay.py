"""Measured replay: backfill wall time into jit-traced spans.

The train/serve hot paths call the engine inside ``jax.jit``, so their
spans are recorded at trace time with ``measured_s=None`` -- there is
no per-collective wall time inside a fused compiled step, and the
tracer refuses to tax the hot path to get one.  This module recovers
the measurement offline: for every unique collective *signature*
``(op, axes, bytes, algorithm)`` seen in a trace, it builds the same
engine call as a standalone jitted ``shard_map`` program on the live
mesh, times it (compile excluded, best of ``repeats``), and writes the
result back into every span carrying that signature
(``measured_s`` + ``measured_via="replay"``).

The engine's decision/plan caches are warm from the traced run, so the
replay executes exactly the plan the span recorded -- the measurement
really is of the plan whose predicted cost the span carries.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.obs import trace as obs_trace

Signature = Tuple[str, Tuple[str, ...], int, str]

#: ops the replay knows how to reconstruct a payload for
_REPLAYABLE = ("allreduce", "reduce_scatter", "allgather", "all_to_all")


def span_signature(span) -> Optional[Signature]:
    """The replayable identity of a collective span (None when the
    span is not a replayable engine collective)."""
    args = span.args
    op = args.get("op")
    axes = args.get("axes")
    nbytes = args.get("bytes")
    if op not in _REPLAYABLE or not axes or not nbytes:
        return None
    algo = args.get("algorithm") or "auto"
    if algo == "identity":
        return None
    return (str(op), tuple(str(a) for a in axes), int(nbytes), str(algo))


def _fold_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    return p


def _build_call(engine, mesh: Mesh, sig: Signature):
    """(jitted zero-arg callable, payload description) for one
    signature, or None when the mesh cannot host it."""
    op, axes, nbytes, algo = sig
    if any(a not in mesh.shape for a in axes):
        return None
    p = _fold_size(mesh, axes)
    spec = P(axes if len(axes) > 1 else axes[0])
    multi = len(axes) > 1

    if op == "allreduce":
        n = max(1, nbytes // 4)
        x = jnp.zeros((n,), jnp.float32)
        if multi:
            fn = lambda v: engine.allreduce_multi(v, axes, algo)
        else:
            fn = lambda v: engine.allreduce_inside(v, axes[0], algo)
        wrapped = shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                            check_rep=False)
    elif op == "reduce_scatter":
        m = max(1, nbytes // (4 * p))
        x = jnp.zeros((p * m,), jnp.float32)
        if multi:
            fn = lambda v: engine.reduce_scatter_multi(v, axes, algo)
        else:
            fn = lambda v: engine.reduce_scatter_inside(v, axes[0], algo)
        wrapped = shard_map(fn, mesh=mesh, in_specs=P(), out_specs=spec,
                            check_rep=False)
    elif op == "allgather":
        # span nbytes is the *global* gathered size (the model's B)
        n = max(1, nbytes // 4)
        n += (-n) % p
        x = jnp.zeros((n,), jnp.float32)
        if multi:
            fn = lambda v: engine.allgather_multi(v, axes, algo)
        else:
            fn = lambda v: engine.allgather_inside(v, axes[0], algo)
        wrapped = shard_map(fn, mesh=mesh, in_specs=spec, out_specs=P(),
                            check_rep=False)
    elif op == "all_to_all":
        # span nbytes is the per-device shard: [p * m] rows locally
        m = max(1, nbytes // (4 * p))
        x = jnp.zeros((p * (p * m),), jnp.float32)
        if multi:
            fn = lambda v: engine.all_to_all_multi(v, axes, algo)
        else:
            fn = lambda v: engine.all_to_all_inside(v, axes[0], algo)
        wrapped = shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                            check_rep=False)
    else:
        return None
    jitted = jax.jit(wrapped)
    return lambda: jitted(x)


def measure_signature(engine, mesh: Mesh, sig: Signature,
                      repeats: int = 3) -> Optional[float]:
    """Wall seconds for one collective signature on the mesh (best of
    ``repeats``, compile excluded), or None when not replayable."""
    call = _build_call(engine, mesh, sig)
    if call is None:
        return None
    tracer = obs_trace.get_tracer()
    was_enabled = tracer.enabled
    tracer.enabled = False      # replay must not re-enter the trace
    try:
        jax.block_until_ready(call())      # compile + cache warm
        best = float("inf")
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            jax.block_until_ready(call())
            best = min(best, time.perf_counter() - t0)
        return best
    finally:
        tracer.enabled = was_enabled


def measure_spans(spans: List[Any], mesh: Mesh, engine=None,
                  repeats: int = 3,
                  only_missing: bool = True) -> Dict[Signature, float]:
    """Backfill ``measured_s`` into every replayable span.

    Spans that already carry a measurement keep it unless
    ``only_missing=False``.  Returns ``{signature: seconds}`` for the
    signatures actually measured."""
    if engine is None:
        from repro.collectives.api import get_engine
        engine = get_engine()
    sigs: Dict[Signature, List[Any]] = {}
    for sp in spans:
        if getattr(sp, "cat", None) != obs_trace.CAT_COLLECTIVE:
            continue
        if only_missing and sp.args.get("measured_s") is not None:
            continue
        sig = span_signature(sp)
        if sig is not None:
            sigs.setdefault(sig, []).append(sp)
    measured: Dict[Signature, float] = {}
    for sig, members in sigs.items():
        secs = measure_signature(engine, mesh, sig, repeats=repeats)
        if secs is None:
            continue
        measured[sig] = secs
        for sp in members:
            sp.set(measured_s=secs, measured_via="replay")
    return measured


__all__ = ["measure_spans", "measure_signature", "span_signature"]
