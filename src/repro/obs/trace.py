"""Span tracing for engine collectives: structured spans, Chrome-trace
export, opt-in blocking measurement.

Every engine collective call emits one *span* carrying the op, the
axes/sizes it ran over, payload bytes, the chosen plan
(``plan.describe()``, ``n_chunks``), whether the decision came from the
cache, and the model's predicted time (Eq.-1 cycles) -- the per-call
evidence behind the paper's "<4% model error" claim.  Phases executed
by the engine's wavefront runner nest as child spans and are
additionally wrapped in ``jax.named_scope`` so an XLA profile lines up
with the model's phase decomposition.

Two measurement regimes, because engine calls run in two worlds:

* **traced** -- the call happened under ``jax.jit`` tracing (the
  train/serve hot paths).  The span records host-side planning time
  and ``measured_s=None``; nothing blocks, the compiled program is
  untouched.
* **eager** -- the call executed op-by-op on concrete arrays.  With
  the tracer's ``measure=True`` (opt-in -- ``block_until_ready`` never
  taxes the hot path by default) the span blocks on the result and
  ``measured_s`` is real wall time.

``measured_s`` for traced spans can be backfilled afterwards with
:func:`repro.obs.replay.measure_spans`, which re-executes each unique
collective signature eagerly on the mesh and times it.

Export is Chrome-trace JSON (``chrome://tracing`` / Perfetto: complete
``"X"`` events with span/parent ids in ``args``), loadable back into
:class:`Span` objects via :func:`load_chrome_trace` for offline
analysis (``benchmarks/obs_report.py``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Optional

import jax

#: span categories (the ``cat`` field of the Chrome events)
CAT_COLLECTIVE = "collective"
CAT_PHASE = "phase"

#: schema tag written into the trace metadata; bump when span args
#: change incompatibly.
TRACE_SCHEMA = "repro-trace-v1"

#: args every CAT_COLLECTIVE span must carry (the contract
#: ``obs_report.py --check`` enforces).
REQUIRED_COLLECTIVE_ARGS = ("op", "axes", "bytes", "plan", "cache",
                            "predicted", "measured_s", "mode")


@dataclasses.dataclass
class Span:
    """One traced operation.  ``t0``/``dur`` are host seconds relative
    to the tracer epoch; ``args`` is the structured payload."""

    span_id: int
    parent_id: Optional[int]
    name: str
    cat: str
    t0: float
    dur: float = 0.0
    tid: int = 0
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def set(self, **kw: Any) -> None:
        self.args.update(kw)

    @property
    def predicted(self) -> Optional[float]:
        return self.args.get("predicted")

    @property
    def measured_s(self) -> Optional[float]:
        return self.args.get("measured_s")


class _NullSpan:
    """No-op span handed out while tracing is disabled: the hot path
    pays one attribute check and nothing else."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None

    def set(self, **kw: Any) -> None:
        return None

    def finish_result(self, result: Any, block: Optional[bool] = None
                      ) -> None:
        return None


NULL_SPAN = _NullSpan()


def _is_traced(x: Any) -> bool:
    return isinstance(x, jax.core.Tracer)


class _SpanContext:
    """Context manager binding a live :class:`Span` to the tracer's
    thread-local stack."""

    __slots__ = ("_tracer", "span", "_finished")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span
        self._finished = False

    def __enter__(self) -> "_SpanContext":
        self._tracer._push(self.span)
        return self

    def __exit__(self, *exc: Any) -> None:
        if not self._finished:
            self.span.dur = self._tracer._now() - self.span.t0
        self._tracer._pop(self.span)

    def set(self, **kw: Any) -> None:
        self.span.set(**kw)

    def finish_result(self, result: Any, block: Optional[bool] = None
                      ) -> None:
        """Stamp mode and (optionally) measured wall time from the
        op's result.  ``block=None`` blocks iff the tracer is in
        measurement mode; traced results never block."""
        traced = _is_traced(result)
        self.span.set(mode="traced" if traced else "eager")
        should_block = self._tracer.measure if block is None else block
        if should_block and not traced:
            jax.block_until_ready(result)
            self.span.dur = self._tracer._now() - self.span.t0
            self.span.set(measured_s=self.span.dur)
            self._finished = True
        elif "measured_s" not in self.span.args:
            self.span.set(measured_s=None)


class Tracer:
    """Collects spans; disabled by default (every ``span()`` call
    returns the shared no-op)."""

    def __init__(self, enabled: bool = False, measure: bool = False,
                 max_spans: int = 200_000,
                 clock=time.perf_counter):
        self.enabled = enabled
        self.measure = measure
        self.max_spans = max_spans
        self.dropped = 0
        self._clock = clock
        self._epoch = clock()
        self._lock = threading.Lock()
        self._local = threading.local()
        self._spans: List[Span] = []
        self._next_id = 0

    # ------------------------------------------------------------------ #
    def _now(self) -> float:
        return self._clock() - self._epoch

    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = []
            self._local.stack = st
        return st

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()

    # ------------------------------------------------------------------ #
    def span(self, name: str, cat: str = CAT_COLLECTIVE, **args: Any):
        """Open a span (context manager).  Returns the shared no-op
        when tracing is disabled."""
        if not self.enabled:
            return NULL_SPAN
        with self._lock:
            if len(self._spans) >= self.max_spans:
                self.dropped += 1
                return NULL_SPAN
            sid = self._next_id
            self._next_id += 1
            st = self._stack()
            parent = st[-1].span_id if st else None
            sp = Span(span_id=sid, parent_id=parent, name=name, cat=cat,
                      t0=self._now(), tid=threading.get_ident() & 0xFFFF,
                      args=dict(args))
            self._spans.append(sp)
        return _SpanContext(self, sp)

    def current_span(self):
        """The innermost live span on this thread (the one a nested
        resolution step should annotate), or the no-op when tracing is
        off / no span is open."""
        if not self.enabled:
            return NULL_SPAN
        st = self._stack()
        return st[-1] if st else NULL_SPAN

    @property
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._next_id = 0
            self.dropped = 0

    # ------------------------------------------------------------------ #
    def to_chrome_events(self) -> List[Dict[str, Any]]:
        events = []
        for sp in self.spans:
            args = dict(sp.args)
            args["span_id"] = sp.span_id
            args["parent_id"] = sp.parent_id
            events.append({
                "name": sp.name, "cat": sp.cat, "ph": "X",
                "ts": sp.t0 * 1e6, "dur": max(sp.dur, 0.0) * 1e6,
                "pid": os.getpid(), "tid": sp.tid, "args": args,
            })
        return events

    def export_chrome(self, path: str) -> int:
        """Write Chrome-trace JSON; returns the number of spans."""
        events = self.to_chrome_events()
        payload = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "metadata": {"schema": TRACE_SCHEMA, "dropped": self.dropped},
        }
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = path + f".tmp{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)
        return len(events)


def spans_from_events(events: List[Dict[str, Any]]) -> List[Span]:
    """Rebuild :class:`Span` objects from Chrome events (inverse of
    :meth:`Tracer.to_chrome_events`), ordered by start time then id."""
    spans = []
    for ev in events:
        if ev.get("ph") != "X":
            continue
        args = dict(ev.get("args", {}))
        sid = args.pop("span_id", len(spans))
        parent = args.pop("parent_id", None)
        spans.append(Span(
            span_id=int(sid),
            parent_id=None if parent is None else int(parent),
            name=str(ev.get("name", "")), cat=str(ev.get("cat", "")),
            t0=float(ev.get("ts", 0.0)) / 1e6,
            dur=float(ev.get("dur", 0.0)) / 1e6,
            tid=int(ev.get("tid", 0)), args=args))
    spans.sort(key=lambda s: (s.t0, s.span_id))
    return spans


def load_chrome_trace(path: str) -> List[Span]:
    with open(path) as f:
        payload = json.load(f)
    events = (payload["traceEvents"] if isinstance(payload, dict)
              else payload)
    return spans_from_events(events)


def collective_spans(spans: List[Span]) -> Iterator[Span]:
    for sp in spans:
        if sp.cat == CAT_COLLECTIVE:
            yield sp


def validate_spans(spans: List[Span]) -> List[str]:
    """The ``obs_report.py --check`` contract: every collective span
    carries the predicted-cost fields.  Returns problems (empty =
    conformant)."""
    problems = []
    n_coll = 0
    for sp in collective_spans(spans):
        n_coll += 1
        missing = [k for k in REQUIRED_COLLECTIVE_ARGS if k not in sp.args]
        if missing:
            problems.append(f"span {sp.span_id} ({sp.name}): missing "
                            f"args {missing}")
            continue
        if sp.args.get("predicted") is None and \
                not sp.args.get("algorithm_forced"):
            problems.append(f"span {sp.span_id} ({sp.name}): predicted "
                            f"cost is null on a model-selected span")
    if n_coll == 0:
        problems.append("no collective spans in trace")
    return problems


# ---------------------------------------------------------------------- #
# process-wide tracer
# ---------------------------------------------------------------------- #
_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process tracer (tests); returns the previous one."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev


def enable_tracing(measure: bool = False, max_spans: int = 200_000
                   ) -> Tracer:
    """Turn on span collection process-wide.  ``measure=True``
    additionally blocks on eager collective results to record wall
    time (never affects jit-traced calls)."""
    tracer = get_tracer()
    tracer.enabled = True
    tracer.measure = measure
    tracer.max_spans = max_spans
    return tracer


def disable_tracing() -> None:
    get_tracer().enabled = False


__all__ = ["Span", "Tracer", "NULL_SPAN", "TRACE_SCHEMA",
           "CAT_COLLECTIVE", "CAT_PHASE", "REQUIRED_COLLECTIVE_ARGS",
           "get_tracer", "set_tracer", "enable_tracing", "disable_tracing",
           "load_chrome_trace", "spans_from_events", "collective_spans",
           "validate_spans"]
