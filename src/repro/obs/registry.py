"""Unified metrics registry: counters, gauges, histograms, one export.

Before this module every subsystem kept its own ad-hoc dict --
``engine.stats`` (mutated in place, shared across callers),
``serving.telemetry`` snapshots, and the ``BENCH_*.json`` bench
counters -- each with its own shape.  The registry gives them one
surface:

* :class:`Counter` -- monotonically increasing (``inc``),
* :class:`Gauge` -- last-write-wins (``set``),
* :class:`Histogram` -- sample accumulator with count/sum and
  percentiles computed at export time,

all addressed by ``(name, labels)`` and exported atomically either as
JSON (:meth:`MetricsRegistry.export_json`, the schema ``BENCH_*.json``
embeds under its ``"metrics"`` key) or Prometheus text exposition
format (:meth:`MetricsRegistry.export_prometheus`).

*Collectors* are callables invoked at export time that push fresh
values into the registry (e.g. an engine dumping its stats snapshot),
so one ``export_json()`` call dumps the whole system's state without
every subsystem eagerly mirroring each mutation.
"""

from __future__ import annotations

import json
import re
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

#: schema tag embedded in every JSON export so readers (bench_gate,
#: obs_report) can validate they are looking at a registry dump.
EXPORT_SCHEMA = "repro-metrics-v1"

Labels = Tuple[Tuple[str, str], ...]


def _freeze_labels(labels: Optional[Dict[str, str]]) -> Labels:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape_label_value(value: str) -> str:
    """Prometheus exposition escaping for label values: backslash,
    double quote, and line feed must be escaped or the ``k="v"`` pair
    is syntactically invalid (tenant ids and file paths are label
    values under the serving fleet)."""
    return (value.replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _label_suffix(labels: Labels) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label_value(v)}"' for k, v in labels)
    return "{" + inner + "}"


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    """Prometheus metric names allow ``[a-zA-Z0-9_:]`` only."""
    return _NAME_RE.sub("_", name)


class Counter:
    """Monotonic counter; ``inc`` with a negative delta raises."""

    kind = "counter"

    def __init__(self, name: str, labels: Labels, help: str = ""):
        self.name = name
        self.labels = labels
        self.help = help
        self._value = 0.0

    def inc(self, delta: float = 1.0) -> None:
        if delta < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(delta={delta})")
        self._value += delta

    @property
    def value(self) -> float:
        return self._value

    def export(self) -> float:
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, labels: Labels, help: str = ""):
        self.name = name
        self.labels = labels
        self.help = help
        self._value = 0.0

    def set(self, value: float) -> None:
        self._value = float(value)

    def inc(self, delta: float = 1.0) -> None:
        self._value += delta

    @property
    def value(self) -> float:
        return self._value

    def export(self) -> float:
        return self._value


class Histogram:
    """Sample accumulator; percentiles computed at export time (the
    sample list is kept, bounded by ``max_samples`` reservoir-style:
    count/sum stay exact, percentiles become approximate past the
    bound)."""

    kind = "histogram"

    def __init__(self, name: str, labels: Labels, help: str = "",
                 max_samples: int = 4096):
        self.name = name
        self.labels = labels
        self.help = help
        self.max_samples = max_samples
        self.count = 0
        self.sum = 0.0
        self._samples: List[float] = []

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        if len(self._samples) < self.max_samples:
            self._samples.append(value)
        else:
            # reservoir: overwrite a deterministic slot so exports stay
            # reproducible for a fixed observation sequence
            self._samples[self.count % self.max_samples] = value

    def export(self) -> Dict[str, float]:
        out = {"count": float(self.count), "sum": self.sum}
        if self._samples:
            arr = np.asarray(self._samples, np.float64)
            for q in (50, 90, 99):
                out[f"p{q}"] = float(np.percentile(arr, q))
            out["min"] = float(arr.min())
            out["max"] = float(arr.max())
        return out


class MetricsRegistry:
    """Thread-safe metric store keyed by ``(name, labels)``.

    ``counter`` / ``gauge`` / ``histogram`` get-or-create, so call
    sites just ask for the metric each time; conflicting kinds under
    one name raise.  ``snapshot()`` freezes every metric's exported
    value into plain data under one lock acquisition -- the atomic
    view the exporters (and tests) build on.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[Tuple[str, Labels], Any] = {}
        self._collectors: Dict[str, Callable[["MetricsRegistry"], None]] = {}

    # ------------------------------------------------------------------ #
    def _get(self, cls, name: str, labels: Optional[Dict[str, str]],
             help: str, **kw):
        key = (name, _freeze_labels(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1], help=help, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, requested {cls.kind}")
            return m

    def counter(self, name: str, labels: Optional[Dict[str, str]] = None,
                help: str = "") -> Counter:
        return self._get(Counter, name, labels, help)

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None,
              help: str = "") -> Gauge:
        return self._get(Gauge, name, labels, help)

    def histogram(self, name: str, labels: Optional[Dict[str, str]] = None,
                  help: str = "", max_samples: int = 4096) -> Histogram:
        return self._get(Histogram, name, labels, help,
                         max_samples=max_samples)

    # ------------------------------------------------------------------ #
    def register_collector(self, key: str,
                           fn: Callable[["MetricsRegistry"], None]) -> None:
        """Register (or replace) a collector run at export time."""
        with self._lock:
            self._collectors[key] = fn

    def unregister_collector(self, key: str) -> None:
        with self._lock:
            self._collectors.pop(key, None)

    def _run_collectors(self) -> None:
        with self._lock:
            collectors = list(self._collectors.values())
        for fn in collectors:
            fn(self)

    # ------------------------------------------------------------------ #
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Atomic plain-data view: ``{kind: {name{labels}: value}}``.
        Runs collectors first so lazily-exported subsystems are
        current."""
        self._run_collectors()
        out: Dict[str, Dict[str, Any]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            for (name, labels), m in sorted(self._metrics.items()):
                out[m.kind + "s"][name + _label_suffix(labels)] = m.export()
        return out

    def export_json(self) -> Dict[str, Any]:
        """The registry schema ``BENCH_*.json`` and ``--metrics-out``
        share: a tagged, atomic snapshot."""
        snap = self.snapshot()
        snap["schema"] = EXPORT_SCHEMA
        return snap

    def export_json_str(self, indent: int = 2) -> str:
        return json.dumps(self.export_json(), indent=indent, sort_keys=True)

    def export_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        self._run_collectors()
        lines: List[str] = []
        seen_header = set()
        with self._lock:
            items = sorted(self._metrics.items())
        for (name, labels), m in items:
            pname = _prom_name(name)
            if pname not in seen_header:
                seen_header.add(pname)
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                ptype = ("summary" if m.kind == "histogram" else m.kind)
                lines.append(f"# TYPE {pname} {ptype}")
            suffix = _label_suffix(labels)
            if m.kind == "histogram":
                exp = m.export()
                lines.append(f"{pname}_count{suffix} {exp['count']:g}")
                lines.append(f"{pname}_sum{suffix} {exp['sum']:g}")
                for q in (50, 90, 99):
                    key = f"p{q}"
                    if key in exp:
                        q_labels = labels + (("quantile", f"0.{q}"),)
                        lines.append(f"{pname}"
                                     f"{_label_suffix(q_labels)} "
                                     f"{exp[key]:g}")
            else:
                lines.append(f"{pname}{suffix} {m.export():g}")
        return "\n".join(lines) + "\n"

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._collectors.clear()


def validate_export(blob: Any) -> List[str]:
    """Schema check for a registry JSON export (or the ``"metrics"``
    section of a BENCH artifact).  Returns a list of problems, empty
    when the blob conforms."""
    problems: List[str] = []
    if not isinstance(blob, dict):
        return [f"metrics export is {type(blob).__name__}, not a dict"]
    if blob.get("schema") != EXPORT_SCHEMA:
        problems.append(f"schema tag {blob.get('schema')!r} != "
                        f"{EXPORT_SCHEMA!r}")
    for kind in ("counters", "gauges", "histograms"):
        sect = blob.get(kind)
        if sect is None:
            problems.append(f"missing section {kind!r}")
            continue
        if not isinstance(sect, dict):
            problems.append(f"section {kind!r} is not a dict")
            continue
        for key, val in sect.items():
            if kind == "histograms":
                if not isinstance(val, dict) or "count" not in val:
                    problems.append(f"histogram {key!r} lacks a count")
            elif not isinstance(val, (int, float)):
                problems.append(f"{kind[:-1]} {key!r} value {val!r} is "
                                f"not numeric")
    return problems


#: process-wide default registry (the one ``--metrics-out`` dumps).
REGISTRY = MetricsRegistry()


def export_engine_stats(engine, registry: Optional[MetricsRegistry] = None
                        ) -> MetricsRegistry:
    """Mirror a :class:`CollectiveEngine`'s cache counters into the
    registry as gauges (values are cumulative since engine creation --
    gauges, because ``clear_cache``/``calibrate`` can reset the
    underlying dict's semantics).  Uses the engine's atomic
    ``stats_snapshot()`` so the export is a consistent view."""
    reg = registry if registry is not None else REGISTRY
    snap = engine.stats_snapshot()
    labels = {"fabric": engine.topology.name or engine.fabric.name}
    for key, val in snap.items():
        reg.gauge(f"engine_{key}", labels=labels,
                  help=f"CollectiveEngine {key} since engine creation"
                  ).set(val)
    return reg


def export_prefix_cache_stats(server,
                              registry: Optional[MetricsRegistry] = None
                              ) -> MetricsRegistry:
    """Mirror a serving ``ContinuousBatchingServer``'s prefix-cache and
    block-pool state into the registry: the live / evictable occupancy
    split (cache pressure vs true load) plus the cache's hit / insert /
    eviction counters.  No-cache servers export the pool gauges only."""
    reg = registry if registry is not None else REGISTRY
    alloc = server.allocator

    def g(name: str, value: float, help_: str) -> None:
        reg.gauge(name, help=help_).set(float(value))

    g("kv_pool_blocks_live", alloc.num_used,
      "KV blocks referenced by live requests (true load)")
    g("kv_pool_blocks_evictable", alloc.num_evictable,
      "refcount-0 cached KV blocks resident until pool pressure")
    g("kv_pool_blocks_free", alloc.num_free,
      "KV blocks holding no retained content")
    g("kv_pool_evictions", alloc.evictions,
      "cached KV blocks reclaimed under pool pressure")
    cache = getattr(server, "prefix_cache", None)
    if cache is not None:
        g("prefix_cache_entries", len(cache),
          "content keys resident in the prefix cache")
        g("prefix_cache_block_hits", cache.hits,
          "blocks served from the prefix cache")
        g("prefix_cache_block_misses", cache.misses,
          "chain lookups that ended a prefix match")
        g("prefix_cache_inserts", cache.inserts,
          "blocks registered in the prefix cache")
    return reg


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
           "EXPORT_SCHEMA", "validate_export", "export_engine_stats",
           "export_prefix_cache_stats"]
