"""Step builders: train (fwd+bwd+AdamW), prefill, decode.

* loss: next-token cross entropy in fp32 (+ MoE load-balance aux);
* remat: per-layer (scan-level) activation checkpointing, policy set in
  the model;
* microbatching: gradient accumulation via lax.scan over microbatch
  slices (keeps the same global batch while bounding live activations);
* gradient sync: under jit+GSPMD the partitioner inserts the reductions
  implied by the shardings (reduce-scatter under FSDP).  The explicit
  paper-collective DP path runs when a ``GradSyncConfig`` is passed:
  gradients then flow through the CollectiveEngine's cached model-driven
  dispatch (repro.collectives) instead of GSPMD's defaults.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, apply_updates
from repro.train.state import TrainState

AUX_WEIGHT = 0.01


@dataclasses.dataclass
class GradSyncConfig:
    """Explicit pure-DP gradient synchronization through the engine.

    Params are replicated over ``axes``; after backward, gradients are
    bucketed and AllReduced with per-bucket-size cached algorithm
    selection (repro.collectives.overlap.bucketed_allreduce)."""

    mesh: Mesh
    axes: Tuple[str, ...] = ("data",)
    algorithm: str = "auto"
    bucket_bytes: int = 4 * 1024 * 1024
    compress: bool = False


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits [B, S, V] fp32; labels [B, S] int32.

    The gold logit is extracted with a fused mask-reduce rather than
    take_along_axis: a gather over the vocab-sharded axis would make
    GSPMD all-gather the full logits; the mask-reduce keeps everything
    local + one tiny [B, S] all-reduce."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
    mask = vocab_ids == labels[..., None].astype(jnp.int32)
    gold = jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)
    return jnp.mean(lse - gold)


def loss_fn(params, cfg: ArchConfig, batch, remat: bool = True,
            unroll: bool = False):
    model_inputs = {k: v for k, v in batch.items() if k != "labels"}
    logits, aux = tf.forward_train(params, cfg, model_inputs, remat=remat,
                                   unroll=unroll)
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def _split_microbatches(batch, n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1, remat: bool = True,
                    unroll: bool = False,
                    grad_sync: Optional[GradSyncConfig] = None
                    ) -> Callable[[TrainState, Dict[str, jax.Array]],
                                  Tuple[TrainState, Dict[str, jax.Array]]]:
    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, remat=remat, unroll=unroll),
        has_aux=True)

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch=batch)
        else:
            mb = _split_microbatches(batch, microbatches)

            def acc_step(carry, mb_i):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state.params, batch=mb_i)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            if unroll:
                # measurement mode: Python loop so HloCostAnalysis sees
                # every microbatch (a scan body is counted once)
                carry = (g0, 0.0)
                for i in range(microbatches):
                    carry, _ = acc_step(
                        carry, jax.tree.map(lambda a: a[i], mb))
                grads, loss = carry
            else:
                (grads, loss), _ = jax.lax.scan(acc_step, (g0, 0.0), mb)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            metrics = {}
        if grad_sync is not None:
            # explicit pure-DP sync: every gradient byte goes through the
            # CollectiveEngine's cached dispatch (import here to keep the
            # collectives layer optional for GSPMD-only users)
            from repro.collectives.overlap import bucketed_allreduce
            grads, _ = bucketed_allreduce(
                grads, grad_sync.mesh, axes=grad_sync.axes,
                algorithm=grad_sync.algorithm,
                bucket_bytes=grad_sync.bucket_bytes,
                compress=grad_sync.compress)
        params, opt, opt_metrics = apply_updates(
            opt_cfg, state.params, grads, state.opt)
        out = {"loss": loss, **metrics, **opt_metrics}
        return TrainState(params=params, opt=opt), out

    return train_step


def make_prefill_step(cfg: ArchConfig, unroll: bool = False):
    def prefill_step(params, batch):
        return tf.prefill(params, cfg, batch, unroll=unroll)
    return prefill_step


def make_decode_step(cfg: ArchConfig, unroll: bool = False):
    def decode_step(params, cache, batch):
        return tf.decode_step(params, cfg, cache, batch, unroll=unroll)
    return decode_step


__all__ = ["cross_entropy", "loss_fn", "make_train_step",
           "make_prefill_step", "make_decode_step", "GradSyncConfig",
           "AUX_WEIGHT"]
