"""Step builders: train (fwd+bwd+AdamW), prefill, decode.

* loss: next-token cross entropy in fp32 (+ MoE load-balance aux);
* remat: per-layer (scan-level) activation checkpointing, policy set in
  the model;
* microbatching: gradient accumulation via lax.scan over microbatch
  slices (keeps the same global batch while bounding live activations);
* gradient sync: under jit+GSPMD the partitioner inserts the reductions
  implied by the shardings (reduce-scatter under FSDP).  The explicit
  paper-collective DP path runs when a ``GradSyncConfig`` is passed:
  gradients then flow through the CollectiveEngine's cached model-driven
  dispatch (repro.collectives) instead of GSPMD's defaults.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, AdamWState, apply_updates
from repro.train.state import TrainState

AUX_WEIGHT = 0.01


@dataclasses.dataclass
class GradSyncConfig:
    """Explicit DP gradient synchronization through the engine.

    ``mode="allreduce"`` (default): params replicated over ``axes``;
    after backward, gradients are bucketed and AllReduced with the
    planner's per-bucket-size cached joint topology plan
    (repro.collectives.overlap.bucketed_allreduce).

    ``mode="fsdp"``: the ZeRO-style pair instead -- gradients are
    reduce-scattered over ``axes`` (each device keeps its 1/P flat
    shard), the AdamW update runs on the shard against flat sharded
    optimizer state, and the updated params are allgathered -- with
    both halves routed through the engine's topology-aware plans
    instead of GSPMD's sharding-implied defaults.  ``master_weights``
    is supported: the fp32 master lives as one flat sharded vector
    updated in place.  ``compress`` is an allreduce-mode knob and is
    ignored here; ``algorithm`` picks the plan shape for all three
    phases ("auto" = planner argmin).

    ``fused=True`` (fsdp mode) routes the grad reduce-scatter through
    the engine's ``fused_matmul_reduce_scatter`` executor.  The grad
    sync site has no local GEMM to fuse (``w=None``), so this is the
    documented degenerate: the chunk-overlapped reduce-scatter -- the
    same opt-in flag the tensor-parallel projections use where a real
    GEMM does feed the ring (``models.layers.set_fused_tp``)."""

    mesh: Mesh
    axes: Tuple[str, ...] = ("data",)
    algorithm: str = "auto"
    bucket_bytes: int = 4 * 1024 * 1024
    compress: bool = False
    mode: str = "allreduce"        # "allreduce" | "fsdp"
    fused: bool = False

    def __post_init__(self):
        if self.mode not in ("allreduce", "fsdp"):
            raise ValueError(f"unknown grad-sync mode {self.mode!r}; "
                             f"expected 'allreduce' or 'fsdp'")


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits [B, S, V] fp32; labels [B, S] int32.

    The gold logit is extracted with a fused mask-reduce rather than
    take_along_axis: a gather over the vocab-sharded axis would make
    GSPMD all-gather the full logits; the mask-reduce keeps everything
    local + one tiny [B, S] all-reduce."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
    mask = vocab_ids == labels[..., None].astype(jnp.int32)
    gold = jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)
    return jnp.mean(lse - gold)


def loss_fn(params, cfg: ArchConfig, batch, remat: bool = True,
            unroll: bool = False):
    model_inputs = {k: v for k, v in batch.items() if k != "labels"}
    logits, aux = tf.forward_train(params, cfg, model_inputs, remat=remat,
                                   unroll=unroll)
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def fsdp_sync_apply(opt_cfg: AdamWConfig, params, grads, opt,
                    gs: GradSyncConfig):
    """FSDP-style sync + update: reduce-scatter grads, AdamW on the
    flat shard, allgather updated params -- every byte through the
    CollectiveEngine's topology-aware plans.

    Numerically equivalent to ``apply_updates`` on fully-synced grads
    (same global clip, bias correction, and matrix-only weight decay;
    fp32 accumulation throughout), but the optimizer state lives as
    flat 1/P shards: ``opt.mu``/``opt.nu`` become single flat vectors,
    padded to a multiple of the folded DP size and sharded over
    ``gs.axes``.  With ``master_weights`` enabled the fp32 master copy
    lives the same way -- one flat sharded vector updated in place,
    with only the model-dtype params allgathered -- so bf16 training
    keeps full-precision state at 1/P memory.  A tree-shaped state
    (step 0, or a restored allreduce-mode checkpoint) is flattened in
    place.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.collectives.api import get_engine
    from repro.collectives.overlap import flatten_tree, unflatten_tree
    from repro.optim.adamw import lr_at

    axes = tuple(gs.axes)
    if not axes:
        # no DP axes (single-device run): nothing to scatter/gather
        return apply_updates(opt_cfg, params, grads, opt)
    engine = get_engine()
    sizes = tuple(gs.mesh.shape[a] for a in axes)
    n_world = 1
    for s in sizes:
        n_world *= s
    use_master = opt.master is not None

    flat_g, _ = flatten_tree(grads)
    flat_p, meta = flatten_tree(params)
    decay = jnp.concatenate(
        [jnp.full((l.size,), 1.0 if l.ndim >= 2 else 0.0, jnp.float32)
         for l in jax.tree.leaves(params)])
    n = flat_p.size
    pad = (-n) % n_world
    if pad:
        z = jnp.zeros((pad,), jnp.float32)
        flat_g, flat_p, decay = (jnp.concatenate([a, z])
                                 for a in (flat_g, flat_p, decay))

    def _as_flat(tree):
        """Flatten a (possibly already-flat) state tree to [n + pad]."""
        leaves = jax.tree.leaves(tree)
        if (len(leaves) == 1 and leaves[0].ndim == 1
                and leaves[0].size == n + pad):
            return leaves[0]
        flat, _ = flatten_tree(tree)
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
        return flat

    mu, nu = _as_flat(opt.mu), _as_flat(opt.nu)
    # the fp32 working copy the update runs against: the persistent
    # master when enabled, else a per-step recast of the params
    w32 = _as_flat(opt.master) if use_master else flat_p
    # allgather in the model dtype when the params share one: the full
    # fp32 master never needs to cross the wire (the gathered values
    # are cast to the leaf dtypes at unflatten anyway)
    param_dtypes = {l.dtype for l in jax.tree.leaves(params)}
    gather_dtype = (param_dtypes.pop() if len(param_dtypes) == 1
                    else jnp.float32)

    count = opt.count + 1
    lr = lr_at(opt_cfg, count)
    b1c = 1 - opt_cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - opt_cfg.b2 ** count.astype(jnp.float32)

    def shard_fn(g, p32, dm, m, v):
        if gs.fused:
            g_s = engine.fused_matmul_reduce_scatter(
                g, None, axes, algorithm=gs.algorithm)
        else:
            g_s = engine.reduce_scatter_multi(g, axes,
                                              algorithm=gs.algorithm)
        g_s = g_s / float(n_world)      # mean over the DP world
        sq = engine.allreduce_multi(jnp.sum(jnp.square(g_s)).reshape(1),
                                    axes, algorithm=gs.algorithm)
        gnorm = jnp.sqrt(sq[0])
        gg = g_s * jnp.minimum(1.0, opt_cfg.grad_clip / (gnorm + 1e-9))
        m2 = opt_cfg.b1 * m + (1 - opt_cfg.b1) * gg
        v2 = opt_cfg.b2 * v + (1 - opt_cfg.b2) * jnp.square(gg)
        step = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + opt_cfg.eps)
        step = step + opt_cfg.weight_decay * dm * p32
        w2 = p32 - lr * step
        w_full = engine.allgather_multi(w2.astype(gather_dtype), axes,
                                        algorithm=gs.algorithm)
        return w_full, m2, v2, w2, gnorm.reshape(1)

    spec = P(axes if len(axes) > 1 else axes[0])
    fn = shard_map(shard_fn, mesh=gs.mesh,
                   in_specs=(P(), spec, spec, spec, spec),
                   out_specs=(P(), spec, spec, spec, P()),
                   check_rep=False)
    w_full, mu2, nu2, w2, gnorm = fn(flat_g, w32, decay, mu, nu)
    params2 = unflatten_tree(w_full[:n], meta)
    opt2 = AdamWState(mu=mu2, nu=nu2, count=count,
                      master=w2 if use_master else None)
    return params2, opt2, {"grad_norm": gnorm[0], "lr": lr}


def _split_microbatches(batch, n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1, remat: bool = True,
                    unroll: bool = False,
                    grad_sync: Optional[GradSyncConfig] = None
                    ) -> Callable[[TrainState, Dict[str, jax.Array]],
                                  Tuple[TrainState, Dict[str, jax.Array]]]:
    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, remat=remat, unroll=unroll),
        has_aux=True)

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch=batch)
        else:
            mb = _split_microbatches(batch, microbatches)

            def acc_step(carry, mb_i):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state.params, batch=mb_i)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            if unroll:
                # measurement mode: Python loop so HloCostAnalysis sees
                # every microbatch (a scan body is counted once)
                carry = (g0, 0.0)
                for i in range(microbatches):
                    carry, _ = acc_step(
                        carry, jax.tree.map(lambda a: a[i], mb))
                grads, loss = carry
            else:
                (grads, loss), _ = jax.lax.scan(acc_step, (g0, 0.0), mb)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            metrics = {}
        if grad_sync is not None and grad_sync.mode == "fsdp":
            # ZeRO-style pair: reduce-scatter grads, update the flat
            # shard, allgather params -- all through the engine
            params, opt, opt_metrics = fsdp_sync_apply(
                opt_cfg, state.params, grads, state.opt, grad_sync)
            out = {"loss": loss, **metrics, **opt_metrics}
            return TrainState(params=params, opt=opt), out
        if grad_sync is not None:
            # explicit pure-DP sync: every gradient byte goes through the
            # CollectiveEngine's cached dispatch (import here to keep the
            # collectives layer optional for GSPMD-only users)
            from repro.collectives.overlap import bucketed_allreduce
            grads, _ = bucketed_allreduce(
                grads, grad_sync.mesh, axes=grad_sync.axes,
                algorithm=grad_sync.algorithm,
                bucket_bytes=grad_sync.bucket_bytes,
                compress=grad_sync.compress)
        params, opt, opt_metrics = apply_updates(
            opt_cfg, state.params, grads, state.opt)
        out = {"loss": loss, **metrics, **opt_metrics}
        return TrainState(params=params, opt=opt), out

    return train_step


def make_prefill_step(cfg: ArchConfig, unroll: bool = False):
    def prefill_step(params, batch):
        return tf.prefill(params, cfg, batch, unroll=unroll)
    return prefill_step


def make_decode_step(cfg: ArchConfig, unroll: bool = False):
    def decode_step(params, cache, batch):
        return tf.decode_step(params, cfg, cache, batch, unroll=unroll)
    return decode_step


__all__ = ["cross_entropy", "loss_fn", "make_train_step",
           "make_prefill_step", "make_decode_step", "GradSyncConfig",
           "fsdp_sync_apply", "AUX_WEIGHT"]
