from repro.train.state import (TrainState, abstract_train_state,
                               init_train_state, train_state_shardings)
from repro.train.step import (cross_entropy, loss_fn, make_decode_step,
                              make_prefill_step, make_train_step)

__all__ = ["TrainState", "abstract_train_state", "init_train_state",
           "train_state_shardings", "cross_entropy", "loss_fn",
           "make_decode_step", "make_prefill_step", "make_train_step"]
