"""TrainState pytree + sharding helpers."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim.adamw import AdamWState, init_state
from repro.sharding.rules import ShardingPolicy, param_sharding_tree


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(params, master_weights: bool = False) -> TrainState:
    return TrainState(params=params,
                      opt=init_state(params, master_weights))


def train_state_shardings(state_or_specs, mesh: Mesh,
                          policy: ShardingPolicy | None = None) -> TrainState:
    """Optimizer moments inherit each parameter's sharding."""
    p_sh = param_sharding_tree(state_or_specs.params, mesh, policy)
    has_master = getattr(state_or_specs.opt, "master", None) is not None
    return TrainState(
        params=p_sh,
        opt=AdamWState(
            mu=jax.tree.map(lambda s: s, p_sh),
            nu=jax.tree.map(lambda s: s, p_sh),
            count=NamedSharding(mesh, P()),
            master=jax.tree.map(lambda s: s, p_sh) if has_master else None,
        ),
    )


def abstract_train_state(cfg) -> TrainState:
    """ShapeDtypeStruct TrainState (dry-run, no allocation)."""
    from repro.models import param_specs
    p = param_specs(cfg)
    return jax.eval_shape(init_train_state, p)


def fsdp_state_to_tree(state: TrainState) -> TrainState:
    """Convert ``mode="fsdp"`` flat optimizer state back to the tree
    layout, so an FSDP checkpoint resumes under ``mode="gspmd"`` /
    ``"allreduce"`` (``apply_updates`` on per-parameter moments).

    ``fsdp_sync_apply`` keeps ``mu``/``nu`` -- and ``master`` when
    enabled -- as single flat fp32 vectors, padded to a multiple of the
    DP world and sharded over the DP axes.  This strips the padding and
    unflattens each back to the parameter tree (fp32, matching
    ``optim.adamw.init_state``).  Leaves that are already trees pass
    through untouched, so the helper is safe to run on any restored
    TrainState; the round trip ``flatten -> fsdp_state_to_tree`` is
    exact (no dtype cast ever happens on the fp32 state).
    """
    from repro.collectives.overlap import unflatten_tree

    leaves, treedef = jax.tree.flatten(state.params)
    sizes = [l.size for l in leaves]
    shapes = [l.shape for l in leaves]
    n = sum(sizes)
    meta32 = (treedef, sizes, shapes, [jnp.float32] * len(leaves))

    def back(tree):
        if tree is None:
            return None
        flat = jax.tree.leaves(tree)
        if not (len(flat) == 1 and flat[0].ndim == 1
                and flat[0].size >= n):
            return tree             # already tree-shaped
        return unflatten_tree(flat[0][:n], meta32)

    opt = state.opt
    return TrainState(
        params=state.params,
        opt=AdamWState(mu=back(opt.mu), nu=back(opt.nu),
                       count=opt.count, master=back(opt.master)))


__all__ = ["TrainState", "init_train_state", "train_state_shardings",
           "abstract_train_state", "fsdp_state_to_tree"]
