"""TrainState pytree + sharding helpers."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim.adamw import AdamWState, init_state
from repro.sharding.rules import ShardingPolicy, param_sharding_tree


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(params, master_weights: bool = False) -> TrainState:
    return TrainState(params=params,
                      opt=init_state(params, master_weights))


def train_state_shardings(state_or_specs, mesh: Mesh,
                          policy: ShardingPolicy | None = None) -> TrainState:
    """Optimizer moments inherit each parameter's sharding."""
    p_sh = param_sharding_tree(state_or_specs.params, mesh, policy)
    has_master = getattr(state_or_specs.opt, "master", None) is not None
    return TrainState(
        params=p_sh,
        opt=AdamWState(
            mu=jax.tree.map(lambda s: s, p_sh),
            nu=jax.tree.map(lambda s: s, p_sh),
            count=NamedSharding(mesh, P()),
            master=jax.tree.map(lambda s: s, p_sh) if has_master else None,
        ),
    )


def abstract_train_state(cfg) -> TrainState:
    """ShapeDtypeStruct TrainState (dry-run, no allocation)."""
    from repro.models import param_specs
    p = param_specs(cfg)
    return jax.eval_shape(init_train_state, p)


__all__ = ["TrainState", "init_train_state", "train_state_shardings",
           "abstract_train_state"]
