"""Sharded, preemption-safe checkpointing.

Layout:  <dir>/step_<n>/
            shard_<proc>.npz     flattened param/opt leaves (this process)
            COMMIT               written last; a step without COMMIT is
                                 treated as torn and ignored on restore

Atomicity: each shard is written to a temp file and os.replace'd; COMMIT
is only written after every shard fsyncs.  ``keep`` bounds disk usage.
Restore picks the newest committed step -- the restart path a preempted
or failed node takes (see repro.runtime.fault_tolerance).

An optional background thread makes saves asynchronous so the train loop
doesn't stall on I/O (checkpoint/compute overlap).
"""

from __future__ import annotations

import itertools
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

_TMP_COUNTER = itertools.count()

import jax
import numpy as np


import ml_dtypes

# npz cannot round-trip ml_dtypes (bfloat16, fp8); encode them as raw
# uint views + a sidecar dtype map.
_RAW_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}
try:  # fp8 families, if present in this ml_dtypes
    _RAW_DTYPES["float8_e4m3fn"] = (ml_dtypes.float8_e4m3fn, np.uint8)
    _RAW_DTYPES["float8_e5m2"] = (ml_dtypes.float8_e5m2, np.uint8)
except AttributeError:  # pragma: no cover
    pass


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    dtypes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        name = arr.dtype.name
        if name in _RAW_DTYPES:
            arr = arr.view(_RAW_DTYPES[name][1])
            dtypes[key] = name
        flat[key] = arr
    flat["__dtypes__"] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8).copy()
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    dtypes = {}
    if "__dtypes__" in flat:
        dtypes = json.loads(bytes(flat["__dtypes__"]).decode())
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if key in dtypes:
            arr = arr.view(_RAW_DTYPES[dtypes[key]][0])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"leaf {key}: checkpoint shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 process_index: int = 0, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.proc = process_index
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ #
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def committed_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, metadata: Optional[dict] = None,
             block: bool = True) -> None:
        if self.async_save and not block:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, tree, metadata))
            self._thread.start()
        else:
            self._save_sync(step, tree, metadata)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_sync(self, step: int, tree, metadata: Optional[dict]) -> None:
        sdir = self._step_dir(step)
        os.makedirs(sdir, exist_ok=True)
        flat = _flatten(tree)
        tmp = os.path.join(
            sdir, f".tmp_shard_{self.proc}.{next(_TMP_COUNTER)}.npz")
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(sdir, f"shard_{self.proc}.npz"))
        if metadata is not None:
            with open(os.path.join(sdir, "metadata.json"), "w") as f:
                json.dump(metadata, f)
        # single-controller commit (process 0)
        if self.proc == 0:
            with open(os.path.join(sdir, "COMMIT"), "w") as f:
                f.write("ok")
        self._gc()

    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------ #
    def restore(self, template, step: Optional[int] = None
                ) -> Tuple[int, Any, Optional[dict]]:
        steps = self.committed_steps()
        if not steps:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        step = steps[-1] if step is None else step
        sdir = self._step_dir(step)
        with np.load(os.path.join(sdir, f"shard_{self.proc}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        meta = None
        mpath = os.path.join(sdir, "metadata.json")
        if os.path.exists(mpath):
            with open(mpath) as f:
                meta = json.load(f)
        return step, tree, meta


__all__ = ["CheckpointManager"]
