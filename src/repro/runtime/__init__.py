from repro.runtime.fault_tolerance import (ElasticPlan, HeartbeatMonitor,
                                           PreemptionGuard,
                                           StragglerDetector,
                                           plan_elastic_remesh)

__all__ = ["ElasticPlan", "HeartbeatMonitor", "PreemptionGuard",
           "StragglerDetector", "plan_elastic_remesh"]
