"""Fault-tolerance utilities for 1000+-node deployments.

Components (all host-side control-plane logic, unit-tested on CPU):

* ``HeartbeatMonitor``   -- declares hosts dead after a missed-beat window.
* ``StragglerDetector``  -- flags hosts whose rolling step time exceeds a
                            multiple of the fleet median (mitigation: the
                            launcher re-shards data away from them or
                            swaps in a hot spare).
* ``ElasticPlan``        -- given a failed-host set, proposes the largest
                            valid sub-mesh (shrinking the data axis, never
                            the model axis: TP groups are monolithic) plus
                            the checkpoint step to restore.
* ``PreemptionGuard``    -- SIGTERM-driven checkpoint-and-exit for the
                            train loop.

The data plane (collective restart) is delegated to JAX's coordinator on
real deployments; these pieces provide the decisions and the restart
protocol around it.
"""

from __future__ import annotations

import dataclasses
import signal
import threading
import time
from collections import defaultdict, deque
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple


class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[str], timeout_s: float = 60.0,
                 clock=time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        now = clock()
        self.last_seen: Dict[str, float] = {h: now for h in hosts}

    def beat(self, host: str) -> None:
        self.last_seen[host] = self.clock()

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last_seen.items()
                if now - t > self.timeout]


class StragglerDetector:
    """Rolling per-host step-time statistics with median-multiple flagging."""

    def __init__(self, window: int = 16, threshold: float = 1.5):
        self.window = window
        self.threshold = threshold
        self.times: Dict[str, Deque[float]] = defaultdict(
            lambda: deque(maxlen=window))

    def record(self, host: str, step_time_s: float) -> None:
        self.times[host].append(step_time_s)

    def _avg(self, host: str) -> Optional[float]:
        t = self.times[host]
        return sum(t) / len(t) if t else None

    def stragglers(self) -> List[Tuple[str, float]]:
        avgs = {h: a for h in self.times if (a := self._avg(h)) is not None}
        if len(avgs) < 2:
            return []
        vals = sorted(avgs.values())
        median = vals[len(vals) // 2]
        if median <= 0:
            return []
        return [(h, a / median) for h, a in sorted(avgs.items())
                if a > self.threshold * median]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_mesh: Tuple[int, ...]
    new_mesh: Tuple[int, ...]
    restore_step: Optional[int]
    dropped_hosts: Tuple[str, ...]

    @property
    def shrink_factor(self) -> float:
        old = 1
        for d in self.old_mesh:
            old *= d
        new = 1
        for d in self.new_mesh:
            new *= d
        return new / old


def plan_elastic_remesh(mesh_shape: Tuple[int, ...],
                        axis_names: Tuple[str, ...],
                        hosts_per_slice: int,
                        failed_hosts: Set[str],
                        all_hosts: Sequence[str],
                        restore_step: Optional[int]) -> ElasticPlan:
    """Shrink the data axis to the largest power-of-two slice count that
    excludes failed hosts.  The model axis is preserved: a TP group with a
    dead member is dropped wholesale (its healthy members become spares).
    """
    assert "data" in axis_names
    data_idx = axis_names.index("data")
    healthy = [h for h in all_hosts if h not in failed_hosts]
    usable_slices = len(healthy) // max(hosts_per_slice, 1)
    new_data = 1
    while new_data * 2 <= min(mesh_shape[data_idx], usable_slices):
        new_data *= 2
    new_shape = list(mesh_shape)
    new_shape[data_idx] = new_data
    return ElasticPlan(tuple(mesh_shape), tuple(new_shape), restore_step,
                       tuple(sorted(failed_hosts)))


class PreemptionGuard:
    """SIGTERM -> set flag; the train loop checks ``should_stop`` each step
    and checkpoints before exiting (preemption-safe training)."""

    def __init__(self, install: bool = True):
        self._stop = threading.Event()
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
            except ValueError:
                pass  # not the main thread (tests)

    def _handler(self, signum, frame) -> None:
        self._stop.set()

    def request_stop(self) -> None:
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()


__all__ = ["HeartbeatMonitor", "StragglerDetector", "ElasticPlan",
           "plan_elastic_remesh", "PreemptionGuard"]
