"""Shared model layers: RMSNorm, RoPE, GQA attention (direct + chunked
flash), SwiGLU MLP.  Pure functional JAX; parameters are plain pytrees.

Attention uses an online-softmax KV-chunked implementation (a pure-JAX
flash attention) whenever the sequence is long, so that the compiled HLO
never materializes an S x S logits tensor -- this is what makes the 32k
prefill and 4k train shapes compile within per-chip HBM.  On TPU the
Pallas kernel (repro.kernels.flash_attention) implements the same
computation; see DESIGN.md (hardware adaptation).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_CHUNK_THRESHOLD = 2048  # direct attention below this sequence length
_KV_CHUNK = 1024


# ---------------------------------------------------------------------- #
# activation sharding hints
#
# Without these, GSPMD is free to pick pathological strategies (e.g.
# partial-summing attention logits over a split head_dim, or reducing
# activations over the FSDP axis instead of gathering weights).  The
# hints use the ambient mesh when one is active (dry-run, launchers) and
# are no-ops otherwise (CPU unit tests).
# ---------------------------------------------------------------------- #
def _ambient_mesh():
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not mesh.empty and mesh.axis_names:
            return mesh
    except Exception:
        pass
    return None


def shard_hint(x: jax.Array, *dims: str | None) -> jax.Array:
    """with_sharding_constraint using placeholder axis roles.

    dims entries: "dp" (batch axes: pod+data), "model", or None.  Missing
    mesh axes degrade to None; no ambient mesh -> identity.
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    spec = []
    for d in dims:
        if d == "dp":
            spec.append(dp if len(dp) > 1 else (dp[0] if dp else None))
        elif d == "model":
            spec.append("model" if "model" in names else None)
        else:
            spec.append(None)
    from jax.sharding import PartitionSpec as _P
    return jax.lax.with_sharding_constraint(x, _P(*spec))


@jax.custom_vjp
def grad_barrier(x):
    """Identity whose cotangent passes an optimization barrier: stops
    XLA from sinking f32 converts across the TP all-reduce in backward
    (which would double the gradient all-reduce bytes)."""
    return x


def _gb_fwd(x):
    return x, None


def _gb_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


grad_barrier.defvjp(_gb_fwd, _gb_bwd)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (or [S])."""
    b, s, h, d = x.shape
    freqs = rope_frequencies(d, theta)                       # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _repeat_kv(k: jax.Array, group: int) -> jax.Array:
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=1)


def _direct_attention(q, k, v, causal: bool, window: Optional[int],
                      q_offset: int | jax.Array = 0,
                      kv_len: Optional[jax.Array] = None,
                      probs_bf16: bool = False) -> jax.Array:
    """q: [B, H, Sq, D]; k/v: [B, H, Skv, D] (already GQA-expanded)."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    scale = 1.0 / (d ** 0.5)
    # native-dtype matmul with fp32 accumulation (the MXU's mode): no
    # fp32 upcast of the (potentially huge) K operand
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    q_ids = q_offset + jnp.arange(sq)[:, None]
    k_ids = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_ids <= q_ids
    if window is not None:
        mask &= k_ids > q_ids - window
    mask = mask[None, None]
    if kv_len is not None:
        mask &= (k_ids < kv_len)[None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    if probs_bf16:
        out = jnp.einsum("bhqk,bhkd->bhqd", probs.astype(jnp.bfloat16),
                         v.astype(jnp.bfloat16))
    else:
        out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


_INNER_UNROLL = False   # dry-run measurement mode: unroll inner scans so
                        # HloCostAnalysis sees real ops, not loop carries
                        # (TPU aliases loop carries in place; the CPU HLO
                        # would otherwise charge giant copy traffic)


def set_inner_unroll(value: bool) -> None:
    global _INNER_UNROLL
    _INNER_UNROLL = value


def inner_unroll_enabled() -> bool:
    return _INNER_UNROLL


def _chunked_attention(q, k, v, causal: bool, window: Optional[int],
                       chunk: int = _KV_CHUNK,
                       probs_bf16: bool = False) -> jax.Array:
    """Online-softmax attention scanning KV chunks; never materializes
    S x S logits.  q: [B, H, S, D]; k/v: [B, H, S, D] (GQA-expanded).

    Each chunk step is rematerialized (jax.checkpoint): the backward pass
    recomputes the chunk's logits instead of saving exp(logits) -- the
    flash-attention-backward memory behavior, matching what the Pallas
    kernel does natively on TPU."""
    b, h, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    chunk = min(chunk, s)
    if _INNER_UNROLL and s // chunk > 16:
        chunk = -(-s // 16)           # bound the measurement unroll
        while s % chunk != 0:
            chunk += 1
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    qf = q.astype(jnp.float32) * scale
    kc = k.reshape(b, h, n_chunks, chunk, d)
    vc = v.reshape(b, h, n_chunks, chunk, d)
    kc = jnp.moveaxis(kc, 2, 0)  # [n, B, H, chunk, D]
    vc = jnp.moveaxis(vc, 2, 0)
    q_ids = jnp.arange(s)

    @jax.checkpoint
    def step(carry, inputs):
        m, l, acc = carry
        ci, kb, vb = inputs
        k_ids = ci * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kb.astype(jnp.float32))
        mask = jnp.ones((s, chunk), dtype=bool)
        if causal:
            mask &= k_ids[None, :] <= q_ids[:, None]
        if window is not None:
            mask &= k_ids[None, :] > q_ids[:, None] - window
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(jnp.where(mask[None, None], logits - safe_m[..., None],
                              -jnp.inf))
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        if probs_bf16:
            pv = jnp.einsum("bhqk,bhkd->bhqd", p.astype(jnp.bfloat16),
                            vb.astype(jnp.bfloat16)).astype(jnp.float32)
        else:
            pv = jnp.einsum("bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    acc0 = jnp.zeros((b, h, s, d), jnp.float32)
    xs = (jnp.arange(n_chunks), kc, vc)
    if _INNER_UNROLL:
        carry = (m0, l0, acc0)
        for i in range(n_chunks):
            carry, _ = step(carry, jax.tree.map(lambda a: a[i], xs))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), xs)
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)


# ---------------------------------------------------------------------- #
# sequence-parallel flash decode (beyond-paper optimization)
#
# GQA archs whose kv-head count doesn't divide the model axis keep their
# KV cache *sequence*-sharded (sharding/rules._kv_cache_spec).  A naive
# decode then all-gathers the whole cache every token (~1 GB/layer on
# yi-34b).  Here each model rank computes flash partials (m, l, acc)
# over its local cache shard and the ranks combine with a log-sum-exp
# merge: one [B, H, D]-sized psum (~0.2 MB) instead of the gather.
# ---------------------------------------------------------------------- #
def flash_decode(q: jax.Array, ck: jax.Array, cv: jax.Array,
                 kv_len: jax.Array, mesh, dp_spec) -> jax.Array:
    """q: [B, 1, H, D] (replicated over model); ck/cv: [B, S, KV, D]
    sequence-sharded over 'model'.  Returns [B, 1, H, D]."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, _, h, d = q.shape
    kvh = ck.shape[2]
    group = h // kvh
    n_ranks = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    s_local = ck.shape[1] // n_ranks

    def body(q_l, k_l, v_l, kv_len_l):
        rank = jax.lax.axis_index("model")
        offset = rank * s_local
        kt = _repeat_kv(jnp.moveaxis(k_l, 1, 2), group)   # [B, H, S_l, D]
        vt = _repeat_kv(jnp.moveaxis(v_l, 1, 2), group)
        qt = jnp.moveaxis(q_l, 1, 2)                      # [B, H, 1, D]
        logits = jnp.einsum("bhqd,bhkd->bhqk", qt, kt,
                            preferred_element_type=jnp.float32)
        logits = logits / (d ** 0.5)
        ids = offset + jnp.arange(s_local)
        mask = (ids < kv_len_l)[None, None, None, :]
        logits = jnp.where(mask, logits, -jnp.inf)
        m_l = jnp.max(logits, axis=-1)                    # [B, H, 1]
        m_g = jax.lax.pmax(m_l, "model")
        safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
        p = jnp.exp(jnp.where(mask, logits - safe[..., None], -jnp.inf))
        l_l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bhqk,bhkd->bhqd", p, vt.astype(jnp.float32))
        l_g = jax.lax.psum(l_l, "model")
        acc_g = jax.lax.psum(acc, "model")
        l_g = jnp.where(l_g == 0.0, 1.0, l_g)
        out = (acc_g / l_g[..., None]).astype(q_l.dtype)
        return jnp.moveaxis(out, 1, 2)                    # [B, 1, H, D]

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, None, None, None),
                  P(dp_spec, "model", None, None),
                  P(dp_spec, "model", None, None),
                  P()),
        out_specs=P(dp_spec, None, None, None),
        check_rep=False)
    return fn(q, ck, cv, jnp.asarray(kv_len, jnp.int32))


def use_flash_decode(b: int, sq: int, skv: int, kvh: int):
    """(mesh, dp_spec) when the seq-parallel decode path applies."""
    mesh = _ambient_mesh()
    if mesh is None or sq != 1 or "model" not in mesh.axis_names:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes["model"]
    if kvh % m == 0 or skv % m != 0:
        return None   # head-sharded caches take the regular path
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_n = 1
    for a in dp:
        dp_n *= sizes[a]
    if dp and b % dp_n != 0:
        dp = ()
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    return mesh, dp_spec


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              q_offset: int | jax.Array = 0,
              kv_len: Optional[jax.Array] = None,
              probs_bf16: bool = False) -> jax.Array:
    """GQA attention.  q: [B, Sq, H, D]; k/v: [B, Skv, Hkv, D].
    Returns [B, Sq, H, D]."""
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qt = jnp.moveaxis(q, 1, 2)                       # [B, H, Sq, D]
    kt = _repeat_kv(jnp.moveaxis(k, 1, 2), group)    # [B, H, Skv, D]
    vt = _repeat_kv(jnp.moveaxis(v, 1, 2), group)
    # batch over DP, heads over TP; head_dim/seq stay unsharded so the
    # QK^T contraction never partial-sums (no logits all-reduce)
    qt = shard_hint(qt, "dp", "model", None, None)
    kt = shard_hint(kt, "dp", "model", None, None)
    vt = shard_hint(vt, "dp", "model", None, None)
    skv = kt.shape[2]
    if sq == skv and sq > _CHUNK_THRESHOLD and kv_len is None:
        out = _chunked_attention(qt, kt, vt, causal, window,
                                 probs_bf16=probs_bf16)
    else:
        out = _direct_attention(qt, kt, vt, causal, window,
                                q_offset=q_offset, kv_len=kv_len,
                                probs_bf16=probs_bf16)
    return jnp.moveaxis(out, 1, 2)


# ---------------------------------------------------------------------- #
# fused tensor-parallel down-projection (opt-in)
#
# The TP down-projection contracts the model-sharded hidden dim, so its
# natural lowering is a partial-sum + psum.  With fusion enabled the
# psum is decomposed into reduce-scatter + allgather and the RS rides
# the engine's fused matmul+reduce-scatter executor
# (kernels/fused_matmul_rs.py): GEMM row blocks feed the ring as they
# complete instead of serializing MXU time behind wire time.
# ---------------------------------------------------------------------- #
_FUSED_TP = False


def set_fused_tp(value: bool) -> None:
    """Enable/disable the fused TP down-projection (launchers flip this
    under ``--fused``; a no-op unless the mesh has a model axis > 1 and
    the shapes tile the ring)."""
    global _FUSED_TP
    _FUSED_TP = bool(value)


def fused_tp_enabled() -> bool:
    return _FUSED_TP


def _fused_tp_applicable(mesh, h: jax.Array) -> bool:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pm = sizes.get("model", 1)
    if pm <= 1:
        return False
    b, s, f = h.shape
    if f % pm:
        return False
    n_dp = 1
    for a in ("pod", "data"):
        n_dp *= sizes.get(a, 1)
    if b % n_dp:
        return False
    return ((b // n_dp) * s) % pm == 0


def _fused_down_proj(h: jax.Array, w_down: jax.Array, mesh) -> jax.Array:
    """``psum(h @ w_down)`` over 'model' as reduce-scatter + allgather,
    the RS fused with the GEMM ring."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.collectives.api import get_engine

    eng = get_engine()
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp_spec = (dp if len(dp) > 1 else dp[0]) if dp else None
    b, s, _ = h.shape
    d = w_down.shape[-1]

    def body(h_l, w_l):
        bl = h_l.shape[0]
        x2 = h_l.reshape(bl * s, h_l.shape[-1])
        y_s = eng.fused_matmul_reduce_scatter(x2, w_l, "model")
        y = eng.allgather_inside(y_s, "model")
        return y.reshape(bl, s, d)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(dp_spec, None, "model"), P("model", None)),
                   out_specs=P(dp_spec, None, None), check_rep=False)
    return fn(h, w_down)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    # hidden is TP-sharded; forces FSDP weight-gather over data instead of
    # partial-sum all-reducing [B,S,F] activations over the data axis
    g = shard_hint(x @ w_gate, "dp", None, "model")
    u = shard_hint(x @ w_up, "dp", None, "model")
    h = jax.nn.silu(g) * u
    if _FUSED_TP:
        mesh = _ambient_mesh()
        if mesh is not None and _fused_tp_applicable(mesh, h):
            return _fused_down_proj(h, w_down, mesh)
    return shard_hint(h @ w_down, "dp", None, None)


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up: jax.Array,
             w_down: jax.Array, b_down: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ w_up + b_up, approximate=True)
    return h @ w_down + b_down


__all__ = ["rms_norm", "apply_rope", "attention", "swiglu", "gelu_mlp",
           "set_fused_tp", "fused_tp_enabled"]
