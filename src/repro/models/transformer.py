"""Unified model zoo: dense GQA decoders, MoE decoders (+Arctic dense
residual), Mamba SSM stacks, RG-LRU hybrids, and encoder-decoder
backbones -- all as functional JAX with stacked-layer parameters and
``lax.scan`` over layers (keeps HLO size and compile time bounded for the
35..64-layer full configs).

Three entry points per family, shared signature:

    forward_train(params, cfg, batch)              -> logits
    prefill(params, cfg, batch)                    -> (logits, cache)
    decode_step(params, cfg, cache, batch)         -> (logits, cache)

``batch`` dicts come from ``repro.launch.input_specs`` (ShapeDtypeStructs
in the dry-run, real arrays in tests/examples).
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_rope, attention, rms_norm,
                                 shard_hint, swiglu)

Params = Dict[str, Any]


# ====================================================================== #
# parameter initialization
# ====================================================================== #
def _norm(d, dtype):
    return jnp.zeros((d,), dtype)


def _dense(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def _attn_layer(key, cfg: ArchConfig, dtype) -> Params:
    d, h, kv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": _dense(ks[0], (d, h * hd), s, dtype),
        "wk": _dense(ks[1], (d, kv * hd), s, dtype),
        "wv": _dense(ks[2], (d, kv * hd), s, dtype),
        "wo": _dense(ks[3], (h * hd, d), (h * hd) ** -0.5, dtype),
    }


def _mlp_layer(key, d, f, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wg": _dense(ks[0], (d, f), d ** -0.5, dtype),
        "wu": _dense(ks[1], (d, f), d ** -0.5, dtype),
        "wd": _dense(ks[2], (f, d), f ** -0.5, dtype),
    }


def _moe_layer(key, cfg: ArchConfig, dtype) -> Params:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense(ks[0], (d, e), d ** -0.5, jnp.float32),
        "eg": _dense(ks[1], (e, d, f), d ** -0.5, dtype),
        "eu": _dense(ks[2], (e, d, f), d ** -0.5, dtype),
        "ed": _dense(ks[3], (e, f, d), f ** -0.5, dtype),
    }
    if cfg.moe_dense_ff:
        p["dense_mlp"] = _mlp_layer(ks[4], d, cfg.moe_dense_ff, dtype)
    return p


def _decoder_layer(key, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"ln1": _norm(cfg.d_model, dtype), "ln2": _norm(cfg.d_model, dtype)}
    p.update(_attn_layer(ks[0], cfg, dtype))
    if cfg.family == "moe":
        p.update(_moe_layer(ks[1], cfg, dtype))
    else:
        p.update(_mlp_layer(ks[2], cfg.d_model, cfg.d_ff, dtype))
    return p


def _stack(layer_params):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layer_params)


def init_params(key, cfg: ArchConfig) -> Params:
    dtype = cfg.activation_dtype
    d, v = cfg.d_model, cfg.vocab_size
    k_embed, k_head, k_layers = jax.random.split(key, 3)

    params: Params = {
        "embed": _dense(k_embed, (v, d), 1.0, dtype),
        "ln_f": _norm(d, dtype),
        "head": _dense(k_head, (d, v), d ** -0.5, dtype),
    }
    lkeys = jax.random.split(k_layers, max(cfg.num_layers, 1) + 8)

    if cfg.family == "ssm":
        layers = [
            {"ln": _norm(d, dtype),
             **ssm_lib.init_mamba_params(lkeys[i], d, cfg.d_inner,
                                         cfg.ssm_state, cfg.dt_rank,
                                         cfg.ssm_conv, dtype)}
            for i in range(cfg.num_layers)]
        params["layers"] = _stack(layers)
        return params

    if cfg.family == "hybrid":
        pattern = cfg.block_pattern
        cyc = len(pattern)
        n_cycles, rem = divmod(cfg.num_layers, cyc)
        ki = iter(jax.random.split(lkeys[0], cfg.num_layers + 4))

        def make_block(kind, key):
            if kind == "local":
                return _decoder_layer(key, cfg, dtype)
            return {"ln": _norm(d, dtype),
                    **rglru_lib.init_rglru_params(
                        key, d, d, cfg.num_heads, cfg.ssm_conv, dtype)}

        cycles = {f"b{j}": [] for j in range(cyc)}
        for _ in range(n_cycles):
            for j, kind in enumerate(pattern):
                cycles[f"b{j}"].append(make_block(kind, next(ki)))
        params["cycles"] = {k: _stack(vs) for k, vs in cycles.items()}
        params["tail"] = [make_block(pattern[j], next(ki))
                          for j in range(rem)]
        return params

    if cfg.family == "encdec":
        enc = [
            {"ln1": _norm(d, dtype), "ln2": _norm(d, dtype),
             **_attn_layer(lkeys[i], cfg, dtype),
             **_mlp_layer(jax.random.fold_in(lkeys[i], 1), d, cfg.d_ff,
                          dtype)}
            for i in range(cfg.encoder_layers)]
        dec = []
        for i in range(cfg.num_layers):
            k0 = jax.random.fold_in(lkeys[i], 2)
            k1 = jax.random.fold_in(lkeys[i], 3)
            k2 = jax.random.fold_in(lkeys[i], 4)
            layer = {"ln1": _norm(d, dtype), "lnx": _norm(d, dtype),
                     "ln2": _norm(d, dtype)}
            layer.update(_attn_layer(k0, cfg, dtype))
            layer.update({f"x_{k}": v
                          for k, v in _attn_layer(k1, cfg, dtype).items()})
            layer.update(_mlp_layer(k2, d, cfg.d_ff, dtype))
            dec.append(layer)
        params["encoder"] = _stack(enc)
        params["enc_ln_f"] = _norm(d, dtype)
        params["layers"] = _stack(dec)
        return params

    # dense / moe / vlm decoder stacks
    layers = [_decoder_layer(lkeys[i], cfg, dtype)
              for i in range(cfg.num_layers)]
    params["layers"] = _stack(layers)
    return params


def param_specs(cfg: ArchConfig):
    """Shapes/dtypes of every parameter without allocating anything."""
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0))


# ====================================================================== #
# blocks
# ====================================================================== #
def _attention_sublayer(cfg: ArchConfig, x, p, *, causal=True, window=None,
                        pos=0, cache_kv=None, prefix=""):
    """Returns (attn_out, new_kv or None)."""
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    wq, wk, wv, wo = (p[prefix + "wq"], p[prefix + "wk"], p[prefix + "wv"],
                      p[prefix + "wo"])
    q = shard_hint((x @ wq).reshape(b, s, h, hd), "dp", None, "model", None)
    k = shard_hint((x @ wk).reshape(b, s, kv, hd), "dp", None, "model", None)
    v = shard_hint((x @ wv).reshape(b, s, kv, hd), "dp", None, "model", None)
    positions = pos + jnp.arange(s)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cache_kv is None:
        out = attention(q, k, v, causal=causal, window=window,
                        probs_bf16=cfg.attn_probs_bf16)
        new_kv = (k, v)
    else:
        ck, cv = cache_kv
        smax = ck.shape[1]
        write = jnp.minimum(pos, smax - s)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, write, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, write, 0, 0))
        from repro.models.layers import flash_decode, use_flash_decode
        fd = use_flash_decode(b, s, smax, kv) if causal and not window \
            else None
        if fd is not None:
            mesh_, dp_spec = fd
            out = flash_decode(q, ck, cv, pos + s, mesh_, dp_spec)
        else:
            out = attention(q, ck, cv, causal=causal, window=window,
                            q_offset=pos, kv_len=pos + s,
                            probs_bf16=cfg.attn_probs_bf16)
        new_kv = (ck, cv)
    return shard_hint(out.reshape(b, s, h * hd) @ wo,
                      "dp", None, None), new_kv


def _cross_attention(cfg: ArchConfig, x, p, enc_k, enc_v):
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["x_wq"]).reshape(b, s, h, hd)
    out = attention(q, enc_k, enc_v, causal=False)
    return out.reshape(b, s, h * hd) @ p["x_wo"]


def _ffn_sublayer(cfg: ArchConfig, x, p):
    """Returns (ffn_out, aux_loss)."""
    if cfg.family == "moe":
        if cfg.moe_ep:
            from repro.models.moe_ep import moe_ffn_ep
            moe_fn = functools.partial(moe_ffn_ep,
                                       algorithm=cfg.moe_ep_algorithm)
        else:
            moe_fn = (moe_lib.moe_ffn_sharded if cfg.moe_shardmap_ep
                      else moe_lib.moe_ffn)
        out, aux = moe_fn(
            x, p["router"], p["eg"], p["eu"], p["ed"],
            top_k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor)
        if cfg.moe_dense_ff:
            dm = p["dense_mlp"]
            out = out + swiglu(x, dm["wg"], dm["wu"], dm["wd"])
        return out, aux
    return swiglu(x, p["wg"], p["wu"], p["wd"]), 0.0


def _decoder_block(cfg: ArchConfig, x, p, *, pos=0, cache_kv=None,
                   window=None):
    if getattr(cfg, "grad_barrier", False) and x.shape[1] > 1:
        from repro.models.layers import grad_barrier
        x = grad_barrier(x)
    if cfg.sp_residuals and x.shape[1] > 1:
        # sequence-parallel residual stream: the tensor saved by remat
        # (the scan carry) is sharded over 'model' along the sequence;
        # GSPMD turns the surrounding TP all-reduces into
        # reduce-scatter + all-gather pairs (same wire bytes)
        x = shard_hint(x, "dp", "model", None)
    a, new_kv = _attention_sublayer(
        cfg, rms_norm(x, p["ln1"], cfg.norm_eps), p,
        causal=True, window=window, pos=pos, cache_kv=cache_kv)
    x = x + a
    f, aux = _ffn_sublayer(cfg, rms_norm(x, p["ln2"], cfg.norm_eps), p)
    out = x + f
    if cfg.sp_residuals and x.shape[1] > 1:
        out = shard_hint(out, "dp", "model", None)
    return out, new_kv, aux


def _hybrid_block(cfg: ArchConfig, kind: str, x, p, *, pos=0,
                  cache=None, single_step=False):
    """kind: 'local' (windowed attention) or 'rglru'."""
    if kind == "local":
        y, new_kv = _decoder_block(cfg, x, p, pos=pos, cache_kv=cache,
                                   window=cfg.local_window)[:2]
        return y, new_kv
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    y, new_state = rglru_lib.rglru_block(
        h, p, state=cache, single_step=single_step)
    return x + y, new_state


# ====================================================================== #
# decoder-only families: train / prefill / decode
# ====================================================================== #
def _embed(params, cfg: ArchConfig, tokens, soft_emb=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if soft_emb is not None:
        x = jnp.concatenate([soft_emb.astype(x.dtype), x], axis=1)
    return shard_hint(x, "dp", None, None)


def _unembed(params, cfg: ArchConfig, x):
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    out_dtype = jnp.bfloat16 if cfg.logits_bf16 else jnp.float32
    logits = (x @ params["head"]).astype(out_dtype)
    return shard_hint(logits, "dp", None, "model")


def _remat_policy(cfg):
    name = getattr(cfg, "remat_policy", "full")
    if name == "dots":
        return jax.checkpoint_policies.dots_saveable
    if name == "dots_no_batch":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _scan_layers(cfg, params, x, layer_fn, remat: bool = True,
                 unroll: bool = False):
    fn = layer_fn
    if remat:
        fn = jax.checkpoint(layer_fn, policy=_remat_policy(cfg))

    if unroll:
        # Python loop: larger HLO, exact per-op cost analysis (the scan
        # body would otherwise be counted once by HloCostAnalysis).
        aux = 0.0
        n = jax.tree.leaves(params["layers"])[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, aux_i = fn(x, lp)
            aux = aux + aux_i
        return x, aux

    def body(carry, lp):
        h, aux = carry
        h, aux_i = fn(h, lp)
        return (h, aux + aux_i), None

    (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["layers"])
    return x, aux


def forward_train(params, cfg: ArchConfig, batch, remat: bool = True,
                  unroll: bool = False):
    """Returns (logits [B, S, V], aux_loss)."""
    tokens = batch["tokens"]
    soft = batch.get("soft_emb")
    if cfg.family == "encdec":
        return _encdec_forward_train(params, cfg, batch, remat, unroll)
    x = _embed(params, cfg, tokens, soft)

    if cfg.family == "ssm":
        def layer(h, lp):
            y, _ = ssm_lib.mamba_block(
                rms_norm(h, lp["ln"], cfg.norm_eps), lp,
                ssm_state=cfg.ssm_state)
            return h + y, 0.0
        x, aux = _scan_layers(cfg, params, x, layer, remat, unroll)
    elif cfg.family == "hybrid":
        x, aux = _hybrid_forward(params, cfg, x, remat, unroll)
    else:
        def layer(h, lp):
            h, _, aux = _decoder_block(cfg, h, lp)
            return h, aux
        x, aux = _scan_layers(cfg, params, x, layer, remat, unroll)

    if soft is not None:
        x = x[:, soft.shape[1]:]
    return _unembed(params, cfg, x), aux


def _hybrid_forward(params, cfg: ArchConfig, x, remat=True, unroll=False):
    pattern = cfg.block_pattern

    def cycle_fn(h, cyc_params):
        for j, kind in enumerate(pattern):
            h, _ = _hybrid_block(cfg, kind, h, cyc_params[f"b{j}"])
        return h, 0.0

    fn = jax.checkpoint(cycle_fn, policy=_remat_policy(cfg)) \
        if remat else cycle_fn

    if unroll:
        n = jax.tree.leaves(params["cycles"])[0].shape[0]
        aux = 0.0
        for c in range(n):
            cp = jax.tree.map(lambda a: a[c], params["cycles"])
            x, a = fn(x, cp)
            aux = aux + a
    else:
        def body(carry, cp):
            h, aux = carry
            h, a = fn(h, cp)
            return (h, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["cycles"])
    for j, tp in enumerate(params["tail"]):
        x, _ = _hybrid_block(cfg, pattern[j], x, tp)
    return x, aux


# ---------------------------------------------------------------------- #
# caches
# ---------------------------------------------------------------------- #
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               enc_len: int = 0) -> Dict[str, Any]:
    """Zeroed decode cache (ShapeDtypeStructs via jax.eval_shape in the
    dry-run).  Dense/MoE: per-layer KV; SSM: conv+state; hybrid: windowed
    KV for the attention blocks + RG-LRU states; encdec: self KV + cross
    KV over the encoder output."""
    dtype = cfg.activation_dtype
    d, kvh, hd = cfg.d_model, cfg.num_kv_heads, cfg.resolved_head_dim
    L = cfg.num_layers
    if cfg.family == "ssm":
        return {
            "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.d_inner),
                              dtype),
            "h": jnp.zeros((L, batch, cfg.d_inner, cfg.ssm_state),
                           jnp.float32),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        pattern = cfg.block_pattern
        kinds = [pattern[i % len(pattern)] for i in range(L)]
        n_attn = sum(k == "local" for k in kinds)
        n_rec = L - n_attn
        w = min(cfg.local_window, max_len)
        return {
            "k": jnp.zeros((n_attn, batch, w, kvh, hd), dtype),
            "v": jnp.zeros((n_attn, batch, w, kvh, hd), dtype),
            "conv": jnp.zeros((n_rec, batch, cfg.ssm_conv - 1, d), dtype),
            "h": jnp.zeros((n_rec, batch, d), jnp.float32),
            "pos": jnp.zeros((), jnp.int32),
        }
    cache = {
        "k": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, kvh, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.family == "encdec":
        cache["enc_k"] = jnp.zeros((L, batch, enc_len, kvh, hd), dtype)
        cache["enc_v"] = jnp.zeros((L, batch, enc_len, kvh, hd), dtype)
    return cache


def _scan_or_unroll(layer, x, stacked_xs, unroll: bool):
    """lax.scan with per-layer ys, or an equivalent Python loop."""
    if not unroll:
        return jax.lax.scan(layer, x, stacked_xs)
    n = jax.tree.leaves(stacked_xs)[0].shape[0]
    ys = []
    for i in range(n):
        xs_i = jax.tree.map(lambda a: a[i], stacked_xs)
        x, y = layer(x, xs_i)
        ys.append(y)
    stacked_ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return x, stacked_ys


def prefill(params, cfg: ArchConfig, batch, unroll: bool = False):
    """Forward over a prompt, returning last-position logits + the cache."""
    if cfg.family == "encdec":
        return _encdec_prefill(params, cfg, batch, unroll)
    tokens = batch["tokens"]
    soft = batch.get("soft_emb")
    x = _embed(params, cfg, tokens, soft)
    b, s = x.shape[:2]

    if cfg.family == "ssm":
        def layer(h, lp):
            y, st = ssm_lib.mamba_block(
                rms_norm(h, lp["ln"], cfg.norm_eps), lp,
                ssm_state=cfg.ssm_state)
            return h + y, st
        x2, states = _scan_or_unroll(layer, x, params["layers"], unroll)
        cache = {"conv": states.conv, "h": states.h,
                 "pos": jnp.asarray(s, jnp.int32)}
        return _unembed(params, cfg, x2[:, -1:]), cache

    if cfg.family == "hybrid":
        return _hybrid_prefill(params, cfg, x)

    def layer(h, lp):
        h, kv, _ = _decoder_block(cfg, h, lp)
        return h, kv
    x2, kvs = _scan_or_unroll(layer, x, params["layers"], unroll)
    cache = {"k": kvs[0], "v": kvs[1], "pos": jnp.asarray(s, jnp.int32)}
    return _unembed(params, cfg, x2[:, -1:]), cache


def _hybrid_prefill(params, cfg: ArchConfig, x):
    pattern = cfg.block_pattern
    b, s = x.shape[:2]
    w = cfg.local_window
    ks, vs, convs, hs = [], [], [], []

    def run(kind, h, p):
        if kind == "local":
            h2, kv = _hybrid_block(cfg, kind, h, p)
            k, v = kv
            # keep only the trailing window
            if k.shape[1] > w:
                k, v = k[:, -w:], v[:, -w:]
            elif k.shape[1] < w:
                pad = w - k.shape[1]
                k = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
            ks.append(k), vs.append(v)
            return h2
        h2, st = _hybrid_block(cfg, kind, h, p)
        convs.append(st.conv), hs.append(st.h)
        return h2

    n_cycles = params["cycles"]["b0"]["out" if pattern[0] != "local"
                                      else "wo"].shape[0]
    for c in range(n_cycles):
        cp = jax.tree.map(lambda a: a[c], params["cycles"])
        for j, kind in enumerate(pattern):
            x = run(kind, x, cp[f"b{j}"])
    for j, tp in enumerate(params["tail"]):
        x = run(pattern[j], x, tp)

    cache = {
        "k": jnp.stack(ks) if ks else jnp.zeros(
            (0, b, w, cfg.num_kv_heads, cfg.resolved_head_dim),
            cfg.activation_dtype),
        "v": jnp.stack(vs) if vs else jnp.zeros(
            (0, b, w, cfg.num_kv_heads, cfg.resolved_head_dim),
            cfg.activation_dtype),
        "conv": jnp.stack(convs),
        "h": jnp.stack(hs),
        "pos": jnp.asarray(s, jnp.int32),
    }
    return _unembed(params, cfg, x[:, -1:]), cache


def decode_step(params, cfg: ArchConfig, cache, batch,
                unroll: bool = False):
    """One-token decode.  batch: {"tokens": [B, 1]}.  Returns
    (logits [B, 1, V], new cache)."""
    if cfg.family == "encdec":
        return _encdec_decode(params, cfg, cache, batch, unroll)
    tokens = batch["tokens"]
    pos = cache["pos"]
    x = _embed(params, cfg, tokens)

    if cfg.family == "ssm":
        def layer(h, xs):
            lp, conv, hstate = xs
            st = ssm_lib.SSMState(conv=conv, h=hstate)
            y, st2 = ssm_lib.mamba_block(
                rms_norm(h, lp["ln"], cfg.norm_eps), lp,
                ssm_state=cfg.ssm_state, state=st, single_step=True)
            return h + y, (st2.conv, st2.h)
        x2, (convs, hs) = _scan_or_unroll(
            layer, x, (params["layers"], cache["conv"], cache["h"]),
            unroll)
        new_cache = {"conv": convs, "h": hs, "pos": pos + 1}
        return _unembed(params, cfg, x2), new_cache

    if cfg.family == "hybrid":
        return _hybrid_decode(params, cfg, cache, x)

    def layer(h, xs):
        lp, ck, cv = xs
        h, (nk, nv), _ = _decoder_block(cfg, h, lp, pos=pos,
                                        cache_kv=(ck, cv))
        return h, (nk, nv)
    x2, (nks, nvs) = _scan_or_unroll(
        layer, x, (params["layers"], cache["k"], cache["v"]), unroll)
    new_cache = dict(cache, k=nks, v=nvs, pos=pos + 1)
    return _unembed(params, cfg, x2), new_cache


def _hybrid_decode(params, cfg: ArchConfig, cache, x):
    pattern = cfg.block_pattern
    pos = cache["pos"]
    w = cache["k"].shape[2]
    ai = 0
    ri = 0
    nks, nvs, nconvs, nhs = ([None] * cache["k"].shape[0],
                             [None] * cache["v"].shape[0],
                             [None] * cache["conv"].shape[0],
                             [None] * cache["h"].shape[0])

    def run(kind, h, p, ai, ri):
        if kind == "local":
            # ring-buffer local attention: write at pos % w, attend over
            # the window (RoPE applied at absolute positions pre-write).
            ck, cv = cache["k"][ai], cache["v"][ai]
            b = h.shape[0]
            hn = rms_norm(h, p["ln1"], cfg.norm_eps)
            hd = cfg.resolved_head_dim
            q = (hn @ p["wq"]).reshape(b, 1, cfg.num_heads, hd)
            k = (hn @ p["wk"]).reshape(b, 1, cfg.num_kv_heads, hd)
            v = (hn @ p["wv"]).reshape(b, 1, cfg.num_kv_heads, hd)
            q = apply_rope(q, pos[None], cfg.rope_theta)
            k = apply_rope(k, pos[None], cfg.rope_theta)
            slot = jnp.mod(pos, w)
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, slot, 0, 0))
            # valid entries: age < window (ring semantics, RoPE absolute)
            from repro.models.layers import _direct_attention, _repeat_kv
            group = cfg.num_heads // cfg.num_kv_heads
            kt = _repeat_kv(jnp.moveaxis(ck, 1, 2), group)
            vt = _repeat_kv(jnp.moveaxis(cv, 1, 2), group)
            qt = jnp.moveaxis(q, 1, 2)
            n_valid = jnp.minimum(pos + 1, w)
            out = _direct_attention(qt, kt, vt, causal=False, window=None,
                                    kv_len=n_valid)
            out = jnp.moveaxis(out, 1, 2).reshape(b, 1,
                                                  cfg.num_heads * hd)
            h = h + out @ p["wo"]
            f, _ = _ffn_sublayer(cfg, rms_norm(h, p["ln2"], cfg.norm_eps), p)
            nks[ai], nvs[ai] = ck, cv
            return h + f, ai + 1, ri
        st = rglru_lib.RGLRUState(conv=cache["conv"][ri], h=cache["h"][ri])
        h2, st2 = _hybrid_block(cfg, kind, h, p, cache=st, single_step=True)
        nconvs[ri], nhs[ri] = st2.conv, st2.h
        return h2, ai, ri + 1

    n_cycles = jax.tree.leaves(params["cycles"])[0].shape[0]
    for c in range(n_cycles):
        cp = jax.tree.map(lambda a: a[c], params["cycles"])
        for j, kind in enumerate(pattern):
            x, ai, ri = run(kind, x, cp[f"b{j}"], ai, ri)
    for j, tp in enumerate(params["tail"]):
        x, ai, ri = run(pattern[j], x, tp, ai, ri)

    new_cache = {
        "k": jnp.stack(nks) if nks else cache["k"],
        "v": jnp.stack(nvs) if nvs else cache["v"],
        "conv": jnp.stack(nconvs) if nconvs else cache["conv"],
        "h": jnp.stack(nhs) if nhs else cache["h"],
        "pos": pos + 1,
    }
    return _unembed(params, cfg, x), new_cache


# ====================================================================== #
# encoder-decoder (Whisper backbone)
# ====================================================================== #
def _encoder_forward(params, cfg: ArchConfig, frames, unroll: bool = False):
    """frames: [B, S_enc, D] precomputed frame embeddings (stub
    frontend)."""
    def layer(h, lp):
        a, _ = _attention_sublayer(
            cfg, rms_norm(h, lp["ln1"], cfg.norm_eps), lp, causal=False)
        h = h + a
        f = swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps),
                   lp["wg"], lp["wu"], lp["wd"])
        return h + f, None
    fn = jax.checkpoint(layer,
                        policy=jax.checkpoint_policies.nothing_saveable)
    if unroll:
        x = frames
        n = jax.tree.leaves(params["encoder"])[0].shape[0]
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], params["encoder"])
            x, _ = fn(x, lp)
    else:
        x, _ = jax.lax.scan(lambda h, lp: fn(h, lp), frames,
                            params["encoder"])
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


def _cross_kv(cfg: ArchConfig, enc_out, lp):
    b, s, d = enc_out.shape
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = (enc_out @ lp["x_wk"]).reshape(b, s, kvh, hd)
    v = (enc_out @ lp["x_wv"]).reshape(b, s, kvh, hd)
    return k, v


def _dec_layer(cfg: ArchConfig, h, lp, enc_kv, *, pos=0, cache_kv=None):
    a, new_kv = _attention_sublayer(
        cfg, rms_norm(h, lp["ln1"], cfg.norm_eps), lp,
        causal=True, pos=pos, cache_kv=cache_kv)
    h = h + a
    c = _cross_attention(cfg, rms_norm(h, lp["lnx"], cfg.norm_eps), lp,
                         *enc_kv)
    h = h + c
    f = swiglu(rms_norm(h, lp["ln2"], cfg.norm_eps),
               lp["wg"], lp["wu"], lp["wd"])
    return h + f, new_kv


def _encdec_forward_train(params, cfg: ArchConfig, batch, remat=True,
                          unroll=False):
    enc_out = _encoder_forward(params, cfg, batch["frames"], unroll=unroll)
    x = _embed(params, cfg, batch["tokens"])

    def layer(h, lp):
        enc_kv = _cross_kv(cfg, enc_out, lp)
        h, _ = _dec_layer(cfg, h, lp, enc_kv)
        return h, 0.0
    x, aux = _scan_layers(cfg, {"layers": params["layers"]}, x, layer,
                          remat, unroll)
    return _unembed(params, cfg, x), aux


def _encdec_prefill(params, cfg: ArchConfig, batch, unroll: bool = False):
    """Encoder pass + cross-KV materialization + first decoder position."""
    enc_out = _encoder_forward(params, cfg, batch["frames"], unroll=unroll)
    tokens = batch["tokens"]           # [B, S_dec] decoder prompt
    x = _embed(params, cfg, tokens)
    s = tokens.shape[1]

    def layer(h, lp):
        enc_kv = _cross_kv(cfg, enc_out, lp)
        h, kv = _dec_layer(cfg, h, lp, enc_kv)
        return h, (kv, enc_kv)
    x2, (kvs, enc_kvs) = _scan_or_unroll(layer, x, params["layers"],
                                         unroll)
    cache = {"k": kvs[0], "v": kvs[1],
             "enc_k": enc_kvs[0], "enc_v": enc_kvs[1],
             "pos": jnp.asarray(s, jnp.int32)}
    return _unembed(params, cfg, x2[:, -1:]), cache


def _encdec_decode(params, cfg: ArchConfig, cache, batch,
                   unroll: bool = False):
    pos = cache["pos"]
    x = _embed(params, cfg, batch["tokens"])

    def layer(h, xs):
        lp, ck, cv, ek, ev = xs
        h, (nk, nv) = _dec_layer(cfg, h, lp, (ek, ev), pos=pos,
                                 cache_kv=(ck, cv))
        return h, (nk, nv)
    x2, (nks, nvs) = _scan_or_unroll(
        layer, x, (params["layers"], cache["k"], cache["v"],
                   cache["enc_k"], cache["enc_v"]), unroll)
    new_cache = dict(cache, k=nks, v=nvs, pos=pos + 1)
    return _unembed(params, cfg, x2), new_cache


__all__ = [
    "init_params", "param_specs", "forward_train", "prefill",
    "decode_step", "init_cache",
]
