"""Mamba-1 (selective SSM) blocks, TPU-adapted.

The CUDA reference implements the selective scan as a fused kernel over
sequential timesteps.  On TPU we recast the recurrence as a chunked
associative linear scan (h_t = a_t h_{t-1} + b_t), which maps onto the
VPU/MXU and keeps the materialized state-expansion tensor bounded by the
chunk length (see models/scan_utils.py).  Decode carries a constant-size
(conv window, SSM state) pair -- this is why falcon-mamba runs the
long_500k cell.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import shard_hint
from repro.models.scan_utils import chunked_linear_scan


class SSMState(NamedTuple):
    conv: jax.Array   # [B, d_conv - 1, d_inner]  (shift register)
    h: jax.Array      # [B, d_inner, N]


def _causal_conv(x: jax.Array, w: jax.Array, prefix: jax.Array | None = None):
    """Depthwise causal conv along seq.  x: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prefix, x], axis=1)
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j:j + x.shape[1]] * w[j]
    new_prefix = xp[:, x.shape[1]:]
    return out, new_prefix


def mamba_block(x: jax.Array, p: dict, *, ssm_state: int, chunk: int = 128,
                state: SSMState | None = None, single_step: bool = False,
                use_kernel: bool = False) -> Tuple[jax.Array, SSMState]:
    """One Mamba-1 mixing block.

    x: [B, S, D].  Params ``p``:
      in_proj [D, 2*di], conv_w [K, di], x_proj [di, R+2N], dt_w [R, di],
      dt_b [di], a_log [di, N], d_skip [di], out_proj [di, D].
    Returns (y [B, S, D], new_state).
    """
    b, s, d = x.shape
    di = p["a_log"].shape[0]
    n = ssm_state
    r = p["dt_w"].shape[0]

    xz = shard_hint(x @ p["in_proj"], "dp", None, "model")  # [B, S, 2di]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard_hint(xs, "dp", None, "model")
    z = shard_hint(z, "dp", None, "model")

    conv_prefix = state.conv if state is not None else None
    xs, new_conv = _causal_conv(xs, p["conv_w"], conv_prefix)
    xs = jax.nn.silu(xs)

    dbc = xs @ p["x_proj"]                      # [B, S, R+2N]
    dt, b_ssm, c_ssm = jnp.split(dbc, [r, r + n], axis=-1)
    delta = jax.nn.softplus(dt @ p["dt_w"] + p["dt_b"])     # [B, S, di]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # [di, N]

    # discretize: a_bar = exp(delta * A); b_bar = delta * B * x
    delta32 = delta.astype(jnp.float32)
    a_bar = jnp.exp(delta32[..., None] * a)                  # [B, S, di, N]
    b_bar = (delta32[..., None]
             * b_ssm.astype(jnp.float32)[..., None, :]
             * xs.astype(jnp.float32)[..., None])            # [B, S, di, N]
    # keep the state-expansion tensors batch x TP sharded; without the
    # hint GSPMD replicates them across the model axis (16x traffic)
    a_bar = shard_hint(a_bar, "dp", None, "model", None)
    b_bar = shard_hint(b_bar, "dp", None, "model", None)

    h0 = (state.h if state is not None
          else jnp.zeros((b, di, n), jnp.float32))
    if single_step:
        assert s == 1
        h_new = a_bar[:, 0] * h0 + b_bar[:, 0]               # [B, di, N]
        h_all = h_new[:, None]
        y = jnp.einsum("bsdn,bsn->bsd", h_all,
                       c_ssm.astype(jnp.float32))            # [B, S, di]
    elif use_kernel:
        # fused Pallas scan: the state expansion never touches HBM
        # (repro/kernels/selective_scan.py; EXPERIMENTS.md §Perf Cell C)
        import math as _math
        from repro.kernels.selective_scan import selective_scan_trainable
        bd = _math.gcd(di, 256)
        ck = _math.gcd(s, 128)
        y, h_new = selective_scan_trainable(
            delta32, xs.astype(jnp.float32), b_ssm.astype(jnp.float32),
            c_ssm.astype(jnp.float32), a, h0, bd, ck,
            jax.default_backend() != "tpu")
    else:
        h_all, h_new = chunked_linear_scan(a_bar, b_bar, h0, chunk=chunk)
        h_all = shard_hint(h_all, "dp", None, "model", None)
        y = jnp.einsum("bsdn,bsn->bsd", h_all,
                       c_ssm.astype(jnp.float32))            # [B, S, di]
    y = y + xs.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = shard_hint(y @ p["out_proj"], "dp", None, None)
    return out, SSMState(conv=new_conv, h=h_new)


def init_mamba_params(key, d_model: int, d_inner: int, ssm_state: int,
                      dt_rank: int, d_conv: int, dtype) -> dict:
    ks = jax.random.split(key, 6)
    scale = d_model ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d_model, 2 * d_inner)) * scale
                    ).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner)) * 0.1
                   ).astype(dtype),
        "x_proj": (jax.random.normal(ks[2], (d_inner, dt_rank + 2 * ssm_state))
                   * d_inner ** -0.5).astype(dtype),
        "dt_w": (jax.random.normal(ks[3], (dt_rank, d_inner))
                 * dt_rank ** -0.5).astype(dtype),
        "dt_b": jnp.full((d_inner,), -4.6, dtype),   # softplus^-1(0.01)
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ssm_state + 1, dtype=jnp.float32),
            (d_inner, ssm_state))),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (d_inner, d_model))
                     * d_inner ** -0.5).astype(dtype),
    }


__all__ = ["mamba_block", "init_mamba_params", "SSMState"]
