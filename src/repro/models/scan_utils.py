"""Chunked linear-recurrence scan shared by the SSM and RG-LRU blocks.

Computes h_t = a_t * h_{t-1} + b_t over the sequence axis by scanning
fixed-size chunks (sequential lax.scan) and running an associative scan
inside each chunk.  This bounds the materialized intermediate to
[B, chunk, ...] instead of [B, S, ...] * log2(S) -- essential for the 4k
train and 500k decode shapes to fit per-chip HBM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

DEFAULT_CHUNK = 128


def _combine(left, right):
    a_l, b_l = left
    a_r, b_r = right
    return a_r * a_l, a_r * b_l + b_r


def chunked_linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
                        chunk: int = DEFAULT_CHUNK):
    """h_t = a_t * h_{t-1} + b_t along axis 1.

    a, b: [B, S, ...] (same shape); h0: [B, ...].
    Returns (h_all [B, S, ...], h_final [B, ...]).
    """
    bsz, s = a.shape[0], a.shape[1]
    chunk = min(chunk, s)
    from repro.models import layers as _layers
    if _layers.inner_unroll_enabled():
        # measurement mode: bound the unroll count; total scan traffic is
        # linear in S regardless of chunking, so widening chunks keeps
        # the cost accounting faithful while keeping the HLO small.
        chunk = max(chunk, -(-s // 8))
        while s % chunk != 0:
            chunk += 1
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    rest = a.shape[2:]

    a_c = a.reshape((bsz, n_chunks, chunk) + rest)
    b_c = b.reshape((bsz, n_chunks, chunk) + rest)
    a_c = jnp.moveaxis(a_c, 1, 0)  # [n, B, chunk, ...]
    b_c = jnp.moveaxis(b_c, 1, 0)

    @jax.checkpoint
    def step(h, ab):
        a_i, b_i = ab
        # within-chunk prefix combine
        a_cum, b_cum = jax.lax.associative_scan(_combine, (a_i, b_i), axis=1)
        h_chunk = a_cum * h[:, None] + b_cum
        return h_chunk[:, -1], h_chunk

    if _layers.inner_unroll_enabled():
        h = h0
        outs = []
        for i in range(n_chunks):
            h, h_chunk = step(h, (a_c[i], b_c[i]))
            outs.append(h_chunk)
        h_final = h
        h_all = jnp.stack(outs)
    else:
        h_final, h_all = jax.lax.scan(step, h0, (a_c, b_c))
    h_all = jnp.moveaxis(h_all, 0, 1).reshape((bsz, s) + rest)
    return h_all, h_final


def linear_scan_step(a: jax.Array, b: jax.Array, h: jax.Array):
    """Single decode step of the same recurrence."""
    return a * h + b


__all__ = ["chunked_linear_scan", "linear_scan_step", "DEFAULT_CHUNK"]
