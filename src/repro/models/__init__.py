"""Model zoo: functional JAX implementations of the assigned families."""

from repro.models import (frontend, layers, moe, moe_ep, paged, rglru,
                          scan_utils, ssm)
from repro.models.paged import (decode_step_paged, forward_paged, init_pages,
                                supports_paged)
from repro.models.transformer import (decode_step, forward_train, init_cache,
                                      init_params, param_specs, prefill)

__all__ = [
    "frontend", "layers", "moe", "moe_ep", "paged", "rglru", "scan_utils",
    "ssm",
    "decode_step", "decode_step_paged", "forward_train", "forward_paged",
    "init_cache", "init_pages", "init_params", "param_specs", "prefill",
    "supports_paged",
]
