"""Model zoo: functional JAX implementations of the assigned families."""

from repro.models import frontend, layers, moe, rglru, scan_utils, ssm
from repro.models.transformer import (decode_step, forward_train, init_cache,
                                      init_params, param_specs, prefill)

__all__ = [
    "frontend", "layers", "moe", "rglru", "scan_utils", "ssm",
    "decode_step", "forward_train", "init_cache", "init_params",
    "param_specs", "prefill",
]
