"""Expert-parallel MoE dispatch through the CollectiveEngine's AllToAll.

The existing shard_map EP path (``moe.moe_ffn_sharded``) keeps tokens
*replicated* over the expert axis: every expert rank runs the router for
the whole batch shard and only the combine communicates (one psum).
That sidesteps the EP exchange entirely -- fine for correctness, but it
is not the traffic pattern a production expert-parallel MoE runs, and it
leaves the all-to-all outside the model-driven collective stack.

This module is the real thing: tokens are sharded over the EP axes,
each device routes only its own tokens, and dispatch/combine are
explicit **all-to-all** exchanges routed through
``CollectiveEngine.all_to_all_multi`` -- so the planner prices the
exchange per axis (`hierarchical` 2-phase intra-pod/inter-pod vs
`sequential` vs `flat` single-shot, plus chunk-pipelined variants that
overlap the inter-pod phase of one payload slice with the intra-pod
phase of the next), heterogeneous ``FabricTopology`` constants
included, and the decision lands in the persistent cache.

Layout (inside one shard_map over the mesh):

* tokens  ``x [G, gs, D]`` -- G sharded over ``dp_axes + ep_axes``;
* experts ``w_* [E, ...]`` -- E sharded over ``ep_axes`` (row-major
  folded rank r owns experts ``[r*E_l, (r+1)*E_l)``), optionally FSDP
  over a spare data axis, gathered just-in-time;
* dispatch: the group-local sort of ``moe.moe_ffn`` builds the
  ``[G_l, E, Cap, D]`` buffer, reordered destination-rank-major and
  exchanged (chunk r -> rank r); the reverse exchange brings expert
  outputs home for the weighted combine.

Per-token results are bit-comparable to ``moe.moe_ffn`` up to fp32
reassociation: routing, capacity, and the keep/pos bookkeeping are
identical -- only *where* each expert's FFN runs differs.

``algorithm`` selects the exchange backend: ``"lax"`` is the bare
``lax.all_to_all`` single-shot (the GSPMD-equivalent baseline), anything
else is handed to the engine (``"auto"``, a plan shape, or a 1D backend
name).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.moe import moe_capacity, moe_ffn_sharded


_warned_fallback = False


def _fallback(reason: str, x, router_w, w_gate, w_up, w_down, *,
              top_k: int, capacity_factor: float):
    """Route through the replicated-token path, loudly: a config that
    silently skips the EP exchange would make --moe-ep smokes vacuous."""
    global _warned_fallback
    if not _warned_fallback:
        _warned_fallback = True
        print(f"[moe_ep] WARNING: falling back to the replicated-token "
              f"shard_map path (no all-to-all dispatch): {reason}")
    return moe_ffn_sharded(x, router_w, w_gate, w_up, w_down,
                           top_k=top_k, capacity_factor=capacity_factor)


def _ep_axes_for(mesh) -> Tuple[str, ...]:
    """Mesh axes the expert dim shards over: the model axis when the
    mesh has a non-trivial one, else the folded DP axes (the
    ("pod", "data") expert mesh the planner's 2-phase decomposition
    targets).  Size-1 axes are skipped so a trivial model axis does
    not shadow a usable expert mesh."""
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    if sizes.get("model", 0) > 1:
        return ("model",)
    return tuple(a for a in ("pod", "data") if sizes.get(a, 0) > 1)


def _moe_ep_local(x, router_w, w_gate, w_up, w_down, *, top_k: int,
                  capacity_factor: float, ep_axes: Tuple[str, ...],
                  token_axes: Tuple[str, ...], fsdp_axis: Optional[str],
                  algorithm: str, engine):
    """Per-device body (inside shard_map).

    x: [G_l, gs, D] (local token groups); router_w: [D, E] replicated;
    w_gate/w_up: [E_l, D(_fsdp), F]; w_down: [E_l, F, D(_fsdp)].
    """
    g, gs, d = x.shape
    e_total = router_w.shape[1]
    if fsdp_axis is not None:
        w_gate = lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)
        w_up = lax.all_gather(w_up, fsdp_axis, axis=1, tiled=True)
        w_down = lax.all_gather(w_down, fsdp_axis, axis=2, tiled=True)
    e_local = w_gate.shape[0]
    n_ranks = e_total // e_local
    cap = moe_capacity(gs, e_total, top_k, capacity_factor)

    # ---- router + group-local sort dispatch (identical to moe_ffn) ----
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, e_total,
                                         dtype=jnp.float32), axis=2),
                  axis=(0, 1))
    aux = e_total * jnp.sum(me * ce) / top_k
    for ax in token_axes:
        aux = lax.pmean(aux, ax)

    flat_e = top_e.reshape(g, gs * top_k)
    flat_w = top_p.reshape(g, gs * top_k).astype(x.dtype)
    sort_idx = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    counts = jnp.sum(jax.nn.one_hot(flat_e, e_total, dtype=jnp.int32),
                     axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts
    starts_sorted = jnp.take_along_axis(starts, sorted_e, axis=1)
    pos = jnp.arange(gs * top_k)[None, :] - starts_sorted
    keep = pos < cap
    token_of = sort_idx // top_k
    g_idx = jnp.arange(g)[:, None]

    x_sel = jnp.take_along_axis(x, token_of[..., None], axis=1)
    x_sel = jnp.where(keep[..., None], x_sel, 0)
    buf = jnp.zeros((g, e_total, cap, d), dtype=x.dtype)
    buf = buf.at[g_idx, sorted_e, pos].set(x_sel, mode="drop")

    # ---- dispatch all-to-all: chunk r carries rank r's experts ----
    def exchange(v):
        flat = v.reshape((n_ranks * g * e_local * cap, d))
        if algorithm == "lax":
            axis = ep_axes if len(ep_axes) > 1 else ep_axes[0]
            out = lax.all_to_all(flat, axis, split_axis=0, concat_axis=0,
                                 tiled=True)
        else:
            out = engine.all_to_all_multi(flat, ep_axes,
                                          algorithm=algorithm)
        return out.reshape((n_ranks, g, e_local, cap, d))

    send = buf.reshape(g, n_ranks, e_local, cap, d).transpose(
        1, 0, 2, 3, 4)
    recv = exchange(send)           # [src_rank, their G_l, my E_l, cap, D]

    # ---- expert compute on every rank's tokens for my experts ----
    tok = recv.transpose(2, 0, 1, 3, 4).reshape(
        e_local, n_ranks * g * cap, d)
    h = jnp.einsum("etd,edf->etf", tok, w_gate)
    u = jnp.einsum("etd,edf->etf", tok, w_up)
    y = jnp.einsum("etf,efd->etd", jax.nn.silu(h) * u, w_down)

    # ---- combine all-to-all: results home to their token owners ----
    y = y.reshape(e_local, n_ranks, g, cap, d).transpose(1, 2, 0, 3, 4)
    back = exchange(y)              # [expert rank, my G_l, its E_l, cap, D]
    y_buf = back.transpose(1, 0, 2, 3, 4).reshape(g, e_total, cap, d)

    w_sorted = jnp.take_along_axis(flat_w, sort_idx, axis=1)
    y_tok = y_buf[g_idx, sorted_e, jnp.where(keep, pos, 0)]
    y_tok = jnp.where(keep[..., None], y_tok, 0) * w_sorted[..., None]
    out = jnp.zeros_like(x)
    out = out.at[g_idx, token_of].add(y_tok)
    return out, aux


def moe_ffn_ep(x, router_w, w_gate, w_up, w_down, *, top_k: int,
               capacity_factor: float = 1.25, algorithm: str = "auto",
               engine=None):
    """Engine-routed expert-parallel MoE when a mesh is ambient; falls
    back to ``moe_ffn_sharded`` (and transitively the GSPMD path) when
    there is no mesh or the shapes don't tile the EP world."""
    from repro.models.layers import _ambient_mesh
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _ambient_mesh()
    ep_axes = _ep_axes_for(mesh) if mesh is not None else ()
    if mesh is None or not ep_axes:
        return _fallback("no ambient mesh / no EP-capable axis", x,
                         router_w, w_gate, w_up, w_down, top_k=top_k,
                         capacity_factor=capacity_factor)
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data")
                    if a in names and a not in ep_axes)
    token_axes = dp_axes + ep_axes
    fsdp_axis = ("data" if "data" in names and "data" not in ep_axes
                 else None)
    e_total = router_w.shape[1]
    n_ranks = 1
    for a in ep_axes:
        n_ranks *= sizes[a]
    n_tok = n_ranks
    for a in dp_axes:
        n_tok *= sizes[a]
    if (n_ranks == 1 or e_total % n_ranks != 0
            or x.shape[0] % n_tok != 0):
        return _fallback(
            f"E={e_total} over {n_ranks} EP ranks ({ep_axes}) or "
            f"G={x.shape[0]} over {n_tok} token shards does not tile",
            x, router_w, w_gate, w_up, w_down, top_k=top_k,
            capacity_factor=capacity_factor)
    if engine is None and algorithm != "lax":
        from repro.collectives.api import get_engine
        engine = get_engine()

    ep_spec = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    tok_spec = token_axes if len(token_axes) > 1 else token_axes[0]
    body = functools.partial(
        _moe_ep_local, top_k=top_k, capacity_factor=capacity_factor,
        ep_axes=ep_axes, token_axes=token_axes, fsdp_axis=fsdp_axis,
        algorithm=algorithm, engine=engine)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(tok_spec, None, None),           # x (tokens)
                  P(),                               # router (replicated)
                  P(ep_spec, fsdp_axis, None),       # w_gate
                  P(ep_spec, fsdp_axis, None),       # w_up
                  P(ep_spec, None, fsdp_axis)),      # w_down
        out_specs=(P(tok_spec, None, None), P()),
        check_rep=False)
    return fn(x, router_w, w_gate, w_up, w_down)


__all__ = ["moe_ffn_ep"]
