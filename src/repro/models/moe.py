"""Capacity-based top-k Mixture-of-Experts FFN (expert-parallel friendly).

Group-local sort-based dispatch: tokens are organized as [G, gs, D] with G
(the batch/sequence groups) sharded over the data axis and experts sharded
over the model axis.  The argsort runs along the *unsharded* gs*k axis, so
dispatch needs no cross-device sort; the scatter into the [G, E, Cap, D]
expert buffers is where GSPMD inserts the all-to-all -- the EP pattern.

FLOPs are proportional to tokens * top_k * capacity_factor (no dense
all-experts waste), which keeps the roofline's MODEL_FLOPS/HLO_FLOPs
ratio honest for the MoE architectures.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import shard_hint


def moe_capacity(tokens_per_group: int, num_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    cap = int(tokens_per_group * top_k * capacity_factor / num_experts)
    return max(cap, top_k)


def moe_ffn(x: jax.Array, router_w: jax.Array, w_gate: jax.Array,
            w_up: jax.Array, w_down: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """x: [G, gs, D]; router_w: [D, E]; w_gate/up: [E, D, F]; w_down:
    [E, F, D].  Returns (out [G, gs, D], aux_loss scalar)."""
    g, gs, d = x.shape
    e = router_w.shape[1]
    cap = moe_capacity(gs, e, top_k, capacity_factor)

    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # [G,gs,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)                 # [G, gs, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)     # renormalize

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                          # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=2),
        axis=(0, 1))
    aux_loss = e * jnp.sum(me * ce) / top_k

    # ---- group-local sort-based dispatch ----
    flat_e = top_e.reshape(g, gs * top_k)                      # [G, gsk]
    flat_w = top_p.reshape(g, gs * top_k).astype(x.dtype)
    sort_idx = jnp.argsort(flat_e, axis=1, stable=True)        # local sort
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    # position of each entry within its expert
    counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts               # [G, E]
    starts_sorted = jnp.take_along_axis(starts, sorted_e, axis=1)
    pos = jnp.arange(gs * top_k)[None, :] - starts_sorted      # [G, gsk]
    keep = pos < cap

    token_of = sort_idx // top_k                               # [G, gsk]
    g_idx = jnp.arange(g)[:, None]
    x_sel = jnp.take_along_axis(
        x, token_of[..., None], axis=1)                        # [G, gsk, D]
    x_sel = jnp.where(keep[..., None], x_sel, 0)

    buf = jnp.zeros((g, e, cap, d), dtype=x.dtype)
    buf = buf.at[g_idx, sorted_e, pos].set(x_sel, mode="drop")
    # EP: expert dim over the model axis (the scatter above is where the
    # all-to-all happens); groups stay on the DP axes
    buf = shard_hint(buf, "dp", "model", None, None)

    # ---- expert compute (E sharded over the model axis) ----
    h = shard_hint(jnp.einsum("gecd,edf->gecf", buf, w_gate),
                   "dp", "model", None, None)
    u = shard_hint(jnp.einsum("gecd,edf->gecf", buf, w_up),
                   "dp", "model", None, None)
    hidden = jax.nn.silu(h) * u
    y = jnp.einsum("gecf,efd->gecd", hidden, w_down)           # [G, E, Cap, D]
    y = shard_hint(y, "dp", "model", None, None)

    # ---- combine ----
    w_sorted = jnp.take_along_axis(flat_w, sort_idx, axis=1)
    y_tok = y[g_idx, sorted_e, pos]                            # [G, gsk, D]
    y_tok = jnp.where(keep[..., None], y_tok, 0) * w_sorted[..., None]
    out = jnp.zeros_like(x)
    out = out.at[g_idx, token_of].add(y_tok)
    return shard_hint(out, "dp", None, None), aux_loss


# ---------------------------------------------------------------------- #
# shard_map expert-parallel path
#
# The jnp/GSPMD path above lets the partitioner handle the dispatch
# scatter -- which it resolves as full-buffer cross-device gathers
# (~600 GB/layer/device on arctic-480b train_4k; see EXPERIMENTS.md
# §Perf).  This path makes the EP structure explicit instead:
#
#  * tokens are batch-sharded over the DP axes and replicated over
#    'model'; every model rank runs the (cheap) router + local sort for
#    its data shard -> the dispatch buffer slice [G_l, E_local, Cap, D]
#    for its OWN experts requires NO communication;
#  * expert weights are E-sharded over 'model' (+ FSDP over 'data'),
#    all-gathered over 'data' just-in-time;
#  * the combine is a scatter-add of each rank's expert outputs followed
#    by one [G_l, gs, D] psum over 'model' -- the classic EP exchange.
# ---------------------------------------------------------------------- #
def _moe_local(x, router_w, w_gate, w_up, w_down, *, top_k: int,
               capacity_factor: float, model_axis: str, fsdp_axis,
               dp_axes) -> Tuple[jax.Array, jax.Array]:
    """Per-device body (inside shard_map).

    x: [G_l, gs, D] (local data shard, replicated over model)
    router_w: [D, E] (replicated)
    w_gate/w_up: [E_local, D_fsdp, F]; w_down: [E_local, F, D_fsdp].
    """
    g, gs, d = x.shape
    e_total = router_w.shape[1]
    if fsdp_axis is not None:
        w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up, fsdp_axis, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, fsdp_axis, axis=2, tiled=True)
    e_local = w_gate.shape[0]
    rank = jax.lax.axis_index(model_axis)
    cap = moe_capacity(gs, e_total, top_k, capacity_factor)

    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, e_total,
                                         dtype=jnp.float32), axis=2),
                  axis=(0, 1))
    aux = e_total * jnp.sum(me * ce) / top_k
    for ax in dp_axes:
        aux = jax.lax.pmean(aux, ax)

    flat_e = top_e.reshape(g, gs * top_k)
    flat_w = top_p.reshape(g, gs * top_k).astype(x.dtype)
    sort_idx = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    counts = jnp.sum(jax.nn.one_hot(flat_e, e_total, dtype=jnp.int32),
                     axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts
    starts_sorted = jnp.take_along_axis(starts, sorted_e, axis=1)
    pos = jnp.arange(gs * top_k)[None, :] - starts_sorted
    keep = pos < cap
    token_of = sort_idx // top_k
    g_idx = jnp.arange(g)[:, None]

    # local-expert coordinates: expert eid lives on rank eid // e_local
    local_e = sorted_e - rank * e_local
    mine = (local_e >= 0) & (local_e < e_local) & keep
    x_sel = jnp.take_along_axis(x, token_of[..., None], axis=1)
    x_sel = jnp.where(mine[..., None], x_sel, 0)
    buf = jnp.zeros((g, e_local, cap, d), dtype=x.dtype)
    buf = buf.at[g_idx, jnp.clip(local_e, 0, e_local - 1),
                 jnp.where(mine, pos, cap)].set(x_sel, mode="drop")

    h = jnp.einsum("gecd,edf->gecf", buf, w_gate)
    u = jnp.einsum("gecd,edf->gecf", buf, w_up)
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u, w_down)

    w_sorted = jnp.take_along_axis(flat_w, sort_idx, axis=1)
    y_tok = y[g_idx, jnp.clip(local_e, 0, e_local - 1),
              jnp.where(mine, pos, 0)]
    y_tok = jnp.where(mine[..., None], y_tok, 0) * w_sorted[..., None]
    out = jnp.zeros_like(x)
    out = out.at[g_idx, token_of].add(y_tok)
    # combine: each rank contributed its experts' tokens
    out = jax.lax.psum(out, model_axis)
    return out, aux


def moe_ffn_sharded(x, router_w, w_gate, w_up, w_down, *, top_k: int,
                    capacity_factor: float = 1.25):
    """Expert-parallel MoE via shard_map when a mesh is ambient; falls
    back to the GSPMD path otherwise (unit tests, single device)."""
    from repro.models.layers import _ambient_mesh
    mesh = _ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe_ffn(x, router_w, w_gate, w_up, w_down, top_k=top_k,
                       capacity_factor=capacity_factor)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    import functools

    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    fsdp_axis = "data" if "data" in names else None
    e_total = router_w.shape[1]
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    if (e_total % model_size != 0
            or (dp and x.shape[0] % mesh.shape[dp[0]] != 0)):
        return moe_ffn(x, router_w, w_gate, w_up, w_down, top_k=top_k,
                       capacity_factor=capacity_factor)

    body = functools.partial(
        _moe_local, top_k=top_k, capacity_factor=capacity_factor,
        model_axis="model", fsdp_axis=fsdp_axis, dp_axes=dp)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec, None, None),            # x
                  P(),                               # router (replicated)
                  P("model", fsdp_axis, None),       # w_gate
                  P("model", fsdp_axis, None),       # w_up
                  P("model", None, fsdp_axis)),      # w_down
        out_specs=(P(dp_spec, None, None), P()),
        check_rep=False)
    return fn(x, router_w, w_gate, w_up, w_down)


__all__ = ["moe_ffn", "moe_ffn_sharded", "moe_capacity"]
