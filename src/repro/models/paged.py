"""Paged-KV-cache model entry points (serving's continuous-batching
counterpart to the dense ``prefill``/``decode_step`` cache in
transformer.py).

The physical cache is a pool of fixed-size blocks shared by every
request in the batch::

    pages = {"k": [L, N, bs, Hkv, Hd], "v": [L, N, bs, Hkv, Hd]}

Per-request state lives host-side in the serving scheduler and is passed
in per call: ``block_tables`` [B, M] int32 (pool indices in logical
order) and ``ctx_lens`` [B] int32 (tokens already cached).  Block 0 is
reserved as a scratch sink: writes from padded chunk tails and inactive
batch rows are redirected there, so idle decode slots never clobber live
cache state (this is what lets the scheduler admit/retire every step
instead of padding waves with garbage rows).

One forward handles both phases:

* **chunked prefill** -- ``forward_paged`` with T > 1 processes a chunk
  of the prompt (long prompts stream in without stalling decode);
* **decode** -- T = 1; off-TPU attention runs a gathered pure-jnp path,
  on TPU the Pallas block-indexed kernel
  (``repro.kernels.paged_attention``) reads only the blocks each request
  references.

Supported families: dense and moe decoders (llava-style vision via
``soft_emb`` on the first chunk).  SSM/hybrid/encdec keep the dense
cache path -- their decode state is O(1) or windowed already.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, rms_norm, shard_hint
from repro.models.transformer import _ffn_sublayer, _unembed

Params = Dict[str, Any]

PAGED_FAMILIES = ("dense", "moe")


def supports_paged(cfg: ArchConfig) -> bool:
    return cfg.family in PAGED_FAMILIES


def init_pages(cfg: ArchConfig, num_blocks: int,
               block_size: int) -> Dict[str, jax.Array]:
    """Zeroed physical block pool (block 0 is the scratch sink)."""
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged KV cache supports families {PAGED_FAMILIES}, "
            f"not {cfg.family!r} (constant-state families keep the dense "
            f"cache)")
    shape = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads,
             cfg.resolved_head_dim)
    dtype = cfg.activation_dtype
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def copy_blocks(pages: Dict[str, jax.Array], src: jax.Array,
                dst: jax.Array) -> Dict[str, jax.Array]:
    """Copy whole physical blocks ``src[i] -> dst[i]`` in every layer
    of the pool (copy-on-write for shared prefix blocks: the scheduler
    re-points a request's table at a private copy before the request
    writes into a block other tables still read).

    ``src``/``dst``: [n] int32 pool indices; destinations must be
    distinct (they are freshly allocated), sources may repeat.
    """
    return {name: arr.at[:, dst].set(arr[:, src])
            for name, arr in pages.items()}


def _write_pages(pages_l: jax.Array, new: jax.Array,
                 block_tables: jax.Array, ctx_lens: jax.Array,
                 valid: jax.Array) -> jax.Array:
    """Scatter [B, T, Hkv, Hd] new KV into one layer's pool.

    Position ctx+t of row b lands in slot (ctx+t) % bs of block
    block_tables[b, (ctx+t) // bs]; invalid positions (padded tails,
    inactive rows) are redirected into scratch block 0.
    """
    n, bs = pages_l.shape[:2]
    b, t = new.shape[:2]
    m = block_tables.shape[1]
    pos = ctx_lens[:, None] + jnp.arange(t)[None, :]          # [B, T]
    blk = jnp.take_along_axis(block_tables,
                              jnp.minimum(pos // bs, m - 1), axis=1)
    flat = blk * bs + pos % bs
    flat = jnp.where(valid, flat, pos % bs)                   # scratch
    out = pages_l.reshape(n * bs, *pages_l.shape[2:])
    out = out.at[flat.reshape(-1)].set(
        new.reshape(b * t, *new.shape[2:]).astype(pages_l.dtype))
    return out.reshape(pages_l.shape)


def _gathered_attention(q: jax.Array, kp: jax.Array, vp: jax.Array,
                        block_tables: jax.Array, ctx_lens: jax.Array
                        ) -> jax.Array:
    """Pure-jnp paged attention for T >= 1 (prefill chunks, CPU decode).

    q: [B, T, H, Hd] at absolute positions ctx..ctx+T-1; kp/vp:
    [N, bs, Hkv, Hd] pool *after* the chunk's writes.  Causal over the
    gathered logical context.
    """
    n, bs, hkv, hd = kp.shape
    b, t, h, _ = q.shape
    m = block_tables.shape[1]
    group = h // hkv
    idx = (block_tables[:, :, None] * bs
           + jnp.arange(bs)[None, None, :]).reshape(b, m * bs)
    k = kp.reshape(n * bs, hkv, hd)[idx]                      # [B, S, Hkv, Hd]
    v = vp.reshape(n * bs, hkv, hd)[idx]
    kt = jnp.repeat(jnp.moveaxis(k, 1, 2), group, axis=1)     # [B, H, S, Hd]
    vt = jnp.repeat(jnp.moveaxis(v, 1, 2), group, axis=1)
    qt = jnp.moveaxis(q, 1, 2)                                # [B, H, T, Hd]
    logits = jnp.einsum("bhqd,bhkd->bhqk", qt, kt,
                        preferred_element_type=jnp.float32) / (hd ** 0.5)
    q_pos = ctx_lens[:, None] + jnp.arange(t)[None, :]        # [B, T]
    k_pos = jnp.arange(m * bs)
    mask = k_pos[None, None, None, :] <= q_pos[:, None, :, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vt.astype(jnp.float32))
    return jnp.moveaxis(out.astype(q.dtype), 1, 2)            # [B, T, H, Hd]


def _paged_decoder_block(cfg: ArchConfig, x, lp, kp, vp, block_tables,
                         ctx_lens, valid, use_kernel: bool):
    b, t, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    hn = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = shard_hint((hn @ lp["wq"]).reshape(b, t, h, hd),
                   "dp", None, "model", None)
    k = shard_hint((hn @ lp["wk"]).reshape(b, t, kvh, hd),
                   "dp", None, "model", None)
    v = shard_hint((hn @ lp["wv"]).reshape(b, t, kvh, hd),
                   "dp", None, "model", None)
    pos = ctx_lens[:, None] + jnp.arange(t)[None, :]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    kp = _write_pages(kp, k, block_tables, ctx_lens, valid)
    vp = _write_pages(vp, v, block_tables, ctx_lens, valid)
    if t == 1 and use_kernel:
        from repro.kernels.ops import paged_attention
        out = paged_attention(q[:, 0], kp, vp, block_tables,
                              ctx_lens + 1)[:, None]
    else:
        out = _gathered_attention(q, kp, vp, block_tables, ctx_lens)
    x = x + shard_hint(out.reshape(b, t, h * hd) @ lp["wo"],
                       "dp", None, None)
    f, _ = _ffn_sublayer(cfg, rms_norm(x, lp["ln2"], cfg.norm_eps), lp)
    return x + f, kp, vp


def forward_paged(params: Params, cfg: ArchConfig,
                  pages: Dict[str, jax.Array], batch: Dict[str, jax.Array],
                  block_tables: jax.Array, ctx_lens: jax.Array,
                  new_lens: Optional[jax.Array] = None, *,
                  use_kernel: bool = False
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Run T new tokens per row against the paged cache.

    ``batch``: {"tokens": [B, T], optional "soft_emb": [B, n_soft, Dm]
    (vision, first chunk only)}.  ``new_lens`` [B]: valid *token*
    positions this chunk (<= T; default all); soft positions are always
    valid when present.  Returns (logits [B, T, V] over token positions,
    updated pages).  Rows read/write positions ctx..ctx+n_soft+T-1;
    invalid tail positions write to the scratch block and their logits
    are garbage the caller must ignore.
    """
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"forward_paged: unsupported family {cfg.family!r}")
    tokens = batch["tokens"]
    b, t = tokens.shape
    if new_lens is None:
        new_lens = jnp.full((b,), t, jnp.int32)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    soft = batch.get("soft_emb")
    n_soft = 0
    if soft is not None:
        n_soft = soft.shape[1]
        x = jnp.concatenate([soft.astype(x.dtype), x], axis=1)
    x = shard_hint(x, "dp", None, None)
    t_eff = t + n_soft
    valid = (jnp.arange(t_eff)[None, :]
             < (new_lens + n_soft)[:, None])                  # [B, T_eff]

    def layer(h, xs):
        lp, kp, vp = xs
        h, kp, vp = _paged_decoder_block(cfg, h, lp, kp, vp, block_tables,
                                         ctx_lens, valid, use_kernel)
        return h, (kp, vp)

    x2, (nk, nv) = jax.lax.scan(
        layer, x, (params["layers"], pages["k"], pages["v"]))
    if n_soft:
        x2 = x2[:, n_soft:]
    return _unembed(params, cfg, x2), {"k": nk, "v": nv}


def decode_step_paged(params: Params, cfg: ArchConfig,
                      pages: Dict[str, jax.Array], batch: Dict[str, jax.Array],
                      block_tables: jax.Array, ctx_lens: jax.Array, *,
                      use_kernel: bool = False
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token paged decode: batch {"tokens": [B, 1]} -> (logits
    [B, 1, V], pages).  ``use_kernel`` routes attention through the
    Pallas block-indexed kernel (native on TPU, interpret elsewhere)."""
    return forward_paged(params, cfg, pages, batch, block_tables, ctx_lens,
                         use_kernel=use_kernel)


__all__ = ["PAGED_FAMILIES", "supports_paged", "init_pages",
           "copy_blocks", "forward_paged", "decode_step_paged"]
