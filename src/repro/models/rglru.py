"""RG-LRU recurrent block (Griffin / RecurrentGemma), TPU-adapted.

Gated linear recurrence h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t); block-diagonal gate
projections (per-head) as in the Griffin paper.  Runs through the same
chunked associative scan as the SSM block; decode state is [B, lru] plus
a conv shift register -- constant in context length, hence the
long_500k-capable hybrid family.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import shard_hint
from repro.models.scan_utils import chunked_linear_scan
from repro.models.ssm import _causal_conv

_C = 8.0


class RGLRUState(NamedTuple):
    conv: jax.Array   # [B, K-1, lru]
    h: jax.Array      # [B, lru]


def _block_diag(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [B, S, lru] -> per-head block-diagonal projection.
    w: [heads, bh, bh] with heads * bh == lru."""
    heads, bh, _ = w.shape
    b, s, lru = x.shape
    xh = x.reshape(b, s, heads, bh)
    return jnp.einsum("bshi,hij->bshj", xh, w).reshape(b, s, lru)


def rglru_block(x: jax.Array, p: dict, *, chunk: int = 128,
                state: RGLRUState | None = None,
                single_step: bool = False) -> Tuple[jax.Array, RGLRUState]:
    """x: [B, S, D].  Params:
      w_x [D, lru], w_y [D, lru], conv_w [K, lru],
      w_a [heads, bh, bh], w_i [heads, bh, bh], lam [lru], out [lru, D].
    """
    b, s, d = x.shape
    lru = p["lam"].shape[0]

    xb = shard_hint(x @ p["w_x"], "dp", None, "model")   # [B, S, lru]
    yb = shard_hint(x @ p["w_y"], "dp", None, "model")
    conv_prefix = state.conv if state is not None else None
    xb, new_conv = _causal_conv(xb, p["conv_w"], conv_prefix)

    r = jax.nn.sigmoid(_block_diag(xb, p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(xb, p["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)                          # [B, S, lru]
    gated = i * xb.astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    bb = beta * gated

    h0 = state.h if state is not None else jnp.zeros((b, lru), jnp.float32)
    if single_step:
        assert s == 1
        h_new = a[:, 0] * h0 + bb[:, 0]
        h_all = h_new[:, None]
    else:
        h_all, h_new = chunked_linear_scan(a, bb, h0, chunk=chunk)

    out = (h_all * jax.nn.gelu(yb.astype(jnp.float32))).astype(x.dtype)
    return shard_hint(out @ p["out"], "dp", None, None), RGLRUState(
        conv=new_conv, h=h_new)


def init_rglru_params(key, d_model: int, lru: int, heads: int, d_conv: int,
                      dtype) -> dict:
    ks = jax.random.split(key, 6)
    bh = lru // heads
    scale = d_model ** -0.5
    return {
        "w_x": (jax.random.normal(ks[0], (d_model, lru)) * scale).astype(dtype),
        "w_y": (jax.random.normal(ks[1], (d_model, lru)) * scale).astype(dtype),
        "conv_w": (jax.random.normal(ks[2], (d_conv, lru)) * 0.1).astype(dtype),
        "w_a": (jax.random.normal(ks[3], (heads, bh, bh)) * bh ** -0.5
                ).astype(dtype),
        "w_i": (jax.random.normal(ks[4], (heads, bh, bh)) * bh ** -0.5
                ).astype(dtype),
        "lam": jnp.linspace(0.5, 3.0, lru, dtype=jnp.float32),
        "out": (jax.random.normal(ks[5], (lru, d_model)) * lru ** -0.5
                ).astype(dtype),
    }


__all__ = ["rglru_block", "init_rglru_params", "RGLRUState"]
