"""Modality frontend stubs.

Per the assignment, ``[audio]``/``[vlm]`` entries specify the transformer
BACKBONE only; the modality frontend is a stub whose outputs --
precomputed frame/patch embeddings -- are produced here (for tests and
examples) and described by ``input_specs`` (for the dry-run).
"""

from __future__ import annotations

import jax

from repro.configs.base import ArchConfig


def audio_frames(key, cfg: ArchConfig, batch: int, seq_len: int):
    """Stub for Whisper's conv1d-over-mel frontend: [B, S, D] frame
    embeddings."""
    return (jax.random.normal(key, (batch, seq_len, cfg.d_model)) * 0.02
            ).astype(cfg.activation_dtype)


def vision_patches(key, cfg: ArchConfig, batch: int):
    """Stub for LLaVA-NeXT anyres tiling + projector: [B, T_img, D]
    soft-token embeddings."""
    return (jax.random.normal(key, (batch, cfg.frontend_tokens, cfg.d_model))
            * 0.02).astype(cfg.activation_dtype)


__all__ = ["audio_frames", "vision_patches"]
