"""Simulator validation: flow vs closed forms, fabric vs flow, and the
paper's own observations (star overhead, chain pipelining)."""

import dataclasses

import numpy as np

from repro.core import patterns as pat
from repro.core.autogen import autogen_tree, compute_tables
from repro.core.model import WSE2
from repro.core.schedule import (binary_tree, chain_tree, star_tree,
                                 two_phase_tree)
from repro.simulator.fabric import (simulate_broadcast_fabric,
                                    simulate_reduce_fabric)
from repro.simulator.flow import (simulate_broadcast, simulate_reduce_tree,
                                  simulate_ring_allreduce)
from repro.simulator.runner import compare_reduce, compare_reduce_2d


def test_flow_chain_matches_lemma():
    for p in (2, 4, 16, 64, 512):
        for b in (1, 64, 4096):
            sim = simulate_reduce_tree(chain_tree(p), b).cycles
            model = pat.t_chain(p, b)
            assert abs(sim - model) <= 2 + 0.02 * model, (p, b, sim, model)


def test_flow_star_matches_refined_lemma():
    for p in (2, 8, 32):
        for b in (1, 64, 1024):
            sim = simulate_reduce_tree(star_tree(p), b).cycles
            model = pat.t_star(p, b)  # refined pipeline form
            assert abs(sim - model) <= 3 + 0.05 * model, (p, b, sim, model)


def test_flow_broadcast_matches_lemma_4_1():
    for p in (2, 16, 512):
        for b in (1, 256, 65536):
            sim = simulate_broadcast(p, b).cycles
            assert abs(sim - pat.t_broadcast(p, b)) <= 2


def test_fabric_agrees_with_flow_on_pipelined_patterns():
    for p in (2, 4, 8, 16):
        for b in (8, 64, 256):
            for mk in (chain_tree, binary_tree, two_phase_tree):
                tree = mk(p)
                fab = simulate_reduce_fabric(tree, b).cycles
                flo = simulate_reduce_tree(tree, b).cycles
                assert abs(fab - flo) <= 4 + 0.15 * fab, (p, b, tree.label)


def test_fabric_reproduces_paper_star_overhead():
    """Sec 8.5: star performs worse than predicted because of per-stream
    receive overhead -- the wavelet-level sim shows it organically."""
    worse = 0
    for p in (8, 16, 32):
        fab = simulate_reduce_fabric(star_tree(p), 8).cycles
        flo = simulate_reduce_tree(star_tree(p), 8).cycles
        if fab > flo * 1.1:
            worse += 1
    assert worse >= 2


def test_fabric_computes_exact_sums():
    rng = np.random.default_rng(7)
    for p in (4, 8):
        data = rng.standard_normal((p, 32))
        res = simulate_reduce_fabric(two_phase_tree(p), 32, data=data)
        np.testing.assert_allclose(res.root_sum, data.sum(0), rtol=1e-9)


def test_fabric_honors_fractional_t_r():
    """Calibrated fabrics carry non-integer ramp latencies; the wavelet
    simulator used to truncate ``t_r`` to int and silently mis-simulate
    them.  Fractional ramps must (a) land between the neighboring
    integer-``t_r`` results, (b) still compute the exact sum, and (c)
    be rounded *up* -- never down -- by the closed-form broadcast."""
    def fab(t_r):
        return dataclasses.replace(WSE2, name=f"tr{t_r}", t_r=t_r)

    for p, b in ((4, 16), (8, 32)):
        tree = chain_tree(p)
        data = np.random.default_rng(1).standard_normal((p, b))
        lo = simulate_reduce_fabric(tree, b, data=data,
                                    fabric=fab(2.0)).cycles
        mid = simulate_reduce_fabric(tree, b, data=data,
                                     fabric=fab(2.5)).cycles
        hi = simulate_reduce_fabric(tree, b, data=data,
                                    fabric=fab(3.0)).cycles
        assert lo <= mid <= hi, (p, b, lo, mid, hi)
        assert lo < hi, (p, b)
        # a fractional ramp must cost more than its floor on a chain
        # (every hop pays the ramp twice)
        assert mid > lo, (p, b, lo, mid)
    # closed-form broadcast: ceil, not truncate (2.25 ramps twice =
    # +4.5 cycles -> 16 cycles, where int-truncation said 15)
    res = simulate_broadcast_fabric(4, 8, fabric=fab(2.25))
    assert res.cycles == 16
    assert simulate_broadcast_fabric(4, 8, fabric=fab(2.0)).cycles == 15


def test_fabric_autogen_trees_run():
    tables = compute_tables(16, use_cache=False)
    for b in (1, 16, 128):
        tree = autogen_tree(16, b, tables=tables)
        res = simulate_reduce_fabric(tree, b)
        assert res.cycles > 0


def test_runner_errors_in_paper_range():
    """Paper: mean relative error 12-35% per pattern; our flow-sim errors
    sit well inside that."""
    tables = compute_tables(64, use_cache=False)
    for pattern in ("chain", "tree", "two_phase", "autogen"):
        errs = [compare_reduce(pattern, 64, b, tables=tables).rel_error
                for b in (1, 16, 256, 4096)]
        assert np.mean(errs) < 0.35, (pattern, errs)


def test_snake_2d_matches_chain():
    cmp = compare_reduce_2d("snake", 8, 8, 256)
    assert cmp.rel_error < 0.05


def test_ring_sim_monotone_in_p():
    times = [simulate_ring_allreduce(p, 4096).cycles for p in (4, 8, 16, 32)]
    assert all(t2 > t1 for t1, t2 in zip(times, times[1:]))


def test_random_trees_fabric_vs_flow_property():
    """Property: ANY valid pre-order reduction tree produces consistent
    timing between the wavelet-level and flow-level simulators (within
    queue/arbitration slack), and an exact sum.  Covers the whole
    Auto-Gen schedule space, not just the named patterns."""
    import random as pyrandom
    from tests.util_trees import random_pre_order_tree
    rng = pyrandom.Random(0)
    for trial in range(6):
        p = rng.randint(3, 14)
        b = rng.choice([4, 16, 64])
        tree = random_pre_order_tree(p, rng)
        fab = simulate_reduce_fabric(tree, b).cycles
        flo = simulate_reduce_tree(tree, b).cycles
        # fabric >= flow minus rounding; within 50% + per-vertex slack
        # (random trees can be star-like where receive-switch overhead
        # dominates, the paper's Sec 8.5 effect)
        assert fab >= flo - 3, (p, b, fab, flo)
        assert fab <= flo * 1.6 + 6 * p, (p, b, fab, flo)


def test_fabric_determinism():
    """The CS-2 property the paper's methodology relies on (Sec. 8.1):
    identical runs produce identical cycle counts."""
    tree = two_phase_tree(12)
    data = np.random.default_rng(3).standard_normal((12, 48))
    runs = [simulate_reduce_fabric(tree, 48, data=data).cycles
            for _ in range(3)]
    assert runs[0] == runs[1] == runs[2]
