"""Validate the production dry-run artifacts (written by the baseline
sweep of repro.launch.dryrun).  Skips gracefully while the sweep is
still filling in cells; the final run asserts full coverage."""

import glob
import json
import os

import pytest

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "var", "dryrun")


def _records():
    recs = []
    for p in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if not r.get("tag"):
            recs.append(r)
    return recs


def test_artifacts_well_formed():
    recs = _records()
    if not recs:
        pytest.skip("no dry-run artifacts yet (sweep not run)")
    for r in recs:
        assert r["status"] in ("ok", "skipped"), (
            r["arch"], r["shape"], r.get("error", "")[:500])
        if r["status"] == "ok":
            t = r["roofline"]
            assert t["compute_s"] > 0, (r["arch"], r["shape"])
            assert t["dominant"] in ("compute", "memory", "collective")
            assert r["cost"]["flops"] > 0
            # HLO flops can never be below the analytic model flops by
            # more than rounding (the compiled program must do the work)
            assert t["hlo_flops_global"] >= 0.5 * t["model_flops_global"], (
                r["arch"], r["shape"], t)


def test_skip_rules_applied():
    recs = _records()
    skipped = [r for r in recs if r["status"] == "skipped"]
    for r in skipped:
        assert r["shape"] == "long_500k"
        assert r["arch"] not in ("falcon-mamba-7b", "recurrentgemma-9b")


def test_multipod_cells_present_when_sweep_done():
    recs = _records()
    pods = [r for r in recs if r["mesh"] == "pod"]
    mps = [r for r in recs if r["mesh"] == "multipod"]
    if len(pods) < 40 or len(mps) < 40:
        pytest.skip(f"sweep incomplete: {len(pods)} pod / {len(mps)} "
                    "multipod cells")
    assert len(pods) >= 40 and len(mps) >= 40
    ok_mp = [r for r in mps if r["status"] == "ok"]
    assert all(r["n_devices"] == 512 for r in ok_mp)
