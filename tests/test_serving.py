"""Serving subsystem: block allocator, continuous-batching scheduler
(mid-decode retirement, out-of-blocks preemption), sampling, telemetry.

Model-level paged-cache numerics live in tests/test_paged_attention.py.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ArchConfig
from repro.models import init_params
from repro.serving import (BlockAllocator, BlockTable,
                           ContinuousBatchingServer, Request,
                           SamplingParams, sample_tokens)
from repro.serving.blocks import RESERVED_BLOCKS

TINY = ArchConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32")


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(jax.random.PRNGKey(0), TINY)


def _server(params, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_chunk", 8)
    return ContinuousBatchingServer(TINY, params, **kw)


def _req(rid, prompt_len=8, max_new=4, rng_seed=None, **kw):
    rng = np.random.default_rng(rid if rng_seed is None else rng_seed)
    return Request(rid=rid,
                   prompt=rng.integers(0, TINY.vocab_size,
                                       prompt_len).astype(np.int32),
                   max_new_tokens=max_new, **kw)


# ------------------------------ allocator ----------------------------- #
def test_allocator_alloc_free_reuse():
    a = BlockAllocator(num_blocks=8, block_size=4)
    assert a.capacity == 8 - RESERVED_BLOCKS
    got = a.alloc(3)
    assert len(got) == 3 and len(set(got)) == 3
    assert 0 not in got, "scratch block must never be handed out"
    assert (a.num_used, a.num_free) == (3, 4)
    a.free(got[:2])
    assert (a.num_used, a.num_free) == (1, 6)
    again = a.alloc(6)
    assert again is not None and 0 not in again
    assert a.num_free == 0


def test_allocator_all_or_nothing_and_double_free():
    a = BlockAllocator(num_blocks=5, block_size=4)
    assert a.alloc(5) is None, "over-ask must not partially allocate"
    assert a.num_used == 0
    got = a.alloc(4)
    assert a.alloc(1) is None
    a.free(got)
    with pytest.raises(ValueError):
        a.free(got[:1])
    with pytest.raises(ValueError):
        a.free([0])        # the reserved scratch block was never allocated


def test_allocator_fragmentation_accounting():
    a = BlockAllocator(num_blocks=16, block_size=8)
    assert a.blocks_for(1) == 1 and a.blocks_for(8) == 1
    assert a.blocks_for(9) == 2
    # 3 requests at 5, 8, 17 tokens -> waste 3 + 0 + 7 slots
    assert a.internal_fragmentation([5, 8, 17]) == 10


def test_block_table_grow_release():
    a = BlockAllocator(num_blocks=6, block_size=4)
    t = BlockTable(a)
    assert t.ensure_capacity(9)       # 3 blocks
    assert t.num_slots == 12 and a.num_used == 3
    assert t.ensure_capacity(12)      # no growth needed
    assert a.num_used == 3
    t2 = BlockTable(a)
    assert t2.ensure_capacity(9) is False, "pool exhausted is all-or-nothing"
    assert a.num_used == 3
    t.release()
    assert a.num_used == 0 and t.blocks == []


# ------------------------------ sampling ------------------------------ #
def test_sampling_greedy_matches_argmax():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 32))
    toks = sample_tokens(logits, jnp.arange(4), jnp.zeros(4), key)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.argmax(np.asarray(logits), -1))


def test_sampling_top_k_support_and_determinism():
    key = jax.random.PRNGKey(1)
    logits = jax.random.normal(key, (6, 64))
    temps = jnp.full((6,), 0.7)
    a = sample_tokens(logits, jnp.arange(6), temps, key, top_ks=4)
    b = sample_tokens(logits, jnp.arange(6), temps, key, top_ks=4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    top4 = np.argsort(np.asarray(logits), -1)[:, -4:]
    for i, t in enumerate(np.asarray(a)):
        assert t in top4[i], "sampled token outside the top-k set"
    # different per-row ids give (generically) different draws
    c = sample_tokens(logits, jnp.arange(6) + 100, temps, key, top_ks=4)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_sampling_per_row_top_k():
    """top_k is honored per row: k=1 forces the argmax even at high
    temperature, k=0 leaves the full vocabulary open."""
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (8, 64)) * 4.0
    temps = jnp.full((8,), 5.0)
    ks = jnp.asarray([1, 0, 1, 0, 1, 0, 1, 0], jnp.int32)
    toks = np.asarray(sample_tokens(logits, jnp.arange(8), temps, key, ks))
    argmax = np.argmax(np.asarray(logits), -1)
    np.testing.assert_array_equal(toks[::2], argmax[::2])


def test_sampling_mixed_greedy_and_stochastic_rows():
    key = jax.random.PRNGKey(2)
    logits = jax.random.normal(key, (4, 32))
    temps = jnp.asarray([0.0, 1.0, 0.0, 1.0])
    toks = np.asarray(sample_tokens(logits, jnp.arange(4), temps, key))
    argmax = np.argmax(np.asarray(logits), -1)
    assert toks[0] == argmax[0] and toks[2] == argmax[2]


# ------------------------- continuous batching ------------------------ #
def test_mid_decode_retirement_and_slot_reuse(tiny_params):
    """A short request retires early and a *queued* request is admitted
    into its slot before the long request finishes -- in one run()."""
    srv = _server(tiny_params, batch_size=2, max_len=96, block_size=4,
                  num_blocks=64)
    short = _req(0, max_new=4)
    long = _req(1, max_new=64)
    queued = _req(2, max_new=4)
    for r in (short, long, queued):
        srv.submit(r)
    results = srv.run()
    assert len(results[0]) == 4
    assert len(results[1]) == 64
    assert len(results[2]) == 4
    # the queued request started while the long one was still decoding
    assert queued.admit_step is not None and long.finish_step is not None
    assert queued.admit_step < long.finish_step
    assert queued.finish_step < long.finish_step
    assert srv.snapshot().preemptions == 0


def test_retirement_frees_blocks_for_admission(tiny_params):
    """Pool sized so the queued request can only be admitted after the
    short one releases its blocks (retire -> admit in the same step)."""
    srv = _server(tiny_params, batch_size=2, max_len=16, block_size=4,
                  num_blocks=9)        # 8 allocatable
    a, b, c = _req(0, max_new=4), _req(1, max_new=8), _req(2, max_new=4)
    for r in (a, b, c):
        srv.submit(r)
    results = srv.run()
    assert {len(results[i]) for i in (0, 2)} == {4} and len(results[1]) == 8
    assert c.admit_step >= a.finish_step


def test_out_of_blocks_preemption_recovers(tiny_params):
    """Decode growth exhausts the pool: the latest-admitted request is
    preempted, re-queued, and still completes with identical tokens."""
    def serve(num_blocks):
        srv = _server(tiny_params, batch_size=2, max_len=16, block_size=4,
                      num_blocks=num_blocks, prefill_chunk=8)
        for rid in range(3):
            srv.submit(_req(rid, prompt_len=8, max_new=8))
        return srv.run(), srv.snapshot()

    tight, snap_tight = serve(6)
    roomy, snap_roomy = serve(13)
    assert snap_tight.preemptions >= 1
    assert snap_roomy.preemptions == 0
    assert all(len(tight[r]) == 8 for r in range(3))
    # recompute-style preemption must not change the sampled streams
    assert tight == roomy


def test_preemption_never_replays_finished_requests(tiny_params):
    """A request that finishes at prefill (max_new=1) sits done-but-
    unretired for one step; pool-exhausted growth must not pick it as a
    preemption victim (a replay would over-generate)."""
    srv = _server(tiny_params, batch_size=2, max_len=16, block_size=4,
                  num_blocks=4, prefill_chunk=8)
    srv.submit(_req(0, prompt_len=8, max_new=4))
    srv.submit(_req(1, prompt_len=4, max_new=1))
    results = srv.run()
    assert len(results[0]) == 4
    assert len(results[1]) == 1, "finished request was replayed"
    snap = srv.snapshot()
    assert snap.finished == snap.submitted == 2


def test_large_request_ids_do_not_overflow(tiny_params):
    """Sample ids wrap modulo 2^31; rid 2048+ must serve fine."""
    srv = _server(tiny_params, batch_size=2, max_len=32, num_blocks=17)
    for rid in (2047, 5000, 123456):
        srv.submit(_req(rid, max_new=4, rng_seed=rid % 7,
                        sampling=SamplingParams(temperature=0.5)))
    results = srv.run()
    assert all(len(results[r]) == 4 for r in (2047, 5000, 123456))


def test_chunked_prefill_interleaves_with_decode(tiny_params):
    """A long prompt streams in chunks while a running request keeps
    decoding (no decode stall)."""
    srv = _server(tiny_params, batch_size=2, max_len=96, block_size=8,
                  num_blocks=32, prefill_chunk=8, prefill_per_step=1)
    srv.submit(_req(0, prompt_len=8, max_new=24))
    srv.submit(_req(1, prompt_len=48, max_new=4))   # 6 chunks
    results = srv.run()
    assert len(results[0]) == 24 and len(results[1]) == 4
    snap = srv.snapshot()
    # 1 + 6 prompt chunks, and every iteration that streamed a chunk of
    # the long prompt also ran a decode step (no decode stall)
    assert snap.prefill_chunks >= 7
    assert snap.decode_steps == snap.steps


def test_request_never_fits_raises(tiny_params):
    srv = _server(tiny_params, batch_size=2, max_len=16, block_size=4)
    with pytest.raises(ValueError):
        srv.submit(_req(0, prompt_len=30, max_new=8))   # > max_len


def test_degenerate_requests_rejected(tiny_params):
    srv = _server(tiny_params)
    with pytest.raises(ValueError):
        srv.submit(Request(rid=0, prompt=np.empty(0, np.int32),
                           max_new_tokens=4))
    with pytest.raises(ValueError):
        srv.submit(_req(1, max_new=0))


def test_partial_results_on_step_budget(tiny_params):
    srv = _server(tiny_params, batch_size=2, max_len=32, num_blocks=17)
    srv.submit(_req(0, max_new=16))
    results = srv.run(max_steps=3)
    assert 1 <= len(results[0]) < 16


def test_fcfs_admission_preserves_submit_order():
    """FCFS: free slots fill from the queue head in submission order."""
    from repro.serving.scheduler import PREFILLING, QUEUED, Scheduler
    a = BlockAllocator(num_blocks=32, block_size=4)
    sched = Scheduler(batch_size=2, allocator=a, max_blocks_per_seq=4,
                      prefill_chunk=8)
    reqs = [_req(rid) for rid in range(3)]
    for r in reqs:
        sched.submit(r, now=float(r.rid))
    admitted = sched.admit(step=0)
    assert [r.rid for r in admitted] == [0, 1]
    assert admitted[0]._admit_seq < admitted[1]._admit_seq
    assert reqs[0].state == PREFILLING and reqs[2].state == QUEUED
    assert [r.rid for r in sched.queue] == [2]


def test_fcfs_head_of_line_blocks_smaller_requests():
    """Admission stops at the first request that does not fit: a small
    request behind a big head must not leapfrog it (the head would
    starve), and the head goes first once blocks free up."""
    from repro.serving.scheduler import Scheduler
    a = BlockAllocator(num_blocks=8, block_size=4)
    hog = a.alloc(6)                    # leave 1 free block
    sched = Scheduler(batch_size=2, allocator=a, max_blocks_per_seq=4,
                      prefill_chunk=8)
    big = _req(0, prompt_len=8, max_new=4)      # needs 2 blocks
    small = _req(1, prompt_len=4, max_new=4)    # would fit in the 1 free
    sched.submit(big, now=0.0)
    sched.submit(small, now=0.0)
    assert sched.admit(step=0) == []
    assert [r.rid for r in sched.queue] == [0, 1], \
        "small request leapfrogged the head of the queue"
    a.free(hog)
    admitted = sched.admit(step=1)
    assert [r.rid for r in admitted] == [0, 1], "head must admit first"


def test_queue_wait_telemetry_and_depth_history(tiny_params):
    """Scheduling delay (submit -> admit) and per-step queue depth are
    recorded: batch of 1 makes the waits strictly staircase and the
    depth history deterministic."""
    srv = _server(tiny_params, batch_size=1, max_len=32, num_blocks=17)
    for rid in range(3):
        srv.submit(_req(rid, max_new=2))
    srv.run()
    snap = srv.snapshot()
    assert snap.queue_wait_samples == 3
    assert snap.queue_wait_p50_ms is not None
    assert snap.queue_wait_p50_ms >= 0.0
    # queue depth: starts at 2 waiting (one admitted), drains to 0
    assert snap.queue_depth_history[0] == 2
    assert snap.queue_depth_max == 2
    assert snap.queue_depth_history[-1] == 0
    hist = list(snap.queue_depth_history)
    assert hist == sorted(hist, reverse=True), "depth must only drain"

    from repro.obs.registry import MetricsRegistry
    from repro.serving.telemetry import export_to_registry
    reg = MetricsRegistry()
    export_to_registry(snap, reg, prefix="serve")
    gauges = reg.snapshot()["gauges"]
    assert gauges["serve_queue_wait_p50_ms"] == snap.queue_wait_p50_ms
    assert gauges["serve_queue_wait_samples"] == 3
    assert gauges["serve_queue_depth_max"] == 2


def test_telemetry_snapshot_sane(tiny_params):
    srv = _server(tiny_params, batch_size=2, max_len=32, num_blocks=17)
    for rid in range(3):
        srv.submit(_req(rid, max_new=4))
    srv.run()
    snap = srv.snapshot()
    assert snap.submitted == 3 and snap.finished == 3
    assert snap.tokens_out == 12
    assert snap.queue_depth == 0 and snap.active == 0
    assert snap.kv_blocks_used == 0 and snap.kv_occupancy == 0.0
    assert snap.kv_peak_occupancy > 0.0
    assert snap.ttft_p50_ms is not None and snap.ttft_p99_ms is not None
    assert snap.ttft_p50_ms <= snap.ttft_p99_ms
    assert snap.tok_per_s > 0


def test_sampled_serving_stays_in_vocab(tiny_params):
    srv = _server(tiny_params, batch_size=2, max_len=32, num_blocks=17,
                  top_k=8)
    for rid in range(3):
        srv.submit(_req(rid, max_new=6,
                        sampling=SamplingParams(temperature=0.9, top_k=8)))
    results = srv.run()
    for toks in results.values():
        assert len(toks) == 6
        assert all(0 <= t < TINY.vocab_size for t in toks)


def test_moe_family_serves(tiny_params):
    del tiny_params
    from repro.configs import get_config
    cfg = get_config("olmoe-1b-7b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    srv = ContinuousBatchingServer(cfg, params, batch_size=2, max_len=32,
                                   block_size=8, prefill_chunk=8)
    rng = np.random.default_rng(0)
    for rid in range(2):
        srv.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               8).astype(np.int32),
                           max_new_tokens=4))
    results = srv.run()
    assert all(len(v) == 4 for v in results.values())


def test_unsupported_family_raises():
    from repro.configs import get_config
    cfg = get_config("falcon-mamba-7b").reduced()
    with pytest.raises(NotImplementedError):
        ContinuousBatchingServer(cfg, None, batch_size=2, max_len=32)
