"""Latency-regime planning: one-shot candidates, the crossover, launch
calibration, cache round-trips, and the fused matmul+RS pricing.

Pure model/planner tests -- no devices.  The execution side (oneshot
dispatch correctness, the fused Pallas kernel vs its oracle) lives in
``test_fused_multidev.py``.
"""

import pytest

from repro.collectives.engine import MODEL_VERSION, CollectiveEngine
from repro.core import patterns as pat
from repro.core.model import WSE2, parse_fabric_topology

SMALL = (256, 1024, 4096)
LARGE = (1 << 20, 4 << 20)
DECODE_OPS = ("allreduce", "allgather", "all_to_all")


def _engine(spec=None):
    if spec:
        return CollectiveEngine(fabric=parse_fabric_topology(spec),
                                persist=False)
    return CollectiveEngine(persist=False)


# --------------------------- the crossover ---------------------------- #
@pytest.mark.parametrize("spec", [None, "pod=slow"])
@pytest.mark.parametrize("op", DECODE_OPS)
def test_latency_wins_below_crossover(spec, op):
    """Decode-sized payloads select the one-phase latency plan on both
    the uniform and the heterogeneous ``pod=slow`` debug topologies."""
    eng = _engine(spec)
    for nbytes in SMALL:
        plan = eng.plan_multi(op, ("pod", "data"), (2, 4), nbytes)
        assert plan.shape == "latency", (spec, op, nbytes, plan.shape)
        assert plan.predicted == min(plan.predictions.values())
        # one phase, no chunking: the whole point of the regime
        assert len(plan.steps) == 1
        assert plan.steps[0].algorithm == "oneshot"
        assert plan.n_chunks == 1


@pytest.mark.parametrize("spec", [None, "pod=slow"])
@pytest.mark.parametrize("op", DECODE_OPS)
def test_bandwidth_wins_above_crossover(spec, op):
    """Training-sized payloads leave the latency plan: the multi-phase
    bandwidth shapes win once wire time dominates launches."""
    eng = _engine(spec)
    for nbytes in LARGE:
        plan = eng.plan_multi(op, ("pod", "data"), (2, 4), nbytes)
        assert plan.shape != "latency", (spec, op, nbytes, plan.shape)
        assert (plan.predictions["latency"]
                > min(plan.predictions.values())), (spec, op, nbytes)


def test_crossover_is_monotone():
    """latency minus best-bandwidth is increasing in payload size, so
    the regime decision is a single crossover, not a fringe."""
    eng = _engine()
    last = None
    for nbytes in (256, 1024, 4096, 16384, 65536, 262144, 1 << 20):
        plan = eng.plan_multi("allgather", ("pod", "data"), (2, 4),
                              nbytes)
        others = min(v for k, v in plan.predictions.items()
                     if k != "latency")
        gap = plan.predictions["latency"] - others
        if last is not None:
            assert gap >= last - 1e-6, nbytes
        last = gap


def test_oneshot_respects_lower_bounds():
    """The one-shot closed forms keep distance >= the 2D injection
    bound for every folding, so no latency candidate undercuts the
    planner's Lemma 7.2 floor (the planner raises if one does)."""
    for spec in (None, "pod=slow"):
        eng = _engine(spec)
        for op in DECODE_OPS:
            for sizes in ((2, 4), (4, 4), (2, 2, 2)):
                axes = tuple(f"a{i}" for i in range(len(sizes)))
                for nbytes in (256, 4096, 1 << 20):
                    plan = eng.plan_multi(op, axes, sizes, nbytes)
                    assert (plan.predictions["latency"]
                            >= plan.lower_bound - 1e-6), (
                        spec, op, sizes, nbytes)


def test_oneshot_is_1d_candidate():
    """At small B the 1D selector's argmin is the depth-1 one-shot for
    allreduce and allgather (a2a keeps its paper frontier and reaches
    the one-shot only through the plan-level latency shape)."""
    eng = _engine()
    for op in ("allreduce", "allgather"):
        d = eng.select(op, 256, 8)
        assert d.algorithm == "oneshot", (op, d.predictions)
        assert "oneshot" in d.predictions
    d = eng.select("all_to_all", 256, 8)
    assert "oneshot" not in d.predictions


def test_single_axis_a2a_has_no_latency_shape():
    """One effective axis folds to nothing: the latency shape needs a
    multi-axis topology to beat, so (1, 8) keeps the sequential
    degenerate plan."""
    eng = _engine()
    plan = eng.plan_multi("all_to_all", ("pod", "data"), (1, 8), 1 << 10)
    assert "latency" not in plan.predictions
    assert plan.shape == "sequential"


# ------------------------- launch calibration ------------------------- #
def _synthetic_samples(eng, t_true, s_per_cycle=2e-9):
    fab = eng.topology.for_axis(None)
    samples = []
    for nbytes in (256, 4096, 65536, 1 << 20):
        for op, algos in (("allreduce", ("ring", "oneshot")),
                          ("allgather", ("ring", "doubling", "oneshot"))):
            for algo in algos:
                base = eng.select(op, nbytes, 8,
                                  fabric=fab).predictions[algo]
                launches = pat.launch_count(op, algo, 8)
                samples.append((op, 8, nbytes, algo,
                                s_per_cycle * (base + t_true * launches)))
    return samples


def test_calibrate_launch_recovers_injected_overhead(tmp_path):
    eng = CollectiveEngine(cache_path=str(tmp_path / "d.json"))
    t_true = 300.0
    fitted = eng.calibrate_launch(_synthetic_samples(eng, t_true))
    assert fitted == pytest.approx(t_true, rel=1e-6)
    assert eng.topology.for_axis(None).t_launch == pytest.approx(t_true,
                                                                 rel=1e-6)
    # post-calibration predictions carry the per-launch charge exactly
    d = eng.select("allreduce", 1 << 20, 8)
    ring_launches = pat.launch_count("allreduce", "ring", 8)
    uncal = CollectiveEngine(persist=False).select("allreduce", 1 << 20, 8)
    assert d.predictions["ring"] == pytest.approx(
        uncal.predictions["ring"] + t_true * ring_launches)


def test_calibrate_launch_flips_small_payloads_to_latency(tmp_path):
    """On a fabric with real launch overhead the one-shot's advantage
    widens: the multi-phase shapes pay per-round, the latency plan
    pays once."""
    eng = CollectiveEngine(cache_path=str(tmp_path / "d.json"))
    # vs the genuinely multi-phase hierarchical shape ("flat" folds to
    # the same one-shot at decode sizes and ties at gap 0)
    before = eng.plan_multi("allreduce", ("pod", "data"), (2, 4), 4096)
    gap_before = (before.predictions["hierarchical"]
                  - before.predictions["latency"])
    eng.calibrate_launch(_synthetic_samples(eng, 300.0))
    after = eng.plan_multi("allreduce", ("pod", "data"), (2, 4), 4096)
    gap_after = (after.predictions["hierarchical"]
                 - after.predictions["latency"])
    assert after.shape == "latency"
    assert gap_after > gap_before


def test_calibrate_launch_rejects_degenerate_samples(tmp_path):
    eng = CollectiveEngine(cache_path=str(tmp_path / "d.json"))
    with pytest.raises(ValueError):
        # all samples share one launch count: the overhead column is
        # unidentifiable
        eng.calibrate_launch([("allreduce", 8, 1 << 20, "ring", 1e-3),
                              ("allreduce", 8, 1 << 10, "ring", 1e-5)])


# --------------------------- cache round-trip ------------------------- #
def test_latency_decisions_roundtrip_cache(tmp_path):
    path = str(tmp_path / "decisions.json")
    eng = CollectiveEngine(cache_path=path)
    d = eng.select("allgather", 256, 8)
    plan = eng.plan_multi("allgather", ("pod", "data"), (2, 4), 256)
    assert d.algorithm == "oneshot" and plan.shape == "latency"
    eng.flush()

    eng2 = CollectiveEngine(cache_path=path)
    d2 = eng2.select("allgather", 256, 8)
    assert eng2.stats["persisted_loads"] >= 1
    assert d2.algorithm == "oneshot"
    assert d2.predictions == pytest.approx(d.predictions)
    plan2 = eng2.plan_multi("allgather", ("pod", "data"), (2, 4), 256)
    assert plan2.shape == "latency"
    assert plan2.predictions == pytest.approx(plan.predictions)


def test_calibrated_t_launch_splits_cache_namespace(tmp_path):
    """A calibrated fabric's decisions are keyed with its ``_tl`` tag,
    so they never collide with the uncalibrated entries -- and the
    uncalibrated tag is unchanged from pre-latency schemas."""
    eng = CollectiveEngine(cache_path=str(tmp_path / "d.json"))
    tag0 = eng._fabric_one_tag(eng.topology.for_axis(None))
    assert "_tl" not in tag0
    eng.calibrate_launch(_synthetic_samples(eng, 250.0))
    tag1 = eng._fabric_one_tag(eng.topology.for_axis(None))
    assert "_tl250" in tag1
    assert MODEL_VERSION == 3


# ------------------------ fused matmul+RS pricing --------------------- #
def test_fused_pricing_wins_at_fsdp_shard_sizes():
    """For >= 1 MiB FFN-shaped shards the modeled overlapped cost is
    strictly below GEMM-then-RS: the per-block GEMM outlasts a ring
    hop, so the wire time hides behind the MXU."""
    eng = _engine()
    # [512, 4096] @ [4096, 512] over p=8: 1 MiB fp32 output
    price = eng.price_fused_matmul_rs(512, 4096, 512, 8)
    assert price["fused"] < price["serial"]
    assert price["saved"] > 0.0
    # the fused form never beats the pure wire floor of the RS
    assert price["fused"] > price["t_rs"] / 8


def test_fused_pricing_declines_tiny_shapes():
    """MQA-decode-sized projections are launch-bound: the ring's extra
    hops cost more than the overlap saves, and auto keeps the gathered
    path."""
    eng = _engine()
    price = eng.price_fused_matmul_rs(32, 16, 12, 8)
    assert price["saved"] < 0.0


def test_fused_closed_form_structure():
    """t_fused_matmul_rs = fill + (P-1) steps at the slower resource +
    drain; equal-resource crossover at t_mm/P == t_hop."""
    fab = WSE2
    p, b = 8, 1 << 18
    hop = (b / p) / fab.link_bw + fab.per_depth_cost + fab.t_launch
    # wire-bound: tiny GEMM, the ring dominates
    t = pat.t_fused_matmul_rs(p, b, 1.0, fab)
    assert t == pytest.approx(1.0 / p + (p - 1) * hop + hop)
    # MXU-bound: huge GEMM, the hops hide entirely
    t_mm = hop * p * 100
    t = pat.t_fused_matmul_rs(p, b, t_mm, fab)
    assert t == pytest.approx(t_mm / p * p + hop)
