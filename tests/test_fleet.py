"""Multi-replica serving fleet: router policies, admission control,
load signals, and the fleet determinism contract.

Single-server scheduler/cache behavior lives in tests/test_serving.py;
this file covers the layer above -- N replicas in lockstep waves behind
a telemetry-driven router.
"""

import math

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import init_params
from repro.serving import ContinuousBatchingServer, Request
from repro.serving.fleet import (AdmissionConfig, AdmissionController,
                                 FleetServer, LoadSignal,
                                 REJECT_QUEUE_FULL, REJECT_RATE_LIMITED,
                                 ROUTER_POLICIES, Replica, arrival_waves,
                                 export_fleet_stats, make_router)

TINY = ArchConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32")


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(jax.random.PRNGKey(0), TINY)


def _fleet(params, n_replicas=2, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_chunk", 8)
    return FleetServer(TINY, params, n_replicas, **kw)


def _req(rid, prompt_len=8, max_new=4, rng_seed=None, **kw):
    rng = np.random.default_rng(rid if rng_seed is None else rng_seed)
    return Request(rid=rid,
                   prompt=rng.integers(0, TINY.vocab_size,
                                       prompt_len).astype(np.int32),
                   max_new_tokens=max_new, **kw)


def _signal(replica=0, queue_depth=0, queued=0, inflight=0, active=0):
    return LoadSignal(replica=replica, queue_depth=queue_depth,
                      active=active, running=active,
                      queued_prefill_tokens=queued,
                      inflight_prefill_tokens=inflight,
                      kv_blocks_live=0, kv_blocks_evictable=0,
                      kv_blocks_free=8, ttft_ewma_s=None,
                      queue_wait_p50_ms=None)


# ------------------------------ routers ------------------------------- #
def test_round_robin_cycles():
    r = make_router("round_robin")
    sigs = [_signal(i) for i in range(3)]
    got = [r.route(_req(i), [None] * 3, sigs) for i in range(7)]
    assert got == [0, 1, 2, 0, 1, 2, 0]


def test_least_queue_picks_least_committed_prefill():
    r = make_router("least_queue")
    sigs = [_signal(0, queued=40), _signal(1, queued=8, inflight=8),
            _signal(2, queued=24)]
    assert r.route(_req(0), [None] * 3, sigs) == 1
    # tie on pending prefill -> backlog, then lowest index
    sigs = [_signal(0, queued=8), _signal(1, queued=8)]
    assert r.route(_req(1), [None] * 2, sigs) == 0


def test_make_router_rejects_unknown_and_cost_needs_cfg():
    with pytest.raises(ValueError):
        make_router("wishful_thinking")
    with pytest.raises(ValueError):
        make_router("cost")
    assert make_router("cost", TINY).price_per_token_s > 0


def test_cost_router_prices_uncached_suffix(tiny_params):
    """The cost router must prefer the replica whose prefix cache
    already holds the prompt's blocks (smaller uncached suffix)."""
    fleet = _fleet(tiny_params, n_replicas=2, router="cost",
                   num_blocks=32, prefix_cache=True)
    warm, cold = fleet.replicas
    prompt = _req(0, prompt_len=12).prompt
    warm.submit(Request(rid=100, prompt=prompt.copy(), max_new_tokens=2))
    while warm.has_work():
        warm.step()
    assert warm.predicted_cached_tokens(prompt) > 0
    assert cold.predicted_cached_tokens(prompt) == 0

    router = fleet.router
    req = Request(rid=101, prompt=prompt.copy(), max_new_tokens=2)
    sigs = [r.load_signal() for r in fleet.replicas]
    assert router.route(req, fleet.replicas, sigs) == 0
    assert router.last_costs[0] < router.last_costs[1]
    # modeled cost is roofline-priced seconds of prefill compute
    expected = router.price_per_token_s * (
        len(prompt) - warm.predicted_cached_tokens(prompt))
    assert math.isclose(router.last_costs[0], expected)


def test_prefix_affinity_pins_before_first_insertion(tiny_params):
    """A burst of same-prefix requests must all land on one replica
    even though the first is still queued (nothing cached yet) -- the
    pin is recorded at routing time, not at cache-insertion time."""
    fleet = _fleet(tiny_params, n_replicas=2, router="prefix_affinity",
                   num_blocks=32, prefix_cache=True)
    shared = _req(0, prompt_len=8).prompt
    for rid in range(4):
        req = Request(rid=rid, prompt=shared.copy(), max_new_tokens=2)
        assert fleet.submit(req, tenant="t0") is None
    assert fleet.routed in ([4, 0], [0, 4])


def test_prefix_affinity_separates_tenants(tiny_params):
    """Distinct prefixes spread over replicas by least committed work
    instead of stacking on one."""
    fleet = _fleet(tiny_params, n_replicas=2, router="prefix_affinity",
                   num_blocks=32, prefix_cache=True)
    for rid in range(4):
        fleet.submit(_req(rid, prompt_len=8), tenant=f"t{rid}")
    assert sorted(fleet.routed) == [2, 2]


# ----------------------------- admission ------------------------------ #
def test_admission_queue_cap_rejects_with_retry_hint():
    ctl = AdmissionController(AdmissionConfig(queue_cap=2))
    assert ctl.admit(_req(0), "a", fleet_queue_depth=1, wave=0) is None
    rej = ctl.admit(_req(1), "a", fleet_queue_depth=2, wave=3)
    assert rej is not None and rej.reason == REJECT_QUEUE_FULL
    assert rej.retry_after_waves == 1 and rej.wave == 3
    deeper = ctl.admit(_req(2), "a", fleet_queue_depth=5, wave=4)
    assert deeper.retry_after_waves == 4, "hint scales with overflow"
    assert (ctl.admitted, ctl.rejected) == (1, 2)
    assert ctl.rejected_below_cap == 0


def test_admission_token_bucket_isolates_tenants():
    # burst = 2x rate of 20 tokens/wave; each request costs 8 + 4 = 12
    ctl = AdmissionController(AdmissionConfig(tenant_rate=20.0,
                                              tenant_burst=40.0))
    assert ctl.admit(_req(0), "hog", fleet_queue_depth=0, wave=0) is None
    assert ctl.admit(_req(1), "hog", fleet_queue_depth=0, wave=0) is None
    assert ctl.admit(_req(2), "hog", fleet_queue_depth=0, wave=0) is None
    rej = ctl.admit(_req(3), "hog", fleet_queue_depth=0, wave=0)
    assert rej is not None and rej.reason == REJECT_RATE_LIMITED
    assert rej.retry_after_waves >= 1
    # a different tenant is untouched by the hog's empty bucket
    assert ctl.admit(_req(4), "quiet", fleet_queue_depth=0, wave=0) is None
    # the hog's bucket refills with the wave clock
    later = rej.wave + rej.retry_after_waves
    assert ctl.admit(_req(5), "hog", fleet_queue_depth=0,
                     wave=later) is None


def test_admission_uncapped_admits_everything():
    ctl = AdmissionController(AdmissionConfig())
    for rid in range(32):
        assert ctl.admit(_req(rid), "t", fleet_queue_depth=rid,
                         wave=rid) is None
    assert ctl.rejected == 0


# ---------------------------- load signals ---------------------------- #
def test_load_signal_tracks_queue_and_inflight(tiny_params):
    srv = ContinuousBatchingServer(TINY, tiny_params, batch_size=1,
                                   max_len=32, block_size=4,
                                   prefill_chunk=4, num_blocks=32)
    rep = Replica(0, srv)
    sig = rep.load_signal()
    assert (sig.queue_depth, sig.active, sig.pending_prefill_tokens) == \
        (0, 0, 0)
    rep.submit(_req(0, prompt_len=8, max_new=4))
    rep.submit(_req(1, prompt_len=12, max_new=4))
    sig = rep.load_signal()     # batch of 1: second request queued
    assert sig.backlog == 2
    assert sig.pending_prefill_tokens == 20
    rep.step()                  # admit + first prefill chunk of req 0
    sig = rep.load_signal()
    assert sig.active == 1 and sig.queue_depth == 1
    assert sig.inflight_prefill_tokens == 4     # 8-token prompt, chunk 4
    assert sig.queued_prefill_tokens == 12
    assert sig.kv_blocks_live > 0
    while rep.has_work():
        rep.step()
    sig = rep.load_signal()
    assert sig.ttft_ewma_s is not None and sig.ttft_ewma_s > 0
    assert sig.queue_wait_p50_ms is not None


# ------------------------------- fleet -------------------------------- #
@pytest.mark.parametrize("policy", ROUTER_POLICIES)
def test_fleet_matches_single_server_bitwise(tiny_params, policy):
    """The determinism contract: greedy token streams are bitwise
    identical between --replicas 1 and --replicas 3 under every
    routing policy."""
    def serve(n):
        fleet = _fleet(tiny_params, n_replicas=n, router=policy,
                       num_blocks=32, prefix_cache=True)
        for rid in range(6):
            assert fleet.submit(_req(rid, max_new=4),
                                tenant=f"t{rid % 2}") is None
        return fleet.run()

    single, multi = serve(1), serve(3)
    assert single == multi
    assert all(len(v) == 4 for v in multi.values())


def test_fleet_run_trace_respects_arrival_waves(tiny_params):
    fleet = _fleet(tiny_params, n_replicas=2, num_blocks=32)
    arrivals = [(0, "a", _req(0, max_new=2)),
                (4, "b", _req(1, max_new=2))]
    results, rejections = fleet.run_trace(arrivals)
    assert rejections == []
    assert sorted(results) == [0, 1]
    snap = fleet.snapshot()
    assert snap.waves >= 5, "late arrival must not be served early"
    assert snap.admitted == 2 and snap.tokens_out == 4


def test_fleet_capped_trace_sheds_only_above_cap(tiny_params):
    """A same-wave burst over a tight cap sheds the overflow -- with
    retry-after hints and zero rejects below the cap."""
    fleet = _fleet(tiny_params, n_replicas=2, num_blocks=32,
                   admission=AdmissionConfig(queue_cap=2))
    arrivals = [(0, "t", _req(rid, max_new=2)) for rid in range(8)]
    results, rejections = fleet.run_trace(arrivals)
    snap = fleet.snapshot()
    assert snap.rejected == len(rejections) > 0
    assert snap.rejected_below_cap == 0
    assert all(r.reason == REJECT_QUEUE_FULL and r.retry_after_waves >= 1
               for r in rejections)
    served = {rid for rid in range(8)} - {r.rid for r in rejections}
    assert set(results) == served
    assert all(len(results[rid]) == 2 for rid in served)


def test_fleet_affinity_beats_round_robin_on_cached_fraction(tiny_params):
    """The tentpole headline at test scale: with K tenants sharing
    prompts, affinity pays each cold prefix once fleet-wide while
    round-robin pays it once per replica."""
    shared = [_req(t, prompt_len=8).prompt for t in range(2)]
    # tenants arrive in runs, not alternating: round-robin's rid parity
    # then splits every tenant across both replicas (each pays both
    # cold prefixes) while affinity pins each prefix to one replica
    tenant_of = [0, 0, 0, 0, 1, 1, 1, 1]

    def serve(policy):
        fleet = _fleet(tiny_params, n_replicas=2, router=policy,
                       num_blocks=32, prefix_cache=True)
        for rid in range(8):
            t = tenant_of[rid]
            req = Request(rid=rid, prompt=shared[t].copy(),
                          max_new_tokens=2)
            fleet.submit(req, tenant=f"t{t}")
        results = fleet.run()
        assert all(len(v) == 2 for v in results.values())
        return fleet.snapshot()

    rr, aff = serve("round_robin"), serve("prefix_affinity")
    assert aff.cached_token_fraction > rr.cached_token_fraction
    assert aff.prefill_tokens_computed < rr.prefill_tokens_computed


def test_fleet_export_and_arrival_modes(tiny_params):
    from repro.obs.registry import MetricsRegistry, validate_export
    fleet = _fleet(tiny_params, n_replicas=2, num_blocks=32,
                   admission=AdmissionConfig(queue_cap=1))
    for rid in range(4):
        fleet.submit(_req(rid, max_new=2), tenant='quo"ted\ntenant')
    fleet.run()
    reg = MetricsRegistry()
    export_fleet_stats(fleet, reg)
    blob = reg.export_json()
    assert validate_export(blob) == []
    gauges = blob["gauges"]
    assert "fleet_waves" in gauges
    assert any(k.startswith("fleet_rejected_by_tenant{") for k in gauges)
    assert any(k.startswith("fleet_routed{replica=") for k in gauges)
    # the tenant label with quotes/newline survives text exposition
    assert "fleet_rejected_by_tenant" in reg.export_prometheus()

    # arrival generator: fixed is wave-0, modes are seeded + monotone
    assert arrival_waves(5, "fixed") == [0] * 5
    for mode in ("poisson", "bursty"):
        a = arrival_waves(50, mode, rng=np.random.default_rng(7), rate=2.0)
        b = arrival_waves(50, mode, rng=np.random.default_rng(7), rate=2.0)
        assert a == b and len(a) == 50
        assert all(x <= y for x, y in zip(a, a[1:]))
    with pytest.raises(ValueError):
        arrival_waves(5, "poisson")     # rng required
    with pytest.raises(ValueError):
        arrival_waves(5, "fractal", rng=np.random.default_rng(0))
