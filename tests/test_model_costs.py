"""Unit tests: the paper's lemmas vs. our generic cost machinery."""

import math

import pytest

from repro.core import patterns as pat
from repro.core.model import WSE2, CostTerms, Fabric
from repro.core.schedule import (binary_tree, chain_tree, snake_tree,
                                 star_tree, two_phase_tree)


PS = (2, 4, 8, 16, 64, 128)
BS = (1, 16, 256, 4096)


def test_message_formula():
    # Lemma: T_MESSAGE = B + P + 2 T_R
    for p in PS:
        for b in BS:
            assert pat.t_message(p, b) == pytest.approx(b + p + 2 * WSE2.t_r)


def test_broadcast_equals_message():
    # Lemma 4.1
    for p in PS:
        for b in BS:
            assert pat.t_broadcast(p, b) == pat.t_message(p, b)


def test_star_lemma_5_1():
    for p in PS:
        for b in BS:
            tree_cost = star_tree(p).cost_terms(b).cycles()
            formula = max(b * (p - 1), p / 2 * b + p - 1) + 2 * WSE2.t_r + 1
            assert tree_cost == pytest.approx(formula)
            # refined: perfect pipeline at the root
            assert pat.t_star(p, b) == pytest.approx(
                b * (p - 1) + 2 * WSE2.t_r + 1)


def test_chain_lemma_5_2():
    for p in PS:
        for b in BS:
            want = b + (2 * WSE2.t_r + 2) * (p - 1)
            assert pat.t_chain(p, b) == pytest.approx(want)
            assert chain_tree(p).cost_terms(b).cycles() == pytest.approx(want)


def test_tree_lemma_5_3():
    for p in PS:
        lg = int(math.log2(p))
        for b in BS:
            want = (max(b * lg, b * p / (2 * (p - 1)) * lg + p - 1)
                    + (2 * WSE2.t_r + 1) * lg)
            assert pat.t_tree(p, b) == pytest.approx(want)
            assert binary_tree(p).cost_terms(b).cycles() == pytest.approx(want)


def test_two_phase_lemma_5_4():
    # Lemma 5.4 is an upper bound (distance written as +P; ours is the
    # exact P-1).  On square P: formula == tree cost, both within 1 of
    # the lemma bound.
    for s in (2, 4, 8, 16):
        p = s * s
        for b in BS:
            bound = (max(2 * b, 2 * b - 2 * b / math.sqrt(p) + p)
                     + (2 * math.sqrt(p) - 2) * (2 * WSE2.t_r + 1))
            ours = pat.t_two_phase(p, b)
            got = two_phase_tree(p, s).cost_terms(b, links=p).cycles()
            assert got == pytest.approx(ours)
            assert ours <= bound + 1e-6
            assert ours >= bound - 1.0 - 1e-6


def test_two_phase_formula_upper_bounds_tree_when_indivisible():
    for p in (6, 10, 12, 20, 100):
        s = max(1, round(p ** 0.5))
        for b in BS:
            got = two_phase_tree(p, s).cost_terms(b, links=p).cycles()
            assert got <= pat.t_two_phase(p, b, s=s) + 1e-6


def test_ring_lemma_6_1():
    for p in PS:
        for b in BS:
            want = (2 * (p - 1) * b / p + 4 * p - 6
                    + 2 * (p - 1) * (2 * WSE2.t_r + 1))
            assert pat.t_ring_allreduce(p, b) == pytest.approx(want)


def test_broadcast_2d_lemma_7_1():
    for m, n in ((4, 4), (8, 16), (32, 32)):
        for b in BS:
            want = b + m + n - 2 + 2 * WSE2.t_r + 1
            assert pat.t_broadcast_2d(m, n, b) == pytest.approx(want)


def test_snake_is_chain_on_mn():
    for m, n in ((4, 4), (8, 16)):
        for b in BS:
            assert pat.t_snake_reduce(m, n, b) == pat.t_chain(m * n, b)
            tree = snake_tree(m, n)
            assert tree.cost_terms(b).cycles() == pytest.approx(
                pat.t_chain(m * n, b))


def test_lower_bound_2d_lemma_7_2():
    for m, n in ((4, 4), (16, 16), (512, 512)):
        for b in BS:
            want = max(b, b / 8 + m + n - 1) + 2 * WSE2.t_r + 1
            assert pat.t_lower_bound_2d(m, n, b) == pytest.approx(want)


def test_eq1_synthesis():
    terms = CostTerms(depth=3, distance=10, energy=100, contention=7,
                      links=5)
    # max(C, E/N + L) + (2 T_R + 1) D
    assert terms.cycles(WSE2) == pytest.approx(max(7, 100 / 5 + 10) + 5 * 3)
    f = Fabric(name="x", t_r=1.0, store_cost=1.0)
    assert terms.cycles(f) == pytest.approx(30 + 3 * 3)


def test_dominant_term():
    t = CostTerms(depth=1, distance=1, energy=1, contention=100, links=1)
    assert t.dominant_term() == "contention"
