"""Multi-device collective correctness, via a subprocess with 8 virtual
CPU devices (tests must not set xla_force_host_platform_device_count
globally).

Covers the 1D backends, and the topology planner's joint multi-axis
plans (hierarchical / 2D xy / 2D snake / flat / sequential) against the
jax.lax references on the (2,2,2) and (2,4) debug meshes -- including
the compress=True error-feedback path over an axis tuple, the FSDP
GradSyncConfig mode against the GSPMD baseline, and every all_to_all
backend/plan shape against ``jax.lax.all_to_all`` (single axis, (2,4)
and (2,2,2) axis tuples, fp32 + bf16)."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.multidev, pytest.mark.slow]

_SCRIPT = r"""
import functools, json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.collectives.api import allreduce_inside, select_algorithm
from repro.collectives.overlap import bucketed_allreduce, bucket_algorithm_plan

results = {}
mesh = jax.make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 96))
vals = jax.device_put(x, NamedSharding(mesh, P("data", None)))
expected = np.tile(np.asarray(x).sum(0), (8, 1))

for algo in ("psum", "chain", "tree", "two_phase", "star", "ring", "autogen", "autogen_pipelined", "auto"):
    fn = shard_map(functools.partial(allreduce_inside, axis="data", algorithm=algo),
                   mesh=mesh, in_specs=P("data", None), out_specs=P("data", None),
                   check_rep=False)
    out = np.asarray(jax.jit(fn)(vals))
    results[f"allreduce_{algo}"] = bool(np.allclose(out, expected, rtol=1e-4, atol=1e-4))

# 2-axis hierarchy (two-phase across pod x data)
mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
v2 = jax.device_put(x, NamedSharding(mesh2, P(("pod", "data"), None)))
def hier(v):
    v = allreduce_inside(v, "data", algorithm="chain")
    v = allreduce_inside(v, "pod", algorithm="chain")
    return v
fn2 = shard_map(hier, mesh=mesh2, in_specs=P(("pod", "data"), None),
                out_specs=P(("pod", "data"), None), check_rep=False)
out2 = np.asarray(jax.jit(fn2)(v2))
results["hierarchical_two_phase"] = bool(np.allclose(out2, expected, rtol=1e-4, atol=1e-4))

# bucketed allreduce with compression + error feedback
grads = {"a": jnp.ones((1000,)) * 0.5, "b": jnp.full((64, 32), 2.0)}
reduced, ef = bucketed_allreduce(grads, mesh, axes=("data",), algorithm="ring",
                                 bucket_bytes=2048, compress=True,
                                 error_feedback=jax.tree.map(jnp.zeros_like, grads))
ok_a = bool(np.allclose(np.asarray(reduced["a"]), 0.5, rtol=1e-2))
ok_b = bool(np.allclose(np.asarray(reduced["b"]), 2.0, rtol=1e-2))
results["bucketed_compressed"] = ok_a and ok_b
results["error_feedback_exists"] = ef is not None

plan = bucket_algorithm_plan(grads, mesh, bucket_bytes=2048)
results["plan_nonempty"] = len(plan) > 1

# ---------------- topology planner: joint multi-axis plans ------------ #
from repro.collectives.api import (allreduce_multi_inside,
                                   reduce_scatter_multi_inside,
                                   allgather_multi_inside)

mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
x3 = jax.random.normal(jax.random.PRNGKey(2), (16, 6))

def run3(fn, in_spec, out_spec):
    f = shard_map(fn, mesh=mesh3, in_specs=in_spec, out_specs=out_spec,
                  check_rep=False)
    return np.asarray(jax.jit(f)(x3))

for axes in (("pod", "data"), ("pod", "data", "model")):
    ref = run3(lambda v: jax.lax.psum(v, axes), P(), P())
    shapes = ("auto", "sequential", "hierarchical", "flat")
    if len(axes) == 2:
        shapes += ("2d_xy", "2d_snake")
    for shape in shapes:
        out = run3(functools.partial(allreduce_multi_inside, axes=axes,
                                     algorithm=shape), P(), P())
        results[f"ar_multi_{len(axes)}ax_{shape}"] = bool(
            np.allclose(out, ref, rtol=1e-4, atol=1e-4))

    ref = run3(lambda v: jax.lax.psum_scatter(v, axes,
                                              scatter_dimension=0,
                                              tiled=True), P(), P(axes))
    for shape in ("auto", "cascade", "flat"):
        out = run3(functools.partial(reduce_scatter_multi_inside,
                                     axes=axes, algorithm=shape),
                   P(), P(axes))
        results[f"rs_multi_{len(axes)}ax_{shape}"] = bool(
            np.allclose(out, ref, rtol=1e-4, atol=1e-4))

    ref = run3(lambda v: jax.lax.all_gather(v, axes, tiled=True),
               P(axes), P())
    for shape in ("auto", "cascade", "flat"):
        out = run3(functools.partial(allgather_multi_inside, axes=axes,
                                     algorithm=shape), P(axes), P())
        results[f"ag_multi_{len(axes)}ax_{shape}"] = bool(
            np.allclose(out, ref))

# (2, 4) debug mesh: planner plans over ("data", "model"), odd vector
# length exercising the hierarchical pad path
mesh24 = jax.make_mesh((2, 4), ("data", "model"))
y = jax.random.normal(jax.random.PRNGKey(3), (13,))
def run24(fn):
    f = shard_map(fn, mesh=mesh24, in_specs=P(), out_specs=P(),
                  check_rep=False)
    return np.asarray(jax.jit(f)(y))
ref = run24(lambda v: jax.lax.psum(v, ("data", "model")))
for shape in ("auto", "hierarchical", "2d_xy", "2d_snake", "flat"):
    out = run24(functools.partial(allreduce_multi_inside,
                                  axes=("data", "model"),
                                  algorithm=shape))
    results[f"ar_multi_24_{shape}"] = bool(
        np.allclose(out, ref, rtol=1e-4, atol=1e-4))

# multi-axis bucketed allreduce: compress=True error-feedback over the
# ("pod", "data") tuple routes each bucket through the planner
mesh22 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
reduced, ef = bucketed_allreduce(
    grads, mesh22, axes=("pod", "data"), bucket_bytes=2048,
    compress=True,
    error_feedback=jax.tree.map(jnp.zeros_like, grads))
results["bucketed_multi_compressed"] = (
    bool(np.allclose(np.asarray(reduced["a"]), 0.5, rtol=1e-2))
    and bool(np.allclose(np.asarray(reduced["b"]), 2.0, rtol=1e-2))
    and ef is not None)

mplan = bucket_algorithm_plan(grads, mesh22, axes=("pod", "data"),
                              bucket_bytes=2048)
results["multi_plan_reports_shapes"] = len(mplan) > 1 and all(
    "(" in desc for _, desc in mplan)

# ------------------- all_to_all vs the lax references ------------------ #
from repro.collectives.api import all_to_all_inside, all_to_all_multi_inside

def a2a_check(mesh_shape, mesh_axes, axes, dtype, tag):
    mesh_a = jax.make_mesh(mesh_shape, mesh_axes)
    p = 1
    for a in axes:
        p *= mesh_a.shape[a]
    xa = jax.random.normal(jax.random.PRNGKey(7),
                           (p * 3, 5)).astype(dtype)
    axis_ref = axes if len(axes) > 1 else axes[0]
    ref_fn = shard_map(
        lambda v: jax.lax.all_to_all(v, axis_ref, 0, 0, tiled=True),
        mesh=mesh_a, in_specs=P(), out_specs=P(), check_rep=False)
    with mesh_a:
        ref = np.asarray(jax.jit(ref_fn)(xa), np.float32)
    algos = (("auto", "ring", "halving") if len(axes) == 1 else
             ("auto", "hierarchical", "sequential", "flat", "ring",
              "halving"))
    for algo in algos:
        if len(axes) == 1:
            body = functools.partial(all_to_all_inside, axis=axes[0],
                                     algorithm=algo)
        else:
            body = functools.partial(all_to_all_multi_inside, axes=axes,
                                     algorithm=algo)
        fn = shard_map(body, mesh=mesh_a, in_specs=P(), out_specs=P(),
                       check_rep=False)
        with mesh_a:
            out = np.asarray(jax.jit(fn)(xa), np.float32)
        results[f"a2a_{tag}_{algo}"] = bool(
            np.allclose(out, ref, rtol=1e-4, atol=1e-4))

for dtype, dtag in ((jnp.float32, "f32"), (jnp.bfloat16, "bf16")):
    a2a_check((8,), ("data",), ("data",), dtype, f"1d_{dtag}")
    a2a_check((2, 4), ("pod", "data"), ("pod", "data"), dtype,
              f"24_{dtag}")
    a2a_check((2, 2, 2), ("pod", "data", "model"),
              ("pod", "data", "model"), dtype, f"222_{dtag}")
print("JSON" + json.dumps(results))
"""


def test_collectives_on_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][-1]
    results = json.loads(line[4:])
    for key, ok in results.items():
        assert ok, (key, results)
