"""Auto-Gen DP: correctness vs brute force, dominance, tree extraction."""


import numpy as np
import pytest

from repro.core import patterns as pat
from repro.core.autogen import autogen_tree, compute_tables, t_autogen


def brute_force_energy(p: int, d: int, c: int) -> float:
    """Exhaustive evaluation of the DP recurrence (exponential; tiny P)."""
    INF = float("inf")
    memo = {}

    def e(pp, dd, cc):
        if pp == 1:
            return 0.0
        if dd < 1 or cc < 1:
            return INF
        key = (pp, dd, cc)
        if key in memo:
            return memo[key]
        best = INF
        for i in range(1, pp):
            best = min(best, e(i, dd, cc - 1) + e(pp - i, dd - 1, cc) + i)
        memo[key] = best
        return best

    return e(p, d, c)


def test_dp_matches_brute_force():
    tables = compute_tables(10, use_cache=False)
    for p in range(1, 11):
        for d in (1, 2, 3, 5, 9):
            for c in (1, 2, 3, 5):
                if (d, c) in tables.pair_index:
                    got = tables.e(d, c, p)
                    want = brute_force_energy(p, d, c)
                    assert (np.isinf(got) and np.isinf(want)) or \
                        got == pytest.approx(want), (p, d, c, got, want)


def test_autogen_dominates_fixed_patterns_under_model():
    # Same-convention comparison: all patterns evaluated as trees with
    # the Auto-Gen DP's P-1 towards-root links (Lemma 5.4 separately
    # grants Two-Phase P bidirectional links; the DP doesn't model that).
    from repro.core.schedule import (binary_tree, chain_tree, star_tree,
                                     two_phase_tree)
    tables = compute_tables(64, use_cache=False)
    for b in (1, 4, 32, 256, 4096, 65536):
        ta, _ = t_autogen(64, b, tables=tables)
        fixed = min(
            star_tree(64).cost_terms(b).cycles(),
            chain_tree(64).cost_terms(b).cycles(),
            binary_tree(64).cost_terms(b).cycles(),
            two_phase_tree(64).cost_terms(b).cycles(),
        )
        assert ta <= fixed + 1e-6, (b, ta, fixed)


def test_autogen_tree_valid_and_consistent():
    tables = compute_tables(32, use_cache=False)
    for b in (1, 8, 128, 2048):
        tree = autogen_tree(32, b, tables=tables)
        tree.validate()
        t_pred, (d, c) = t_autogen(32, b, tables=tables)
        terms = tree.cost_terms(b, links=31)
        # the extracted tree's depth/contention respect the DP bounds
        assert terms.depth <= d + 1e-9
        assert terms.contention <= c * b + 1e-9
        # energy matches the DP energy exactly
        assert terms.energy == pytest.approx(b * tables.e(d, c, 32))


def test_autogen_reduces_to_chain_for_huge_b():
    tables = compute_tables(16, use_cache=False)
    tree = autogen_tree(16, 10 ** 6, tables=tables)
    # chain == path: every vertex has at most one child
    assert max(len(c) for c in tree.children) == 1


def test_autogen_prefers_low_depth_for_scalar():
    tables = compute_tables(64, use_cache=False)
    _, (d, c) = t_autogen(64, 1, tables=tables)
    assert d <= 8  # scalar reduce: shallow, star-ish trees win


def test_rounds_disjoint():
    tables = compute_tables(24, use_cache=False)
    for b in (1, 64, 1024):
        tree = autogen_tree(24, b, tables=tables)
        for sends in tree.to_rounds():
            srcs = [s for s, _ in sends]
            dsts = [d for _, d in sends]
            assert len(set(srcs)) == len(srcs)
            assert len(set(dsts)) == len(dsts)


def test_region_restriction_is_lossless():
    """The (D, C) search region {C<=c_small} U {D<=d_small} must not cost
    anything vs a full exploration at small P where the full DP is
    feasible -- evidence the O(P^4)->restricted-region cut is safe."""
    p = 24
    full = compute_tables(p, d_small=p, c_small=p, use_cache=False)
    restricted = compute_tables(p, use_cache=False)
    for b in (1, 2, 8, 64, 512, 8192):
        t_full, _ = t_autogen(p, b, tables=full)
        t_res, _ = t_autogen(p, b, tables=restricted)
        assert t_res <= t_full * 1.0 + 1e-6, (b, t_res, t_full)


def test_selector_matches_argmin_of_model():
    # the ICI fabric has no multicast, so the reduce+broadcast composites
    # are priced with the log-depth doubling broadcast the shard_map
    # layer actually executes (t_reduce_then_broadcast dispatches on
    # fabric.multicast)
    from repro.collectives.api import select_algorithm
    from repro.core.model import TPU_V5E_AXIS
    from repro.core import patterns as pat
    assert not TPU_V5E_AXIS.multicast
    for nbytes in (1 << 10, 1 << 16, 1 << 22, 1 << 28):
        for p in (8, 16, 64, 256):
            algo = select_algorithm(nbytes, p)
            b = max(1, nbytes // 512)
            costs = {
                "tree": pat.t_tree(p, b, TPU_V5E_AXIS)
                + pat.t_doubling_broadcast(p, b, TPU_V5E_AXIS)
                if p & (p - 1) == 0 else float("inf"),
                "two_phase": pat.t_two_phase(p, b, TPU_V5E_AXIS)
                + pat.t_doubling_broadcast(p, b, TPU_V5E_AXIS),
                "chain": pat.t_chain(p, b, TPU_V5E_AXIS)
                + pat.t_doubling_broadcast(p, b, TPU_V5E_AXIS),
                "ring": pat.t_ring_allreduce(p, b, TPU_V5E_AXIS),
                "oneshot": pat.t_oneshot_allreduce(p, b, TPU_V5E_AXIS),
            }
            assert costs[algo] == min(costs.values())


def test_pipelined_rounds_structure():
    """Pipelining a depth-D round schedule over n chunks issues
    D + n - 1 waves (the paper's pipeline overlap at tile granularity)."""
    from repro.core.schedule import chain_tree
    rounds = chain_tree(8).to_rounds()
    d = len(rounds)
    n = 4
    waves = d + n - 1
    # structural count: every (chunk, round) pair appears exactly once
    issued = sum(1 for w in range(waves) for c in range(n)
                 if 0 <= w - c < d)
    assert issued == d * n
