"""Fused matmul+reduce-scatter execution on 8 virtual devices, via a
subprocess (tests must not set xla_force_host_platform_device_count
globally).

Covers the Pallas ring kernel against the einsum oracle and the
serialized GEMM-then-RS on the 1D ``model`` axis and the folded
``(pod, data)`` FSDP layout, at FFN-sized and MQA-decode-sized shapes;
the engine executor's ``auto`` / forced-``fused`` / forced-``unfused``
agreement and its ``w=None`` grad-sync degenerate; the fused swiglu
down-projection (forward and gradients) against the GSPMD reference;
and the engine's one-shot latency dispatch (result + stats counter)."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.multidev, pytest.mark.slow]

_SCRIPT = r"""
import functools, json
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.collectives.api import get_engine
from repro.kernels.fused_matmul_rs import fused_matmul_rs, matmul_then_rs
from repro.kernels.ref import fused_matmul_rs_ref

results = {}
eng = get_engine()
key = jax.random.PRNGKey(7)

# ------------------------------------------------------------------ #
# kernel vs oracle: 1D model axis, FFN-sized and MQA-sized shapes
# ------------------------------------------------------------------ #
mesh = jax.make_mesh((8,), ("model",))
for tag, (m, k, n) in (("ffn", (64, 512, 48)), ("mqa", (16, 64, 24))):
    kx, kw, key = *jax.random.split(key, 2), key
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
    xs = jax.device_put(x, NamedSharding(mesh, P(None, "model")))
    ws = jax.device_put(w, NamedSharding(mesh, P("model", None)))
    want = fused_matmul_rs_ref(
        np.asarray(x).reshape(m, 8, k // 8).transpose(1, 0, 2),
        np.asarray(w).reshape(8, k // 8, n)).reshape(m, n)
    for name, body in (
            ("fused", lambda xl, wl: fused_matmul_rs(xl, wl, "model")),
            ("unfused", lambda xl, wl: matmul_then_rs(xl, wl, "model"))):
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(None, "model"), P("model", None)),
                       out_specs=P("model", None), check_rep=False)
        out = np.asarray(jax.jit(fn)(xs, ws))
        results[f"kernel_{tag}_{name}"] = bool(
            np.allclose(out, want, rtol=1e-5, atol=1e-5))

# ------------------------------------------------------------------ #
# folded (pod, data) FSDP layout
# ------------------------------------------------------------------ #
mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
m, k, n = 64, 512, 32
kx, kw, key = *jax.random.split(key, 2), key
x = jax.random.normal(kx, (m, k), jnp.float32)
w = jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
xs = jax.device_put(x, NamedSharding(mesh2, P(None, ("pod", "data"))))
ws = jax.device_put(w, NamedSharding(mesh2, P(("pod", "data"), None)))
want = np.asarray(x, np.float32) @ np.asarray(w, np.float32)
fn = shard_map(lambda xl, wl: fused_matmul_rs(xl, wl, ("pod", "data")),
               mesh=mesh2,
               in_specs=(P(None, ("pod", "data")), P(("pod", "data"), None)),
               out_specs=P(("pod", "data"), None), check_rep=False)
out = np.asarray(jax.jit(fn)(xs, ws))
results["kernel_folded_fsdp"] = bool(
    np.allclose(out, want, rtol=1e-4, atol=1e-4))

# ------------------------------------------------------------------ #
# engine executor: auto / forced-fused / forced-unfused agree; the
# w=None grad-sync degenerate equals psum_scatter
# ------------------------------------------------------------------ #
mesh = jax.make_mesh((8,), ("model",))
xs = jax.device_put(x, NamedSharding(mesh, P(None, "model")))
ws = jax.device_put(w, NamedSharding(mesh, P("model", None)))
for algo in ("auto", "fused", "unfused"):
    fn = shard_map(
        lambda xl, wl, a=algo: eng.fused_matmul_reduce_scatter(
            xl, wl, "model", algorithm=a),
        mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
        out_specs=P("model", None), check_rep=False)
    out = np.asarray(jax.jit(fn)(xs, ws))
    results[f"engine_{algo}"] = bool(
        np.allclose(out, want, rtol=1e-4, atol=1e-4))

g = jax.random.normal(key, (64, 16), jnp.float32)
gs = jax.device_put(g, NamedSharding(mesh, P(None, None)))
def degenerate(gl):
    a = eng.fused_matmul_reduce_scatter(gl, None, ("model",))
    b = lax.psum_scatter(gl, "model", tiled=True)
    return a, b
fn = shard_map(degenerate, mesh=mesh, in_specs=P(None, None),
               out_specs=(P("model", None), P("model", None)),
               check_rep=False)
a, b = jax.jit(fn)(gs)
results["engine_w_none_degenerate"] = bool(
    np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5))

# ------------------------------------------------------------------ #
# fused swiglu down-projection: forward + grads vs GSPMD reference
# ------------------------------------------------------------------ #
from repro.models import layers

mesh_tp = jax.make_mesh((2, 4), ("data", "model"))
b_, s_, d_, f_ = 8, 16, 32, 64
ks = jax.random.split(jax.random.PRNGKey(3), 4)
xin = jax.random.normal(ks[0], (b_, s_, d_), jnp.float32)
wg = jax.random.normal(ks[1], (d_, f_), jnp.float32) / np.sqrt(d_)
wu = jax.random.normal(ks[2], (d_, f_), jnp.float32) / np.sqrt(d_)
wd = jax.random.normal(ks[3], (f_, d_), jnp.float32) / np.sqrt(f_)

def loss(params, x):
    y = layers.swiglu(x, *params)
    return jnp.sum(y * y)

with mesh_tp:
    layers.set_fused_tp(False)
    ref_l, ref_g = jax.value_and_grad(loss)((wg, wu, wd), xin)
    layers.set_fused_tp(True)
    fus_l, fus_g = jax.value_and_grad(loss)((wg, wu, wd), xin)
    layers.set_fused_tp(False)
results["swiglu_fused_forward"] = bool(
    np.allclose(float(ref_l), float(fus_l), rtol=1e-5))
results["swiglu_fused_grads"] = all(
    bool(np.allclose(np.asarray(r), np.asarray(f), rtol=1e-4, atol=1e-4))
    for r, f in zip(ref_g, fus_g))

# ------------------------------------------------------------------ #
# one-shot latency dispatch: correct result, counted in stats
# ------------------------------------------------------------------ #
mesh = jax.make_mesh((8,), ("data",))
v = jax.random.normal(jax.random.PRNGKey(11), (8, 8), jnp.float32)
vs = jax.device_put(v, NamedSharding(mesh, P("data", None)))
before = eng.stats["latency_dispatches"]
fn = shard_map(
    lambda x: eng.allreduce_inside(x, "data", algorithm="oneshot"),
    mesh=mesh, in_specs=P("data", None), out_specs=P("data", None),
    check_rep=False)
out = np.asarray(jax.jit(fn)(vs))
want = np.tile(np.asarray(v).sum(0), (8, 1))
results["oneshot_allreduce_value"] = bool(
    np.allclose(out, want, rtol=1e-4, atol=1e-4))
results["oneshot_counted"] = eng.stats["latency_dispatches"] > before

print("JSON" + json.dumps(results))
"""


def test_fused_on_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][-1]
    results = json.loads(line[4:])
    for key, ok in results.items():
        assert ok, (key, results)
