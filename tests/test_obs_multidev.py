"""Traced engine collectives on 8 virtual devices, via a subprocess
(tests must not set xla_force_host_platform_device_count globally).

The acceptance scenario of the observability layer: run every engine
collective family over a (2, 4) mesh with tracing on, backfill wall
time by measured replay, and assert the exported Chrome trace loads
back with every collective span carrying predicted cost, measured wall
time, plan description, and cache status -- plus nested phase spans
under the multi-axis plans."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.multidev, pytest.mark.slow]

_SCRIPT = r"""
import json, numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro import obs
from repro.collectives.api import get_engine
from repro.collectives.engine import CollectiveEngine
from repro.collectives.api import set_engine
from repro.obs import replay

results = {}
eng = CollectiveEngine(cache_path=None)
set_engine(eng)
tracer = obs.enable_tracing(measure=True)

devs = np.array(jax.devices()).reshape(2, 4)
mesh = Mesh(devs, ("pod", "data"))
axes = ("pod", "data")

def run(fn, x, in_spec, out_spec):
    w = shard_map(fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec,
                  check_rep=False)
    return jax.block_until_ready(jax.jit(w)(x))

x = jnp.arange(4096, dtype=jnp.float32)
run(lambda v: eng.allreduce_multi(v, axes), x, P(), P())
run(lambda v: eng.reduce_scatter_multi(v, axes), x, P(), P(axes))
xs = jnp.arange(512, dtype=jnp.float32)
run(lambda v: eng.allgather_inside(v, "data"), xs, P("data"), P())
xa = jnp.arange(128, dtype=jnp.float32)
run(lambda v: eng.all_to_all_multi(v, axes), xa, P(axes), P(axes))

spans = tracer.spans
coll = [s for s in spans if s.cat == obs.CAT_COLLECTIVE]
phases = [s for s in spans if s.cat == "phase"]
results["has_collective_spans"] = len(coll) >= 4
results["ops_covered"] = {s.args["op"] for s in coll} >= {
    "allreduce", "reduce_scatter", "allgather", "all_to_all"}
results["traced_mode"] = all(s.args["mode"] == "traced" for s in coll)
results["no_wall_time_yet"] = all(
    s.args["measured_s"] is None for s in coll)

# top-level spans carry the model's decision; the multi-axis ones a
# full plan description, the 1D allgather a bare algorithm
tops = [s for s in coll if s.parent_id is None]
results["top_spans_decided"] = all(
    s.args["predicted"] is not None
    and s.args["cache"] in ("hit", "miss") for s in tops)
multi_tops = [s for s in tops if s.name.endswith("_multi")]
results["multi_spans_have_plan"] = len(multi_tops) >= 3 and all(
    s.args["plan"] is not None and s.args["n_chunks"] >= 1
    for s in multi_tops)
results["phase_spans_nest"] = bool(phases) and all(
    p.parent_id is not None for p in phases)

# measured replay backfills wall time into every replayable span
measured = replay.measure_spans(spans, mesh, engine=eng)
results["replay_measured"] = len(measured) >= 4
results["all_backfilled"] = all(
    s.args["measured_s"] is not None and s.args["measured_s"] > 0
    for s in coll)
results["replay_tagged"] = all(
    s.args.get("measured_via") == "replay" for s in coll)

# the exported trace conforms and loads back identically
results["validates"] = obs.validate_spans(spans) == []
path = "trace_multidev.json"
n = tracer.export_chrome(path)
loaded = obs.load_chrome_trace(path)
results["export_count"] = n == len(spans)
results["roundtrip_ids"] = (
    [s.span_id for s in loaded] == [s.span_id for s in spans])
results["roundtrip_parents"] = (
    [s.parent_id for s in loaded] == [s.parent_id for s in spans])
results["roundtrip_validates"] = obs.validate_spans(loaded) == []
results["roundtrip_measured"] = all(
    s.args["measured_s"] is not None
    for s in loaded if s.cat == obs.CAT_COLLECTIVE)

print("JSON" + json.dumps(results))
"""


def test_traced_collectives_on_8_devices(tmp_path):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          cwd=str(tmp_path), capture_output=True,
                          text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("JSON")][-1]
    results = json.loads(line[4:])
    for key, ok in results.items():
        assert ok, (key, results)
