"""Chunk-pipelined plan execution: planner pricing + engine runner.

Fast tier covers the pipelined candidates' pricing properties (they
win on a heterogeneous `pod=slow` topology at bandwidth-bound bucket
sizes, lose below the launch-overhead cutoff, never undercut the
overlap-aware ``lower_bound_multi``, and report their chunk count and
modeled overlap savings in ``cost_terms``).  The multidev tier checks
the wavefront runner's numerical equivalence against the ``jax.lax``
references for every op on the (2, 4) and (2, 2, 2) debug meshes,
including the odd-length pad paths and the compress=True
error-feedback bucketed path over a folded axis tuple.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.collectives import planner
from repro.collectives.engine import CollectiveEngine
from repro.core.model import parse_fabric_topology


def _slow_engine():
    return CollectiveEngine(fabric=parse_fabric_topology("pod=slow"),
                            persist=False)


# --------------------------- planner pricing -------------------------- #
def test_pipelined_wins_on_slow_pod_at_large_buckets():
    """Acceptance: on a pod=slow topology at >= 1 MiB the argmin is a
    pipelined plan, strictly below the best phase-sequential candidate
    and still >= lower_bound_multi."""
    eng = _slow_engine()
    cases = (("allreduce", (2, 4), 1 << 20),
             ("allreduce", (2, 4), 16 << 20),
             ("all_to_all", (2, 4), 1 << 20),
             ("reduce_scatter", (2, 4), 4 << 20),
             ("allgather", (2, 4), 4 << 20))
    for op, sizes, nbytes in cases:
        plan = eng.plan_multi(op, ("pod", "data"), sizes, nbytes)
        assert plan.shape.endswith("_pipelined"), (op, nbytes,
                                                   plan.predictions)
        serial_best = min(t for s, t in plan.predictions.items()
                          if not s.endswith("_pipelined"))
        assert plan.predicted < serial_best, (op, nbytes)
        assert plan.predicted >= plan.lower_bound - 1e-6
        assert plan.n_chunks >= 2
        entry = plan.cost_terms[plan.shape]
        assert entry["n_chunks"] == plan.n_chunks
        assert entry["overlap_saved"] > 0.0
        assert f"[chunks={plan.n_chunks}]" in plan.describe()


def test_pipelined_loses_below_launch_cutoff():
    """Per-chunk launch overhead makes tiny payloads fall back: the
    pipelined variant is priced but loses to its serial base."""
    eng = _slow_engine()
    for op in ("allreduce", "all_to_all"):
        plan = eng.plan_multi(op, ("pod", "data"), (2, 4), 1 << 12)
        assert not plan.shape.endswith("_pipelined"), (op,
                                                       plan.predictions)
        assert (plan.predictions["hierarchical_pipelined"]
                > plan.predictions["hierarchical"])


def test_single_effective_axis_has_no_pipelined_candidates():
    """One effective axis means one link class -- nothing to overlap,
    so no pipelined candidate is priced."""
    eng = _slow_engine()
    for op in ("allreduce", "reduce_scatter", "allgather",
               "all_to_all"):
        plan = eng.plan_multi(op, ("pod", "data"), (1, 8), 1 << 20)
        assert not any(s.endswith("_pipelined")
                       for s in plan.predictions), (op, plan.predictions)
        assert plan.n_chunks == 1


def test_overlap_savings_consistent_with_serial_base():
    """cost_terms reports overlap_saved == serial base predicted minus
    the pipelined predicted, and the pipelined plan ships at least the
    serial plan's wire bytes per axis (chunk quantization only adds)."""
    eng = _slow_engine()
    plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 4), 4 << 20)
    for name, entry in plan.cost_terms.items():
        if not name.endswith("_pipelined"):
            assert "n_chunks" not in entry
            continue
        base = planner.base_shape(name)
        saved = (plan.cost_terms[base]["predicted"]
                 - entry["predicted"])
        assert entry["overlap_saved"] == pytest.approx(saved)
        for ax, b in plan.cost_terms[base]["axis_bytes"].items():
            assert entry["axis_bytes"][ax] >= b - 1e-6, (name, ax)


def test_forced_pipelined_shape_and_chunk_count():
    """Forcing a *_pipelined shape works on a uniform fabric too, and
    the plan carries the model-chosen chunk count."""
    eng = CollectiveEngine(persist=False)
    plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 4), 1 << 20,
                          shape="hierarchical_pipelined")
    assert plan.shape == "hierarchical_pipelined"
    assert plan.n_chunks in planner.PIPELINE_CHUNK_CANDIDATES
    rec = eng.plan_multi("all_to_all", ("pod", "data"), (2, 4), 1 << 20,
                         shape="sequential_pipelined")
    assert rec.n_chunks >= 2
    assert [s.axes[0] for s in rec.steps] == ["pod", "data"]


def test_pipelined_plan_survives_cache_roundtrip(tmp_path):
    """n_chunks and the extra cost_terms keys persist through the plan
    cache (flush + reload)."""
    path = str(tmp_path / "decisions.json")
    eng = CollectiveEngine(fabric=parse_fabric_topology("pod=slow"),
                          cache_path=path)
    p1 = eng.plan_multi("allreduce", ("pod", "data"), (2, 4), 1 << 20)
    assert p1.shape.endswith("_pipelined") and p1.n_chunks >= 2
    eng.flush()
    eng2 = CollectiveEngine(fabric=parse_fabric_topology("pod=slow"),
                            cache_path=path)
    p2 = eng2.plan_multi("allreduce", ("pod", "data"), (2, 4), 1 << 20)
    assert eng2.stats["plan_hits"] == 1
    assert p2.shape == p1.shape and p2.n_chunks == p1.n_chunks
    assert (p2.cost_terms[p2.shape]["overlap_saved"]
            == pytest.approx(p1.cost_terms[p1.shape]["overlap_saved"]))


# ----------------------- multidev equivalence ------------------------- #
_SCRIPT = r"""
import functools, json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.collectives.api import (allreduce_multi_inside,
                                   reduce_scatter_multi_inside,
                                   allgather_multi_inside,
                                   all_to_all_multi_inside)
from repro.collectives.overlap import bucketed_allreduce

results = {}
mesh24 = jax.make_mesh((2, 4), ("pod", "data"))
mesh222 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

def run(mesh, fn, x, in_spec, out_spec):
    f = shard_map(fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec,
                  check_rep=False)
    with mesh:
        return np.asarray(jax.jit(f)(x))

for mesh, axes, tag in ((mesh24, ("pod", "data"), "24"),
                        (mesh222, ("pod", "data"), "222sub"),
                        (mesh222, ("pod", "data", "model"), "222")):
    # odd length exercises every chunk/phase pad path
    x = jax.random.normal(jax.random.PRNGKey(1), (13,))
    ref = run(mesh, lambda v: jax.lax.psum(v, axes), x, P(), P())
    for shape in ("sequential_pipelined", "hierarchical_pipelined"):
        out = run(mesh, functools.partial(allreduce_multi_inside,
                                          axes=axes, algorithm=shape),
                  x, P(), P())
        results[f"ar_{tag}_{shape}"] = bool(
            np.allclose(out, ref, rtol=1e-4, atol=1e-4))

    p = 1
    for a in axes:
        p *= mesh.shape[a]
    xs = jax.random.normal(jax.random.PRNGKey(2), (p * 3, 5))
    ref = run(mesh, lambda v: jax.lax.psum_scatter(
        v, axes, scatter_dimension=0, tiled=True), xs, P(), P(axes))
    out = run(mesh, functools.partial(reduce_scatter_multi_inside,
                                      axes=axes,
                                      algorithm="cascade_pipelined"),
              xs, P(), P(axes))
    results[f"rs_{tag}"] = bool(np.allclose(out, ref, rtol=1e-4,
                                            atol=1e-4))

    ref = run(mesh, lambda v: jax.lax.all_gather(v, axes, tiled=True),
              xs, P(axes), P())
    out = run(mesh, functools.partial(allgather_multi_inside, axes=axes,
                                      algorithm="cascade_pipelined"),
              xs, P(axes), P())
    results[f"ag_{tag}"] = bool(np.allclose(out, ref))

    ref = run(mesh, lambda v: jax.lax.all_to_all(
        v, axes if len(axes) > 1 else axes[0], 0, 0, tiled=True),
        xs, P(), P())
    for shape in ("hierarchical_pipelined", "sequential_pipelined"):
        out = run(mesh, functools.partial(all_to_all_multi_inside,
                                          axes=axes, algorithm=shape),
                  xs, P(), P())
        results[f"a2a_{tag}_{shape}"] = bool(
            np.allclose(out, ref, rtol=1e-4, atol=1e-4))

# compress=True error feedback through a forced pipelined plan over the
# folded ("pod", "data") tuple
grads = {"a": jnp.ones((1000,)) * 0.5, "b": jnp.full((64, 32), 2.0)}
reduced, ef = bucketed_allreduce(
    grads, mesh222, axes=("pod", "data"),
    algorithm="hierarchical_pipelined", bucket_bytes=2048,
    compress=True,
    error_feedback=jax.tree.map(jnp.zeros_like, grads))
results["bucketed_pipelined_compressed"] = (
    bool(np.allclose(np.asarray(reduced["a"]), 0.5, rtol=1e-2))
    and bool(np.allclose(np.asarray(reduced["b"]), 2.0, rtol=1e-2))
    and ef is not None)
print("JSON" + json.dumps(results))
"""


@pytest.mark.multidev
@pytest.mark.slow
def test_pipelined_execution_on_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("JSON")][-1]
    results = json.loads(line[4:])
    for key, ok in results.items():
        assert ok, (key, results)
