"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step on CPU; output shapes + no NaNs (assignment
requirement)."""

import numpy as np
import jax
import pytest

from repro.configs import get_config, list_archs
from repro.models import (decode_step, forward_train, init_params, prefill)
from repro.models.frontend import audio_frames, vision_patches
from repro.optim.adamw import AdamWConfig
from repro.train.state import init_train_state
from repro.train.step import make_train_step

B, S = 2, 64


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = audio_frames(key, cfg, B, S)
    if cfg.frontend == "vision":
        batch["soft_emb"] = vision_patches(key, cfg, B)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = forward_train(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    state = init_train_state(params)
    batch = _batch(cfg, key)
    batch["labels"] = jax.random.randint(key, batch["tokens"].shape, 0,
                                         cfg.vocab_size)
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10))
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree.leaves(state.params)[3]
    after = jax.tree.leaves(state2.params)[3]
    assert not np.allclose(np.asarray(before, np.float32),
                           np.asarray(after, np.float32))


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_consistency(arch):
    """decode_step(prefill(x[:-1])) logits == forward(x) last-position
    logits (KV-cache correctness)."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    full_logits, _ = forward_train(params, cfg, batch)

    prompt = dict(batch, tokens=batch["tokens"][:, :-1])
    _, cache = prefill(params, cfg, prompt)
    step_logits, cache2 = decode_step(params, cfg, cache,
                                      {"tokens": batch["tokens"][:, -1:]})
    assert int(cache2["pos"]) == int(cache["pos"]) + 1
    if cfg.family == "ssm":
        # exact: recurrent state carries everything
        np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                                   np.asarray(full_logits[:, -1]),
                                   rtol=2e-2, atol=2e-2)
    elif cfg.family == "dense" and cfg.frontend != "vision":
        # atol covers bf16 rounding: prefill and decode accumulate the
        # attention/KV math in different orders, and on the CPU backend a
        # handful of logits land one bf16 ulp (~0.03 at |x|~2) apart
        np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                                   np.asarray(full_logits[:, -1]),
                                   rtol=2e-2, atol=3e-2)
    elif cfg.family == "moe":
        # MoE capacity dropping differs between a gs=S-1 prefill and a
        # gs=1 decode (tokens past expert capacity are dropped in the
        # longer group); logits agree up to those drops.
        a = np.asarray(step_logits[:, 0]).ravel()
        b = np.asarray(full_logits[:, -1]).ravel()
        corr = np.corrcoef(a, b)[0, 1]
        assert corr > 0.99, corr
    else:
        assert np.all(np.isfinite(np.asarray(step_logits, np.float32)))


def test_encdec_prefill_matches_forward():
    """Whisper backbone: decoder prefill logits at the last position ==
    forward_train logits at the last position (same enc context)."""
    cfg = get_config("whisper-medium").reduced()
    key = jax.random.PRNGKey(5)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    full_logits, _ = forward_train(params, cfg, batch)
    lg, cache = prefill(params, cfg, batch)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)
