"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret
mode (CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.multi_add import multi_add
from repro.kernels.ref import flash_attention_ref, multi_add_ref


@pytest.mark.parametrize("k", [2, 3, 8, 17])
@pytest.mark.parametrize("n", [128, 512, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_multi_add_sweep(k, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(k * n), (k, n)).astype(dtype)
    got = multi_add(x)
    want = multi_add_ref(x)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,hkv,s,d", [
    (1, 2, 2, 128, 32),
    (2, 4, 2, 256, 64),
    (1, 8, 1, 256, 64),     # MQA
    (2, 4, 4, 128, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, hkv, s, d, causal):
    keys = jax.random.split(jax.random.PRNGKey(b * s + h), 3)
    q = jax.random.normal(keys[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, hkv, s, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_window(window):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    b, h, s, d = 1, 4, 256, 64
    q = jax.random.normal(keys[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, h, s, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, h, s, d), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    want = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    b, h, s, d = 1, 2, 128, 64
    q = jax.random.normal(keys[0], (b, h, s, d)).astype(jnp.bfloat16)
    k = jax.random.normal(keys[1], (b, h, s, d)).astype(jnp.bfloat16)
    v = jax.random.normal(keys[2], (b, h, s, d)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_jax_chunked_attention_matches_kernel_oracle():
    """The pure-JAX chunked path used by the dry-run model == kernel
    oracle."""
    from repro.models.layers import attention
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    b, s, h, hkv, d = 2, 4096, 4, 2, 32   # s > chunk threshold
    q = jax.random.normal(keys[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(keys[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(keys[2], (b, s, hkv, d), jnp.float32)
    got = attention(q, k, v, causal=True)           # [B, S, H, D]
    want = flash_attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                               jnp.moveaxis(v, 1, 2), causal=True)
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(got, 1, 2)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,s,d,n,block_d,chunk", [
    (1, 32, 16, 8, 16, 16),
    (2, 64, 32, 8, 16, 32),
    (2, 128, 64, 16, 32, 64),
    (1, 64, 48, 16, 16, 16),
])
def test_selective_scan_sweep(b, s, d, n, block_d, chunk):
    from repro.kernels.selective_scan import selective_scan
    from repro.kernels.ref import selective_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(b * s + d), 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, d)))
    x = jax.random.normal(ks[1], (b, s, d))
    bb = jax.random.normal(ks[2], (b, s, n))
    c = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.5)
    h0 = jax.random.normal(ks[5], (b, d, n))
    y_k, h_k = selective_scan(dt, x, bb, c, a, h0, block_d=block_d,
                              chunk=chunk)
    y_r, h_r = selective_scan_ref(dt, x, bb, c, a, h0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=1e-4, atol=1e-4)


def test_selective_scan_bf16_inputs():
    from repro.kernels.selective_scan import selective_scan
    from repro.kernels.ref import selective_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(9), 6)
    b, s, d, n = 1, 32, 16, 8
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, d))).astype(
        jnp.bfloat16)
    x = jax.random.normal(ks[1], (b, s, d)).astype(jnp.bfloat16)
    bb = jax.random.normal(ks[2], (b, s, n)).astype(jnp.bfloat16)
    c = jax.random.normal(ks[3], (b, s, n)).astype(jnp.bfloat16)
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.5)
    h0 = jnp.zeros((b, d, n), jnp.float32)
    y_k, h_k = selective_scan(dt, x, bb, c, a, h0, block_d=16, chunk=16)
    y_r, h_r = selective_scan_ref(dt, x, bb, c, a, h0)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("b,s,d,n,block_d,chunk", [
    (1, 32, 16, 8, 16, 16),
    (2, 64, 32, 8, 16, 32),
])
def test_selective_scan_backward_kernel(b, s, d, n, block_d, chunk):
    """Backward (flash-style recompute) kernel vs jax.grad of the
    oracle, for every input cotangent."""
    from repro.kernels.selective_scan import selective_scan_trainable
    from repro.kernels.ref import selective_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(7 * b + s), 7)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, d)))
    x = jax.random.normal(ks[1], (b, s, d))
    bb = jax.random.normal(ks[2], (b, s, n))
    c = jax.random.normal(ks[3], (b, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (d, n)) * 0.5)
    h0 = jax.random.normal(ks[5], (b, d, n))
    w = jax.random.normal(ks[6], (b, s, d))

    def lk(*args):
        y, hf = selective_scan_trainable(*args, block_d, chunk, True)
        return jnp.sum(y * w) + 0.5 * jnp.sum(hf)

    def lr(*args):
        y, hf = selective_scan_ref(*args)
        return jnp.sum(y * w) + 0.5 * jnp.sum(hf)

    gk = jax.grad(lk, argnums=tuple(range(6)))(dt, x, bb, c, a, h0)
    gr = jax.grad(lr, argnums=tuple(range(6)))(dt, x, bb, c, a, h0)
    for k_, r_ in zip(gk, gr):
        denom = float(jnp.max(jnp.abs(r_))) + 1e-9
        assert float(jnp.max(jnp.abs(k_ - r_))) / denom < 1e-4


def test_mamba_block_kernel_path_matches_jnp():
    """The fused-kernel mamba block (fwd + grad) == the chunked jnp
    path."""
    from repro.models.ssm import init_mamba_params, mamba_block
    key = jax.random.PRNGKey(0)
    d_model, di, n, r = 32, 64, 8, 4
    p = init_mamba_params(key, d_model, di, n, r, 4, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, d_model))
    y1, st1 = mamba_block(x, p, ssm_state=n, use_kernel=False)
    y2, st2 = mamba_block(x, p, ssm_state=n, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    g1 = jax.grad(lambda x: mamba_block(x, p, ssm_state=n)[0].sum())(x)
    g2 = jax.grad(lambda x: mamba_block(x, p, ssm_state=n,
                                        use_kernel=True)[0].sum())(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-5)
