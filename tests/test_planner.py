"""Topology-aware collective planner: joint multi-axis plans.

Fast tier -- no devices needed.  Covers the acceptance properties of
the planner itself (hierarchical moves strictly fewer modeled cross-pod
bytes than sequential; nothing beats the 2D lower bound), the re-keyed
persistent decision cache (topology signatures, schema v2, v1
migration), and plan introspection.  Execution correctness against the
jax.lax references lives in the multidev tier
(tests/test_collectives_multidev.py, tests/test_engine.py).
"""

import json

import pytest

from repro.collectives import planner
from repro.collectives.engine import (CollectiveEngine, ICI_ELEMENT_BYTES,
                                      SCHEMA_VERSION)
from repro.core.model import TPU_V5E_AXIS, WSE2


def _engine(tmp_path, **kw):
    return CollectiveEngine(cache_path=str(tmp_path / "decisions.json"),
                            **kw)


# --------------------------- plan properties -------------------------- #
def test_hierarchical_moves_fewer_cross_pod_bytes(tmp_path):
    """On the (2,2,2) debug mesh's ("pod","data") DP topology, the
    hierarchical composition's cross-pod phase sees B/P_inner bytes
    while the sequential loop ships the full vector -- asserted via
    CollectivePlan.cost_terms, per bucket size."""
    eng = _engine(tmp_path)
    for nbytes in (1 << 10, 1 << 16, 1 << 22, 64 << 20):
        plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 2),
                              nbytes)
        hier = plan.cost_terms["hierarchical"]["axis_bytes"]["pod"]
        seq = plan.cost_terms["sequential"]["axis_bytes"]["pod"]
        assert hier < seq, (nbytes, hier, seq)
        assert hier == pytest.approx(seq / 2)   # inner axis size 2


def test_planner_argmin_and_predictions(tmp_path):
    eng = _engine(tmp_path)
    plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 16), 1 << 22)
    assert set(plan.predictions) == {"sequential", "hierarchical",
                                     "2d_xy", "2d_snake", "flat"}
    assert plan.predicted == min(plan.predictions.values())
    assert plan.shape == min(plan.predictions, key=plan.predictions.get)
    # hierarchical must beat sequential at DP-bucket sizes: its cross-pod
    # phase runs on 1/16 of the bytes
    assert (plan.predictions["hierarchical"]
            < plan.predictions["sequential"])


def test_no_plan_beats_2d_lower_bound(tmp_path):
    """Every candidate shape of every multi-axis op stays above the
    paper's Lemma 7.2 bound for its folded topology (the planner raises
    on violation; this sweep exercises it across fabrics/shapes)."""
    for fabric in (TPU_V5E_AXIS, WSE2):
        eng = CollectiveEngine(fabric=fabric, persist=False)
        for sizes in ((2, 2), (2, 4), (4, 4), (2, 2, 2), (1, 8)):
            for op in ("allreduce", "reduce_scatter", "allgather"):
                for nbytes in (512, 1 << 13, 1 << 20, 1 << 26):
                    axes = tuple(f"a{i}" for i in range(len(sizes)))
                    plan = eng.plan_multi(op, axes, sizes, nbytes)
                    assert plan.predicted >= plan.lower_bound - 1e-6
                    for shape, t in plan.predictions.items():
                        assert t >= plan.lower_bound - 1e-6, (
                            fabric.name, sizes, op, nbytes, shape)


def test_forced_shape_and_describe(tmp_path):
    eng = _engine(tmp_path)
    plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 2), 1 << 20,
                          shape="2d_snake")
    assert plan.shape == "2d_snake"
    assert plan.describe().startswith("2d_snake(")
    plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 2), 1 << 20,
                          shape="hierarchical")
    kinds = [s.kind for s in plan.steps]
    assert kinds == ["reduce_scatter", "allreduce", "allgather"]
    assert plan.steps[0].axes == ("data",)      # inner first
    assert plan.steps[1].axes == ("pod",)
    with pytest.raises(ValueError):
        eng.plan_multi("allreduce", ("pod", "data"), (2, 2), 1 << 20,
                       shape="nonsense")


def test_three_axis_hierarchy_recurses(tmp_path):
    eng = _engine(tmp_path)
    plan = eng.plan_multi("allreduce", ("pod", "data", "model"),
                          (2, 2, 2), 1 << 20, shape="hierarchical")
    rs, mid, ag = plan.steps
    assert rs.axes == ("model",) and ag.axes == ("model",)
    assert mid.axes == ("pod", "data")
    # the middle step names a plan shape for the outer sub-topology
    assert mid.algorithm in planner.ALLREDUCE_SHAPES
    assert mid.nbytes < plan.nbytes


def test_sharded_op_plans(tmp_path):
    eng = _engine(tmp_path)
    rs = eng.plan_multi("reduce_scatter", ("pod", "data"), (2, 4),
                        1 << 20)
    assert set(rs.predictions) == {"cascade", "flat"}
    ag = eng.plan_multi("allgather", ("pod", "data"), (2, 4), 1 << 20)
    assert set(ag.predictions) == {"cascade", "flat"}
    # cascade reduce-scatter shrinks innermost-first
    forced = eng.plan_multi("reduce_scatter", ("pod", "data"), (2, 4),
                            1 << 20, shape="cascade")
    assert [s.axes[0] for s in forced.steps] == ["data", "pod"]
    assert forced.steps[0].nbytes > forced.steps[1].nbytes
    # cascade allgather grows outermost-first (the exact inverse)
    forced = eng.plan_multi("allgather", ("pod", "data"), (2, 4),
                            1 << 20, shape="cascade")
    assert [s.axes[0] for s in forced.steps] == ["pod", "data"]


# --------------------------- cache behavior --------------------------- #
def test_plan_cache_hit_miss_and_persistence(tmp_path):
    eng = _engine(tmp_path)
    p1 = eng.plan_multi("allreduce", ("pod", "data"), (2, 8), 1 << 20)
    assert eng.stats["plan_misses"] == 1
    p2 = eng.plan_multi("allreduce", ("pod", "data"), (2, 8), 1 << 20)
    assert eng.stats["plan_hits"] == 1 and eng.stats["plan_misses"] == 1
    assert p1 == p2
    # different topology, same folded size: fresh plan
    eng.plan_multi("allreduce", ("pod", "data"), (4, 4), 1 << 20)
    assert eng.stats["plan_misses"] == 2
    eng.flush()

    eng2 = _engine(tmp_path)
    q = eng2.plan_multi("allreduce", ("pod", "data"), (2, 8), 1 << 20)
    assert eng2.stats["plan_misses"] == 0
    assert eng2.stats["plan_hits"] == 1
    assert q.shape == p1.shape
    assert q.predictions == pytest.approx(p1.predictions)
    # axis names rebind on retrieval: same topology, different mesh names
    r = eng2.plan_multi("allreduce", ("x", "y"), (2, 8), 1 << 20)
    assert r.steps[0].axes[0] in ("x", "y")


def test_topology_signature_avoids_1d_collisions(tmp_path):
    """A 16-way 'data' axis and a 16-way folded (2, 8) topology must
    not share decision-cache entries."""
    eng = _engine(tmp_path)
    d_1d = eng.select("allreduce", 1 << 20, 16)
    misses = eng.stats["misses"]
    d_folded = eng.select("allreduce", 1 << 20, 16, topo=(2, 8))
    assert eng.stats["misses"] == misses + 1, "folded topo hit 1D entry"
    assert d_1d.p == d_folded.p == 16
    # and the 1D entry is still served from cache
    eng.select("allreduce", 1 << 20, 16)
    assert eng.stats["misses"] == misses + 1


def test_schema_v1_cache_migrates(tmp_path):
    """A v1 (schema-less, 'op|p=..' keyed) decisions file loads into
    the v2 engine: its entries are re-keyed as 1D topology signatures
    and served as hits."""
    eng = _engine(tmp_path)
    d = eng.select("allreduce", 1 << 20, 8)
    eng.flush()
    path = str(tmp_path / "decisions.json")
    with open(path) as f:
        payload = json.load(f)
    assert payload["schema"] == SCHEMA_VERSION
    legacy = {
        "fabric": payload["fabric"],
        "decisions": {k.replace("|t=", "|p=", 1): v
                      for k, v in payload["decisions"].items()},
    }
    with open(path, "w") as f:
        json.dump(legacy, f)

    eng2 = _engine(tmp_path)
    d2 = eng2.select("allreduce", 1 << 20, 8)
    assert eng2.stats["misses"] == 0, "v1 entry was not migrated"
    assert eng2.stats["hits"] == 1
    assert d2.algorithm == d.algorithm
    assert d2.predictions == pytest.approx(d.predictions)


# ------------------------ simulator cross-check ----------------------- #
def test_planner_2d_pricing_matches_flow_simulator():
    """On the WSE2 fabric the planner's 2D candidates are exactly the
    Sec.-7 closed forms the flow simulator validates: the snake
    estimate must equal the simulator comparison's model column, and
    the flow simulation itself must land within the paper's error
    envelope."""
    from repro.simulator.runner import compare_allreduce_2d

    eng = CollectiveEngine(fabric=WSE2, persist=False)
    for m, n in ((4, 4), (8, 8)):
        for b in (64, 4096):
            nbytes = b * ICI_ELEMENT_BYTES
            plan = eng.plan_multi("allreduce", ("y", "x"), (m, n), nbytes)
            cmp = compare_allreduce_2d("snake", m, n, b, WSE2)
            assert plan.predictions["2d_snake"] == pytest.approx(
                cmp.model_cycles)
            assert cmp.rel_error < 0.35, (m, n, b, cmp)
            # xy candidate: planner takes the best pattern per
            # dimension, so it lower-bounds every uniform-pattern xy
            for pattern in ("chain", "two_phase"):
                uni = compare_allreduce_2d(pattern, m, n, b, WSE2)
                assert (plan.predictions["2d_xy"]
                        <= uni.model_cycles + 1e-6)


def test_lower_bound_multi_folding():
    b = 4096 * ICI_ELEMENT_BYTES
    lb_22 = planner.lower_bound_multi("allreduce", (2, 2), b,
                                      TPU_V5E_AXIS, ICI_ELEMENT_BYTES)
    lb_44 = planner.lower_bound_multi("allreduce", (4, 4), b,
                                      TPU_V5E_AXIS, ICI_ELEMENT_BYTES)
    assert lb_44 >= lb_22 > 0
    assert planner.lower_bound_multi("allreduce", (1, 1), b,
                                     TPU_V5E_AXIS,
                                     ICI_ELEMENT_BYTES) == 0.0
