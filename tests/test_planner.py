"""Topology-aware collective planner: joint multi-axis plans.

Fast tier -- no devices needed.  Covers the acceptance properties of
the planner itself (hierarchical moves strictly fewer modeled cross-pod
bytes than sequential; nothing beats the 2D lower bound), the re-keyed
persistent decision cache (topology signatures, schema v2, v1
migration), and plan introspection.  Execution correctness against the
jax.lax references lives in the multidev tier
(tests/test_collectives_multidev.py, tests/test_engine.py).
"""

import dataclasses
import json

import pytest

from repro.collectives import planner
from repro.collectives.engine import (CollectiveEngine, ICI_ELEMENT_BYTES,
                                      SCHEMA_VERSION)
from repro.core.model import (FabricTopology, TPU_V5E_AXIS, WSE2,
                              parse_fabric_topology)


def _engine(tmp_path, **kw):
    return CollectiveEngine(cache_path=str(tmp_path / "decisions.json"),
                            **kw)


def _slow_pod_topology(factor: float = 4.0) -> FabricTopology:
    """(pod, data) with the pod link ``factor``x slower than data."""
    slow = dataclasses.replace(TPU_V5E_AXIS,
                               name=f"{TPU_V5E_AXIS.name}_pod",
                               link_bw=TPU_V5E_AXIS.link_bw / factor,
                               t_r=TPU_V5E_AXIS.t_r * factor)
    return FabricTopology(default=TPU_V5E_AXIS,
                          axis_fabrics=(("pod", slow),))


# --------------------------- plan properties -------------------------- #
def test_hierarchical_moves_fewer_cross_pod_bytes(tmp_path):
    """On the (2,2,2) debug mesh's ("pod","data") DP topology, the
    hierarchical composition's cross-pod phase sees B/P_inner bytes
    while the sequential loop ships the full vector -- asserted via
    CollectivePlan.cost_terms, per bucket size."""
    eng = _engine(tmp_path)
    for nbytes in (1 << 10, 1 << 16, 1 << 22, 64 << 20):
        plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 2),
                              nbytes)
        hier = plan.cost_terms["hierarchical"]["axis_bytes"]["pod"]
        seq = plan.cost_terms["sequential"]["axis_bytes"]["pod"]
        assert hier < seq, (nbytes, hier, seq)
        assert hier == pytest.approx(seq / 2)   # inner axis size 2


def test_planner_argmin_and_predictions(tmp_path):
    eng = _engine(tmp_path)
    plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 16), 1 << 22)
    assert set(plan.predictions) == {"sequential", "hierarchical",
                                     "2d_xy", "2d_snake", "flat",
                                     "latency",
                                     "sequential_pipelined",
                                     "hierarchical_pipelined"}
    assert plan.predicted == min(plan.predictions.values())
    assert plan.shape == min(plan.predictions, key=plan.predictions.get)
    # hierarchical must beat sequential at DP-bucket sizes: its cross-pod
    # phase runs on 1/16 of the bytes
    assert (plan.predictions["hierarchical"]
            < plan.predictions["sequential"])


def test_no_plan_beats_2d_lower_bound(tmp_path):
    """Every candidate shape of every multi-axis op stays above the
    paper's Lemma 7.2 bound for its folded topology (the planner raises
    on violation; this sweep exercises it across fabrics/shapes)."""
    for fabric in (TPU_V5E_AXIS, WSE2):
        eng = CollectiveEngine(fabric=fabric, persist=False)
        for sizes in ((2, 2), (2, 4), (4, 4), (2, 2, 2), (1, 8)):
            for op in ("allreduce", "reduce_scatter", "allgather",
                       "all_to_all"):
                for nbytes in (512, 1 << 13, 1 << 20, 1 << 26):
                    axes = tuple(f"a{i}" for i in range(len(sizes)))
                    plan = eng.plan_multi(op, axes, sizes, nbytes)
                    assert plan.predicted >= plan.lower_bound - 1e-6
                    for shape, t in plan.predictions.items():
                        assert t >= plan.lower_bound - 1e-6, (
                            fabric.name, sizes, op, nbytes, shape)


def test_forced_shape_and_describe(tmp_path):
    eng = _engine(tmp_path)
    plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 2), 1 << 20,
                          shape="2d_snake")
    assert plan.shape == "2d_snake"
    assert plan.describe().startswith("2d_snake(")
    plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 2), 1 << 20,
                          shape="hierarchical")
    kinds = [s.kind for s in plan.steps]
    assert kinds == ["reduce_scatter", "allreduce", "allgather"]
    assert plan.steps[0].axes == ("data",)      # inner first
    assert plan.steps[1].axes == ("pod",)
    with pytest.raises(ValueError):
        eng.plan_multi("allreduce", ("pod", "data"), (2, 2), 1 << 20,
                       shape="nonsense")


def test_three_axis_hierarchy_recurses(tmp_path):
    eng = _engine(tmp_path)
    plan = eng.plan_multi("allreduce", ("pod", "data", "model"),
                          (2, 2, 2), 1 << 20, shape="hierarchical")
    rs, mid, ag = plan.steps
    assert rs.axes == ("model",) and ag.axes == ("model",)
    assert mid.axes == ("pod", "data")
    # the middle step names a plan shape for the outer sub-topology
    assert mid.algorithm in planner.ALLREDUCE_SHAPES
    assert mid.nbytes < plan.nbytes


def test_sharded_op_plans(tmp_path):
    eng = _engine(tmp_path)
    rs = eng.plan_multi("reduce_scatter", ("pod", "data"), (2, 4),
                        1 << 20)
    assert set(rs.predictions) == {"cascade", "flat",
                                   "cascade_pipelined"}
    ag = eng.plan_multi("allgather", ("pod", "data"), (2, 4), 1 << 20)
    assert set(ag.predictions) == {"cascade", "flat", "latency",
                                   "cascade_pipelined"}
    # cascade reduce-scatter shrinks innermost-first
    forced = eng.plan_multi("reduce_scatter", ("pod", "data"), (2, 4),
                            1 << 20, shape="cascade")
    assert [s.axes[0] for s in forced.steps] == ["data", "pod"]
    assert forced.steps[0].nbytes > forced.steps[1].nbytes
    # cascade allgather grows outermost-first (the exact inverse)
    forced = eng.plan_multi("allgather", ("pod", "data"), (2, 4),
                            1 << 20, shape="cascade")
    assert [s.axes[0] for s in forced.steps] == ["pod", "data"]


# ------------------------------ all_to_all ---------------------------- #
def test_a2a_candidate_set_and_shapes(tmp_path):
    eng = _engine(tmp_path)
    plan = eng.plan_multi("all_to_all", ("pod", "data"), (2, 4), 1 << 20)
    assert set(plan.predictions) == {"hierarchical", "sequential",
                                     "flat", "latency",
                                     "hierarchical_pipelined",
                                     "sequential_pipelined"}
    assert plan.predicted == min(plan.predictions.values())
    # hierarchical runs intra-pod (inner) first, then cross-pod
    forced = eng.plan_multi("all_to_all", ("pod", "data"), (2, 4),
                            1 << 20, shape="hierarchical")
    assert [s.kind for s in forced.steps] == ["all_to_all", "all_to_all"]
    assert [s.axes[0] for s in forced.steps] == ["data", "pod"]
    assert forced.describe().startswith("hierarchical(a2a:")
    # sequential is the naive outermost-first order of the same phases
    seq = eng.plan_multi("all_to_all", ("pod", "data"), (2, 4), 1 << 20,
                         shape="sequential")
    assert [s.axes[0] for s in seq.steps] == ["pod", "data"]
    # AllToAll conserves bytes: both orders price identically, and the
    # argmin tie resolves to hierarchical (aggregate before crossing)
    assert (plan.predictions["hierarchical"]
            == pytest.approx(plan.predictions["sequential"]))
    # a single effective axis degenerates to one sequential phase
    one = eng.plan_multi("all_to_all", ("pod", "data"), (1, 8), 1 << 20)
    assert one.shape == "sequential" and len(one.steps) == 1


def test_a2a_selector_frontier(tmp_path):
    """1D backend selection: Bruck halving (log launches) wins the
    latency-bound region, the pairwise ring (injection-optimal) the
    bandwidth-bound region."""
    eng = _engine(tmp_path)
    small = eng.select("all_to_all", 512, 8)
    big = eng.select("all_to_all", 16 << 20, 8)
    assert set(small.predictions) == {"ring", "halving"}
    assert small.algorithm == "halving", small.predictions
    assert big.algorithm == "ring", big.predictions


def test_a2a_slow_pod_picks_hierarchical_fewer_cross_pod_bytes():
    """Acceptance: on a pod=slow topology the joint argmin is the
    2-phase intra-pod/inter-pod decomposition, its modeled cross-pod
    wire bytes are strictly below the flat single-shot exchange's, and
    every candidate respects the Theta(B*(P-1)/P) bound."""
    eng = CollectiveEngine(fabric=parse_fabric_topology("pod=slow"),
                           persist=False)
    for sizes in ((2, 4), (2, 16), (4, 8)):
        for nbytes in (1 << 16, 1 << 20, 64 << 20):
            plan = eng.plan_multi("all_to_all", ("pod", "data"), sizes,
                                  nbytes)
            assert planner.base_shape(plan.shape) == "hierarchical", (
                sizes, nbytes, plan.predictions)
            hier = plan.cost_terms["hierarchical"]["axis_bytes"]["pod"]
            flat = plan.cost_terms["flat"]["axis_bytes"]["pod"]
            assert hier < flat, (sizes, nbytes)
            # the cross-pod phase ships exactly B*(M-1)/M per device
            m = sizes[0]
            assert hier == pytest.approx(nbytes * (m - 1) / m)
            for shape, t in plan.predictions.items():
                assert t >= plan.lower_bound - 1e-6, (sizes, nbytes,
                                                      shape)


def test_a2a_lower_bound_sweep_heterogeneous():
    """LB sweep over heterogeneous fabrics: no candidate shape of any
    topology/byte-size combination undercuts the injection bound (the
    planner raises on violation; this exercises it broadly)."""
    topos = (parse_fabric_topology("pod=slow"),
             parse_fabric_topology("pod=slow,data=0.5"),
             parse_fabric_topology("pod=dcn"),
             _slow_pod_topology(16.0))
    for topo in topos:
        eng = CollectiveEngine(fabric=topo, persist=False)
        for sizes in ((2, 2), (2, 8), (4, 4), (2, 2, 2)):
            axes = ("pod", "data", "model")[:len(sizes)]
            for nbytes in (512, 1 << 16, 1 << 24):
                plan = eng.plan_multi("all_to_all", axes, sizes, nbytes)
                assert plan.lower_bound > 0.0
                for shape, t in plan.predictions.items():
                    assert t >= plan.lower_bound - 1e-6, (
                        topo.describe(), sizes, nbytes, shape)


# --------------------------- cache behavior --------------------------- #
def test_plan_cache_hit_miss_and_persistence(tmp_path):
    eng = _engine(tmp_path)
    p1 = eng.plan_multi("allreduce", ("pod", "data"), (2, 8), 1 << 20)
    assert eng.stats["plan_misses"] == 1
    p2 = eng.plan_multi("allreduce", ("pod", "data"), (2, 8), 1 << 20)
    assert eng.stats["plan_hits"] == 1 and eng.stats["plan_misses"] == 1
    assert p1 == p2
    # different topology, same folded size: fresh plan
    eng.plan_multi("allreduce", ("pod", "data"), (4, 4), 1 << 20)
    assert eng.stats["plan_misses"] == 2
    eng.flush()

    eng2 = _engine(tmp_path)
    q = eng2.plan_multi("allreduce", ("pod", "data"), (2, 8), 1 << 20)
    assert eng2.stats["plan_misses"] == 0
    assert eng2.stats["plan_hits"] == 1
    assert q.shape == p1.shape
    assert q.predictions == pytest.approx(p1.predictions)
    # axis names rebind on retrieval: same topology, different mesh names
    r = eng2.plan_multi("allreduce", ("x", "y"), (2, 8), 1 << 20)
    assert r.steps[0].axes[0] in ("x", "y")


def test_topology_signature_avoids_1d_collisions(tmp_path):
    """A 16-way 'data' axis and a 16-way folded (2, 8) topology must
    not share decision-cache entries."""
    eng = _engine(tmp_path)
    d_1d = eng.select("allreduce", 1 << 20, 16)
    misses = eng.stats["misses"]
    d_folded = eng.select("allreduce", 1 << 20, 16, topo=(2, 8))
    assert eng.stats["misses"] == misses + 1, "folded topo hit 1D entry"
    assert d_1d.p == d_folded.p == 16
    # and the 1D entry is still served from cache
    eng.select("allreduce", 1 << 20, 16)
    assert eng.stats["misses"] == misses + 1


def test_schema_v1_cache_migrates(tmp_path):
    """A v1 (schema-less, 'op|p=..' keyed) decisions file loads into
    the v2 engine: its entries are re-keyed as 1D topology signatures
    and served as hits."""
    eng = _engine(tmp_path)
    d = eng.select("allreduce", 1 << 20, 8)
    eng.flush()
    path = str(tmp_path / "decisions.json")
    with open(path) as f:
        payload = json.load(f)
    assert payload["schema"] == SCHEMA_VERSION
    legacy = {
        "fabric": payload["fabric"],
        "decisions": {k.replace("|t=", "|p=", 1): v
                      for k, v in payload["decisions"].items()},
    }
    with open(path, "w") as f:
        json.dump(legacy, f)

    eng2 = _engine(tmp_path)
    d2 = eng2.select("allreduce", 1 << 20, 8)
    assert eng2.stats["misses"] == 0, "v1 entry was not migrated"
    assert eng2.stats["hits"] == 1
    assert d2.algorithm == d.algorithm
    assert d2.predictions == pytest.approx(d.predictions)


# ------------------------ simulator cross-check ----------------------- #
def test_planner_2d_pricing_matches_flow_simulator():
    """On the WSE2 fabric the planner's 2D candidates are exactly the
    Sec.-7 closed forms the flow simulator validates: the snake
    estimate must equal the simulator comparison's model column, and
    the flow simulation itself must land within the paper's error
    envelope."""
    from repro.simulator.runner import compare_allreduce_2d

    eng = CollectiveEngine(fabric=WSE2, persist=False)
    for m, n in ((4, 4), (8, 8)):
        for b in (64, 4096):
            nbytes = b * ICI_ELEMENT_BYTES
            plan = eng.plan_multi("allreduce", ("y", "x"), (m, n), nbytes)
            cmp = compare_allreduce_2d("snake", m, n, b, WSE2)
            assert plan.predictions["2d_snake"] == pytest.approx(
                cmp.model_cycles)
            assert cmp.rel_error < 0.35, (m, n, b, cmp)
            # xy candidate: planner takes the best pattern per
            # dimension, so it lower-bounds every uniform-pattern xy
            for pattern in ("chain", "two_phase"):
                uni = compare_allreduce_2d(pattern, m, n, b, WSE2)
                assert (plan.predictions["2d_xy"]
                        <= uni.model_cycles + 1e-6)


# ----------------------- heterogeneous topology ----------------------- #
def test_asymmetric_topology_selects_hierarchical():
    """Acceptance: with the pod link >= 4x slower than the data link,
    the joint argmin is the hierarchical composition at bandwidth-bound
    bucket sizes, and its modeled cross-pod wire bytes are strictly
    lower than the flat plan's."""
    eng = CollectiveEngine(fabric=_slow_pod_topology(4.0), persist=False)
    for sizes in ((2, 4), (2, 16), (4, 4)):
        for nbytes in (1 << 20, 4 << 20, 64 << 20):
            plan = eng.plan_multi("allreduce", ("pod", "data"), sizes,
                                  nbytes)
            assert planner.base_shape(plan.shape) == "hierarchical", (
                sizes, nbytes, plan.predictions)
            hier = plan.cost_terms["hierarchical"]["axis_bytes"]["pod"]
            flat = plan.cost_terms["flat"]["axis_bytes"]["pod"]
            seq = plan.cost_terms["sequential"]["axis_bytes"]["pod"]
            assert hier < flat, (sizes, nbytes)
            assert hier < seq, (sizes, nbytes)
            # every candidate still respects the (fast-fabric) bound
            for shape, t in plan.predictions.items():
                assert t >= plan.lower_bound - 1e-6, (sizes, nbytes,
                                                      shape)


def test_asymmetric_pricing_charges_slow_axis_more():
    """The same plan shapes get strictly more expensive when the pod
    link slows down -- and shapes that avoid cross-pod volume
    (hierarchical) rise less than shapes that ship the full vector
    across it (sequential, flat)."""
    uni = CollectiveEngine(persist=False)
    het = CollectiveEngine(fabric=_slow_pod_topology(4.0), persist=False)
    nbytes = 4 << 20
    p_uni = uni.plan_multi("allreduce", ("pod", "data"), (2, 8), nbytes)
    p_het = het.plan_multi("allreduce", ("pod", "data"), (2, 8), nbytes)
    for shape in ("sequential", "hierarchical", "flat"):
        assert p_het.predictions[shape] > p_uni.predictions[shape], shape
    rise = {s: p_het.predictions[s] / p_uni.predictions[s]
            for s in ("sequential", "hierarchical", "flat")}
    assert rise["hierarchical"] < rise["sequential"]
    assert rise["hierarchical"] < rise["flat"]


def test_uniform_topology_prices_bit_for_bit():
    """Golden values captured from the pre-FabricTopology planner: a
    uniform topology must reproduce every modeled price exactly --
    threading per-axis fabrics through the planner cannot perturb the
    single-fabric arithmetic."""
    # re-captured when the one-shot latency candidates landed: phases
    # whose argmin flipped to "oneshot" (small per-phase payloads on
    # the launch-heavy ICI fabric) price lower than the pre-latency
    # goldens, and every plan now carries a "latency" shape
    golden = {
        ((2, 16), 1 << 22): {
            "sequential": 29097.0, "flat": 26968.0,
            "hierarchical": 19441.0, "2d_xy": 61076.0,
            "2d_snake": 55555.0, "latency": 254129.0,
            "sequential_pipelined": 30369.0,
            "hierarchical_pipelined": 22577.0},
        ((2, 4), 1 << 16): {
            "sequential": 866.0, "flat": 1073.0, "hierarchical": 1150.0,
            "2d_xy": 1781.0, "2d_snake": 2289.0, "latency": 1073.0,
            "sequential_pipelined": 979.0,
            "hierarchical_pipelined": 1851.0},
        ((4, 4), 16 << 20): {
            "sequential": 100448.0, "flat": 66808.0,
            "hierarchical": 63402.0, "2d_xy": 198384.0,
            "2d_snake": 167218.0, "latency": 491697.0,
            "sequential_pipelined": 64944.0,
            "hierarchical_pipelined": 56856.0},
    }
    for wrap in (TPU_V5E_AXIS, FabricTopology.uniform(TPU_V5E_AXIS)):
        eng = CollectiveEngine(fabric=wrap, persist=False)
        for (sizes, nbytes), want in golden.items():
            plan = eng.plan_multi("allreduce", ("pod", "data"), sizes,
                                  nbytes)
            assert plan.predictions == want, (sizes, nbytes,
                                              plan.predictions)
        rs = eng.plan_multi("reduce_scatter", ("pod", "data"), (2, 4),
                            1 << 20)
        assert rs.predictions == {"cascade": 2506.0, "flat": 3044.0,
                                  "cascade_pipelined": 2914.0}
        assert rs.lower_bound == 945.0
        assert eng.select("allreduce", 1 << 20, 8).predictions == {
            "chain": 9969.0, "tree": 13350.0, "two_phase": 11479.0,
            "ring": 6088.0, "oneshot": 14513.0}
    wse = CollectiveEngine(fabric=WSE2, persist=False)
    pw = wse.plan_multi("allreduce", ("y", "x"), (4, 4), 4096 * 512)
    assert pw.predictions == {
        "sequential": 12368.0, "flat": 7888.0, "hierarchical": 7750.0,
        "2d_xy": 12335.0, "2d_snake": 8293.0, "latency": 61445.0,
        "sequential_pipelined": 7272.0,
        "hierarchical_pipelined": 6616.0}
    assert pw.lower_bound == 4101.0


def test_hetero_plans_do_not_collide_with_uniform_axis_names():
    """Same axis sizes, different axis bindings: ('pod','data') prices
    the pod axis slow, ('x','y') prices both with the default -- the
    per-axis constants are part of the plan cache key, so the two must
    not share entries (and the uniform one still rebinds freely)."""
    eng = CollectiveEngine(fabric=_slow_pod_topology(4.0), persist=False)
    nbytes = 4 << 20
    p_slow = eng.plan_multi("allreduce", ("pod", "data"), (2, 8), nbytes)
    assert eng.stats["plan_misses"] == 1
    p_fast = eng.plan_multi("allreduce", ("x", "y"), (2, 8), nbytes)
    assert eng.stats["plan_misses"] == 2, "hetero plan served for " \
                                          "uniform axis names"
    assert (p_slow.predictions["sequential"]
            > p_fast.predictions["sequential"])
    # uniform axis names rebind onto the cached uniform record
    p_fast2 = eng.plan_multi("allreduce", ("u", "v"), (2, 8), nbytes)
    assert eng.stats["plan_hits"] == 1
    assert p_fast2.predictions == p_fast.predictions


def test_no_plan_beats_lower_bound_heterogeneous():
    """The Lemma-7.2 bound instantiated with best-of-axes constants
    stays below every per-axis-priced candidate across asymmetry
    factors and ops."""
    for factor in (2.0, 4.0, 16.0):
        eng = CollectiveEngine(fabric=_slow_pod_topology(factor),
                               persist=False)
        for op in ("allreduce", "reduce_scatter", "allgather"):
            for sizes in ((2, 2), (2, 8), (4, 4)):
                for nbytes in (512, 1 << 16, 1 << 22):
                    plan = eng.plan_multi(op, ("pod", "data"), sizes,
                                          nbytes)
                    for shape, t in plan.predictions.items():
                        assert t >= plan.lower_bound - 1e-6, (
                            factor, op, sizes, nbytes, shape)


def test_parse_fabric_topology_spec_drives_planner():
    """The CLI spec form reaches the planner: 'pod=slow' prices pod
    traffic 4x slower and flips bandwidth-bound plans hierarchical."""
    topo = parse_fabric_topology("pod=slow,data=fast")
    assert topo.for_axis("data") == TPU_V5E_AXIS
    pod = topo.for_axis("pod")
    assert pod.link_bw == pytest.approx(TPU_V5E_AXIS.link_bw / 4)
    assert pod.t_r == pytest.approx(TPU_V5E_AXIS.t_r * 4)
    eng = CollectiveEngine(fabric=topo, persist=False)
    plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 16), 4 << 20)
    assert planner.base_shape(plan.shape) == "hierarchical"


def test_lower_bound_multi_folding():
    b = 4096 * ICI_ELEMENT_BYTES
    lb_22 = planner.lower_bound_multi("allreduce", (2, 2), b,
                                      TPU_V5E_AXIS, ICI_ELEMENT_BYTES)
    lb_44 = planner.lower_bound_multi("allreduce", (4, 4), b,
                                      TPU_V5E_AXIS, ICI_ELEMENT_BYTES)
    assert lb_44 >= lb_22 > 0
    assert planner.lower_bound_multi("allreduce", (1, 1), b,
                                     TPU_V5E_AXIS,
                                     ICI_ELEMENT_BYTES) == 0.0
