"""Observability layer: metrics registry, span tracing, model-error
monitoring, telemetry export.

Fast tier: registry semantics (kinds, labels, collectors, exporters),
engine stats snapshot/export, Chrome-trace round-trip (ordering,
nesting, args preserved), model-error drift firing at/below threshold,
TTFT sample counting and low-confidence marking, and the
``obs_report.py --check`` schema gate.  Multidev tier
(``test_obs_multidev.py``): traced engine collectives on 8 virtual
devices with measured replay.
"""

import json
import os
import subprocess
import sys
import threading

import pytest

from repro.collectives.engine import CollectiveEngine
from repro.obs import trace as obs_trace
from repro.obs.model_error import (DEFAULT_THRESHOLD, ModelErrorMonitor,
                                   bytes_decile)
from repro.obs.registry import (EXPORT_SCHEMA, MetricsRegistry,
                                export_engine_stats, validate_export)
from repro.serving.telemetry import (TTFT_LOW_CONFIDENCE, Telemetry,
                                     export_to_registry,
                                     ttft_low_confidence)


# ------------------------------ registry ------------------------------ #
def test_counter_monotonic():
    reg = MetricsRegistry()
    c = reg.counter("requests")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    # get-or-create returns the same object
    assert reg.counter("requests") is c


def test_gauge_and_labels_key_separately():
    reg = MetricsRegistry()
    reg.gauge("occupancy", labels={"pool": "kv"}).set(0.5)
    reg.gauge("occupancy", labels={"pool": "host"}).set(0.9)
    snap = reg.snapshot()
    assert snap["gauges"]['occupancy{pool="kv"}'] == 0.5
    assert snap["gauges"]['occupancy{pool="host"}'] == 0.9


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("n")
    with pytest.raises(TypeError):
        reg.gauge("n")


def test_histogram_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in range(1, 101):
        h.observe(float(v))
    exp = h.export()
    assert exp["count"] == 100 and exp["sum"] == 5050
    assert 49 <= exp["p50"] <= 52
    assert exp["min"] == 1.0 and exp["max"] == 100.0


def test_collector_runs_at_export():
    reg = MetricsRegistry()
    calls = []

    def collect(r):
        calls.append(1)
        r.gauge("fresh").set(len(calls))

    reg.register_collector("src", collect)
    assert reg.snapshot()["gauges"]["fresh"] == 1
    assert reg.snapshot()["gauges"]["fresh"] == 2
    # same key replaces, not stacks
    reg.register_collector("src", lambda r: r.gauge("fresh").set(-1))
    assert reg.snapshot()["gauges"]["fresh"] == -1


def test_export_json_schema_and_validation():
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.histogram("h").observe(1.0)
    blob = reg.export_json()
    assert blob["schema"] == EXPORT_SCHEMA
    assert validate_export(blob) == []
    # round-trips through JSON text
    assert validate_export(json.loads(reg.export_json_str())) == []
    # broken blobs produce problems, not exceptions
    assert validate_export({"schema": "nope"})
    assert validate_export([1, 2])
    bad = reg.export_json()
    bad["counters"]["a"] = "NaN-ish"
    assert any("not numeric" in p for p in validate_export(bad))


def test_export_prometheus_format():
    reg = MetricsRegistry()
    reg.counter("reqs", labels={"code": "200"}, help="requests").inc(3)
    reg.histogram("lat").observe(2.0)
    text = reg.export_prometheus()
    assert "# HELP reqs requests" in text
    assert "# TYPE reqs counter" in text
    assert 'reqs{code="200"} 3' in text
    assert "lat_count 1" in text and "lat_sum 2" in text
    assert 'lat{quantile="0.50"} 2' in text


def test_export_prometheus_label_escaping_round_trips():
    """Label values containing backslash, double quote, and newline
    must escape per the exposition format -- and unescape back to the
    original value (tenant ids are label values under the serving
    fleet, and they are client-controlled strings)."""
    import re
    hostile = 'a"b\\c\nd'
    reg = MetricsRegistry()
    reg.gauge("fleet_rejected_by_tenant",
              labels={"tenant": hostile}).set(2)
    text = reg.export_prometheus()
    # a raw newline inside the label value would split the sample line
    # in two, so exactly one parseable line proves the escaping
    (line,) = [ln for ln in text.splitlines()
               if ln.startswith("fleet_rejected_by_tenant{")]
    m = re.fullmatch(r'fleet_rejected_by_tenant\{tenant="((?:[^"\\]|'
                     r'\\.)*)"\} 2', line)
    assert m, f"label pair not parseable: {line!r}"
    unescaped = re.sub(r"\\(.)",
                       lambda e: "\n" if e.group(1) == "n" else e.group(1),
                       m.group(1))
    assert unescaped == hostile


def test_registry_snapshot_is_atomic_copy():
    reg = MetricsRegistry()
    g = reg.gauge("x")
    g.set(1)
    snap = reg.snapshot()
    g.set(2)
    assert snap["gauges"]["x"] == 1


# ------------------------------ engine stats -------------------------- #
def _engine(tmp_path):
    return CollectiveEngine(cache_path=str(tmp_path / "decisions.json"))


def test_engine_stats_snapshot_counters(tmp_path):
    eng = _engine(tmp_path)
    s0 = eng.stats_snapshot()
    assert s0 == {"hits": 0, "misses": 0, "dp_runs": 0,
                  "persisted_loads": 0, "plan_hits": 0,
                  "plan_misses": 0, "latency_dispatches": 0}
    eng.select("allreduce", 1 << 20, 8)
    eng.select("allreduce", 1 << 20, 8)
    s_sel = eng.stats_snapshot()
    assert s_sel["misses"] == 1 and s_sel["hits"] == 1
    # planning scores candidates through select(), so only the plan
    # counters are exact here
    eng.plan_multi("allreduce", ("pod", "data"), (2, 4), 1 << 16)
    eng.plan_multi("allreduce", ("pod", "data"), (2, 4), 1 << 16)
    s1 = eng.stats_snapshot()
    assert s1["plan_misses"] == 1 and s1["plan_hits"] == 1
    # the snapshot is a copy: mutating it does not touch the engine
    s1["hits"] = 999
    assert eng.stats_snapshot()["hits"] != 999
    # select() still returns bare Decisions and _select_meta the hit bit
    d, hit = eng._select_meta("allreduce", 1 << 20, 8)
    assert hit and d.algorithm == eng.select("allreduce", 1 << 20, 8
                                             ).algorithm


def test_export_engine_stats_gauges(tmp_path):
    eng = _engine(tmp_path)
    eng.select("allreduce", 1 << 20, 8)
    reg = MetricsRegistry()
    export_engine_stats(eng, reg)
    gauges = reg.snapshot()["gauges"]
    key = [k for k in gauges if k.startswith("engine_misses")]
    assert key and gauges[key[0]] == 1
    assert any(k.startswith("engine_hits") for k in gauges)


def test_select_meta_hit_bit(tmp_path):
    eng = _engine(tmp_path)
    _, hit1 = eng._select_meta("allgather", 1 << 18, 4)
    _, hit2 = eng._select_meta("allgather", 1 << 18, 4)
    assert (hit1, hit2) == (False, True)
    d, hit = eng._select_meta("allreduce", 123, 1)
    assert not hit and d.algorithm == "identity"


# ------------------------------ trace round-trip ---------------------- #
def _fresh_tracer(**kw):
    return obs_trace.Tracer(enabled=True, **kw)


def test_trace_chrome_roundtrip(tmp_path):
    tracer = _fresh_tracer()
    prev = obs_trace.set_tracer(tracer)
    try:
        with tracer.span("allreduce_multi", op="allreduce",
                         axes=("pod", "data"), bytes=4096,
                         plan="hierarchical(rs:ring->ar:ring->ag:ring)",
                         cache="miss", predicted=123.0,
                         measured_s=0.0015, mode="eager") as root:
            with tracer.span("rs:ring@data", cat=obs_trace.CAT_PHASE,
                             op="allreduce", phase=0):
                pass
            with tracer.span("ar:ring@pod", cat=obs_trace.CAT_PHASE,
                             op="allreduce", phase=1):
                pass
            root.set(n_chunks=2)
    finally:
        obs_trace.set_tracer(prev)
    path = str(tmp_path / "trace.json")
    assert tracer.export_chrome(path) == 3

    loaded = obs_trace.load_chrome_trace(path)
    orig = tracer.spans
    assert [s.name for s in loaded] == [s.name for s in orig]
    assert [s.span_id for s in loaded] == [s.span_id for s in orig]
    by_id = {s.span_id: s for s in loaded}
    # nesting survives: both phases hang off the collective span
    root_l = [s for s in loaded if s.cat == obs_trace.CAT_COLLECTIVE][0]
    phases = [s for s in loaded if s.cat == obs_trace.CAT_PHASE]
    assert len(phases) == 2
    assert all(p.parent_id == root_l.span_id for p in phases)
    assert root_l.parent_id is None
    # args round-trip, including the late .set()
    assert root_l.args["plan"].startswith("hierarchical")
    assert root_l.predicted == 123.0
    assert root_l.measured_s == 0.0015
    assert root_l.args["n_chunks"] == 2
    assert by_id[phases[0].span_id].args["phase"] == 0
    # file metadata carries the schema tag
    with open(path) as f:
        payload = json.load(f)
    assert payload["metadata"]["schema"] == obs_trace.TRACE_SCHEMA


def test_tracer_disabled_is_noop_and_max_spans_drops():
    tracer = obs_trace.Tracer(enabled=False)
    sp = tracer.span("x")
    assert sp is obs_trace.NULL_SPAN
    with sp:
        sp.set(a=1)
        sp.finish_result(None)
    assert tracer.spans == []

    tracer = _fresh_tracer(max_spans=1)
    with tracer.span("kept"):
        pass
    with tracer.span("dropped"):
        pass
    assert [s.name for s in tracer.spans] == ["kept"]
    assert tracer.dropped == 1


def test_finish_result_measure_blocks_eager_only():
    import jax.numpy as jnp
    tracer = obs_trace.Tracer(enabled=True, measure=True)
    with tracer.span("coll", op="allreduce") as sp:
        sp.finish_result(jnp.zeros((4,)))
    (span,) = tracer.spans
    assert span.args["mode"] == "eager"
    assert span.args["measured_s"] == span.dur > 0

    # phase spans opt out of blocking regardless of measure mode
    tracer2 = obs_trace.Tracer(enabled=True, measure=True)
    with tracer2.span("phase", cat=obs_trace.CAT_PHASE) as sp:
        sp.finish_result(jnp.zeros((4,)), block=False)
    (span2,) = tracer2.spans
    assert span2.args["measured_s"] is None

    # measure=False never blocks: measured_s stays null
    tracer3 = obs_trace.Tracer(enabled=True, measure=False)
    with tracer3.span("coll", op="allreduce") as sp:
        sp.finish_result(jnp.zeros((4,)))
    (span3,) = tracer3.spans
    assert span3.args["measured_s"] is None
    assert span3.args["mode"] == "eager"


def test_span_stack_is_thread_local():
    tracer = _fresh_tracer()
    seen = {}

    def worker():
        with tracer.span("child_b") as sp:
            seen["parent_b"] = sp.span.parent_id

    with tracer.span("root_a") as root:
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        with tracer.span("child_a") as sp:
            seen["parent_a"] = sp.span.parent_id
    assert seen["parent_a"] == root.span.span_id
    assert seen["parent_b"] is None


def test_validate_spans_contract():
    tracer = _fresh_tracer()
    with tracer.span("good", op="allreduce", axes=("d",), bytes=8,
                     plan=None, cache="hit", predicted=1.0,
                     measured_s=None, mode="traced"):
        pass
    assert obs_trace.validate_spans(tracer.spans) == []
    # missing keys flagged
    tracer2 = _fresh_tracer()
    with tracer2.span("bad", op="allreduce"):
        pass
    problems = obs_trace.validate_spans(tracer2.spans)
    assert problems and "missing" in problems[0]
    # null prediction only allowed when forced
    tracer3 = _fresh_tracer()
    with tracer3.span("forced", op="allreduce", axes=("d",), bytes=8,
                      plan=None, cache="forced", predicted=None,
                      measured_s=None, mode="traced",
                      algorithm_forced=True):
        pass
    assert obs_trace.validate_spans(tracer3.spans) == []
    assert obs_trace.validate_spans([]) == ["no collective spans in trace"]


# ------------------------------ model error --------------------------- #
def test_bytes_decile_bins():
    assert bytes_decile(1) == 0
    assert bytes_decile(999) == 2
    assert bytes_decile(1 << 20) == 6


def _feed(mon, err, n=32, predicted=1000.0, scale=1e-6):
    """Anchor a bin at ``scale`` seconds/cycle, then feed ``n`` samples
    measuring ``err`` relative error against the anchor."""
    for _ in range(mon.min_samples):
        mon.observe("allreduce", "2x4", 1 << 20, predicted,
                    predicted * scale)
    for _ in range(n):
        mon.observe("allreduce", "2x4", 1 << 20, predicted,
                    predicted * scale * (1.0 + err))


def test_drift_fires_above_threshold_only():
    quiet = ModelErrorMonitor(threshold=DEFAULT_THRESHOLD, min_samples=4)
    _feed(quiet, err=0.02)
    assert not quiet.should_recalibrate
    assert quiet.recommendation() is None
    assert all(not b.drifted for b in quiet.bins.values())

    drifted = ModelErrorMonitor(threshold=DEFAULT_THRESHOLD,
                                min_samples=4)
    _feed(drifted, err=0.10)
    assert drifted.should_recalibrate
    assert len(drifted.drifted_bins()) == 1
    rec = drifted.recommendation()
    assert "calibrate" in rec
    assert "DRIFT" in drifted.render_table()
    assert "!!" in drifted.render_table()


def test_drift_needs_min_scored_samples():
    mon = ModelErrorMonitor(min_samples=8)
    # anchor (8) + 3 scored samples of huge error: not enough to flag
    for _ in range(8):
        mon.observe("allgather", "8", 1 << 16, 100.0, 100e-6)
    for _ in range(3):
        mon.observe("allgather", "8", 1 << 16, 100.0, 200e-6)
    assert not mon.should_recalibrate


def test_explicit_seconds_per_cycle_skips_anchoring():
    mon = ModelErrorMonitor(min_samples=2, seconds_per_cycle=1e-6)
    for _ in range(4):
        mon.observe("allreduce", "4", 1 << 12, 500.0, 500e-6 * 1.2)
    assert mon.should_recalibrate


def test_monitor_observe_spans_filters():
    mon = ModelErrorMonitor(min_samples=2)
    tracer = _fresh_tracer()
    with tracer.span("ar", op="allreduce", axes=("d",), axis_sizes=(8,),
                     bytes=1 << 16, predicted=100.0, measured_s=1e-4):
        pass
    with tracer.span("no_measure", op="allreduce", axes=("d",),
                     bytes=1 << 16, predicted=100.0, measured_s=None):
        pass
    with tracer.span("phase", cat=obs_trace.CAT_PHASE, op="allreduce"):
        pass
    fed = mon.observe_spans(tracer.spans)
    assert fed == 1 and mon.skipped == 1
    assert list(mon.bins) == [("allreduce", "8", bytes_decile(1 << 16))]
    blob = mon.report()
    assert blob["observed"] == 1 and blob["bins"][0]["op"] == "allreduce"


# ------------------------------ telemetry ----------------------------- #
class _StubAllocator:
    capacity = 10
    num_used = 3
    num_evictable = 0
    occupancy = 0.3
    evictions = 0

    @staticmethod
    def internal_fragmentation(block_usage):
        return 0


def _snap_with_ttfts(n):
    tel = Telemetry(clock=iter(range(1000)).__next__)
    for _ in range(n):
        tel.record_first_token(0.0)
    return tel.snapshot(queue_depth=0, active=0,
                        allocator=_StubAllocator, block_usage=[])


def test_ttft_samples_and_low_confidence():
    snap = _snap_with_ttfts(4)
    assert snap.ttft_samples == 4
    assert ttft_low_confidence(snap)
    snap = _snap_with_ttfts(TTFT_LOW_CONFIDENCE)
    assert snap.ttft_samples == TTFT_LOW_CONFIDENCE
    assert not ttft_low_confidence(snap)
    assert _snap_with_ttfts(0).ttft_samples == 0


def test_export_to_registry_marks_confidence():
    snap = _snap_with_ttfts(3)
    reg = MetricsRegistry()
    export_to_registry(snap, reg)
    gauges = reg.snapshot()["gauges"]
    assert gauges["serve_ttft_samples"] == 3
    assert gauges["serve_ttft_low_confidence"] == 1
    assert "serve_ttft_p50_ms" in gauges
    assert validate_export(reg.export_json()) == []

    snap = _snap_with_ttfts(TTFT_LOW_CONFIDENCE + 1)
    reg2 = MetricsRegistry()
    export_to_registry(snap, reg2)
    assert reg2.snapshot()["gauges"]["serve_ttft_low_confidence"] == 0


def test_export_to_registry_skips_null_percentiles():
    snap = _snap_with_ttfts(0)
    reg = MetricsRegistry()
    export_to_registry(snap, reg)
    gauges = reg.snapshot()["gauges"]
    assert "serve_ttft_p50_ms" not in gauges
    assert gauges["serve_ttft_samples"] == 0


# ------------------------------ obs_report CLI ------------------------ #
_REPORT = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "obs_report.py")


def _run_report(args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run([sys.executable, _REPORT, *args], env=env,
                          capture_output=True, text=True, timeout=240)


def _write_trace(path, spans_args):
    events = []
    for i, args in enumerate(spans_args):
        args = dict(args)
        args.setdefault("span_id", i)
        args.setdefault("parent_id", None)
        events.append({"name": f"s{i}", "cat": "collective", "ph": "X",
                       "ts": i * 10.0, "dur": 5.0, "pid": 1, "tid": 0,
                       "args": args})
    with open(path, "w") as f:
        json.dump({"traceEvents": events,
                   "metadata": {"schema": obs_trace.TRACE_SCHEMA}}, f)


@pytest.mark.slow
def test_obs_report_check_gate(tmp_path):
    good = str(tmp_path / "good.json")
    _write_trace(good, [{"op": "allreduce", "axes": ["d"], "bytes": 64,
                         "plan": "flat(ar:ring)", "cache": "hit",
                         "predicted": 10.0, "measured_s": 1e-5,
                         "mode": "eager"}])
    proc = _run_report([good, "--check"])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "conform" in proc.stdout

    bad = str(tmp_path / "bad.json")
    _write_trace(bad, [{"op": "allreduce"}])
    proc = _run_report([bad, "--check"])
    assert proc.returncode == 1
    assert "missing" in proc.stderr

    # report mode renders the table from the same trace
    proc = _run_report([good])
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "allreduce" in proc.stdout
