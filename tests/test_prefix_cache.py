"""Content-addressed prefix cache: hash-chain keys, refcounted
allocator with the evictable tier, scheduler-level sharing invariants
(host-side simulated pool vs a no-sharing oracle), and server-level
bitwise-identity of greedy streams with the cache on vs off.

Model-level paged-cache numerics live in tests/test_paged_attention.py;
the non-cache serving paths in tests/test_serving.py.
"""

from collections import Counter, deque

import numpy as np
import jax
import pytest

from repro.configs.base import ArchConfig
from repro.models import init_params
from repro.serving import (BlockAllocator, ContinuousBatchingServer,
                           PrefixCache, Request, Scheduler, chain_keys)
from repro.serving.blocks import RESERVED_BLOCKS
from repro.serving.scheduler import RUNNING

VOCAB = 64
TINY = ArchConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=VOCAB,
                  dtype="float32")


# ----------------------------- chain keys ----------------------------- #
def test_chain_keys_basic():
    toks = np.arange(10, dtype=np.int32)
    keys = chain_keys(toks, 4)
    assert len(keys) == 2, "only full blocks get keys"
    assert len(set(keys)) == 2
    assert chain_keys(toks[:3], 4) == []
    assert chain_keys([], 4) == []
    # deterministic across calls and input container types
    assert chain_keys(list(map(int, toks)), 4) == keys


def test_chain_keys_shared_prefix_shares_keys():
    a = np.arange(12, dtype=np.int32)
    b = np.concatenate([a[:8], a[8:] + 1])
    ka, kb = chain_keys(a, 4), chain_keys(b, 4)
    assert ka[:2] == kb[:2], "identical prefix -> identical keys"
    assert ka[2] != kb[2]


def test_chain_keys_commit_to_entire_prefix():
    # same tokens in block 1, different block 0: the chain must give
    # block 1 different keys (a key addresses the whole prefix)
    a = np.arange(8, dtype=np.int32)
    b = a.copy()
    b[0] += 1
    ka, kb = chain_keys(a, 4), chain_keys(b, 4)
    assert ka[0] != kb[0] and ka[1] != kb[1]
    # block size is part of the addressing (different chunking of the
    # same stream must not collide)
    assert chain_keys(a, 4)[-1] != chain_keys(a, 8)[-1]


# ----------------------- allocator refcounting ------------------------ #
def test_allocator_refcount_lifecycle():
    a = BlockAllocator(num_blocks=6, block_size=4)
    blk = a.alloc(1)[0]
    assert a.refcount(blk) == 1
    a.ref(blk)
    assert a.refcount(blk) == 2
    a.decref(blk)
    assert a.refcount(blk) == 1 and a.num_used == 1
    a.decref(blk)            # uncached: straight back to the free list
    assert a.refcount(blk) == 0
    assert (a.num_used, a.num_evictable, a.num_free) == (0, 0, 5)
    with pytest.raises(ValueError):
        a.decref(blk)        # double free
    with pytest.raises(ValueError):
        a.ref(99)            # foreign block


def test_allocator_evictable_park_revive_and_lru_order():
    a = BlockAllocator(num_blocks=6, block_size=4)   # capacity 5
    b0, b1, b2 = a.alloc(3)
    a.register_cached(b0, b"k0")
    a.register_cached(b1, b"k1")
    a.decref(b0)
    a.decref(b1)
    a.decref(b2)
    # cached blocks park evictable (content retained), plain one frees
    assert (a.num_used, a.num_evictable, a.num_free) == (0, 2, 3)
    assert a.num_available == 5
    # revive keeps the content claim and the cached flag
    a.ref(b0)
    assert a.refcount(b0) == 1 and a.num_evictable == 1
    a.decref(b0)             # re-parks at the MRU end: LRU order b1, b0
    evicted = []
    a.evict_hook = lambda blk, key: evicted.append((blk, key))
    got = a.alloc(5)         # 3 free first, then reclaim LRU-first
    assert got is not None and len(got) == 5
    assert evicted == [(b1, b"k1"), (b0, b"k0")]
    assert a.evictions == 2
    assert a.num_evictable == 0 and not a.is_cached(b0)


def test_allocator_all_or_nothing_spans_evictable():
    a = BlockAllocator(num_blocks=6, block_size=4)
    blks = a.alloc(2)
    for b in blks:
        a.register_cached(b, bytes([b]))
        a.decref(b)
    assert (a.num_free, a.num_evictable) == (3, 2)
    assert a.alloc(6) is None, "over-ask must not evict anything"
    assert a.num_evictable == 2 and a.evictions == 0
    assert len(a.alloc(5)) == 5


def test_register_cached_requires_live_block():
    a = BlockAllocator(num_blocks=4, block_size=4)
    with pytest.raises(ValueError):
        a.register_cached(2, b"k")


# ---------------------------- prefix cache ---------------------------- #
def test_prefix_cache_insert_match_first_writer_wins():
    a = BlockAllocator(num_blocks=8, block_size=4)
    pc = PrefixCache(a)
    keys = chain_keys(np.arange(8, dtype=np.int32), 4)
    blocks = a.alloc(2)
    assert pc.insert(keys[0], blocks[0])
    assert pc.insert(keys[1], blocks[1])
    assert len(pc) == 2 and pc.inserts == 2
    dup = a.alloc(1)[0]
    assert not pc.insert(keys[0], dup), "first writer wins"
    assert a.cached_key(dup) is None
    m = pc.match(keys)
    assert m == blocks
    assert [a.refcount(b) for b in blocks] == [2, 2]
    assert pc.hits == 2
    # a miss ends the walk without touching later keys
    assert pc.match([b"absent", keys[0]]) == []
    assert pc.misses >= 1


def test_prefix_cache_eviction_drops_mapping_and_orphans_chain():
    a = BlockAllocator(num_blocks=4, block_size=4)   # capacity 3
    pc = PrefixCache(a)
    keys = chain_keys(np.arange(8, dtype=np.int32), 4)
    b0, b1 = a.alloc(2)
    pc.insert(keys[0], b0)
    pc.insert(keys[1], b1)
    a.decref(b0)
    a.decref(b1)             # LRU order: b0 then b1
    assert a.num_evictable == 2 and a.num_free == 1
    got = a.alloc(2)         # free block + LRU-evict b0
    assert b0 in got and a.evictions == 1
    assert len(pc) == 1, "evict hook must drop the mapping"
    # b1's key survives but the chain walk stops at the evicted link:
    # descendants are orphaned, not wrongly matched
    assert pc.match(keys) == []
    # revived by a later match? no -- orphan ages out under pressure
    for blk in got:
        a.decref(blk)
    assert a.alloc(3) is not None
    assert len(pc) == 0


def test_internal_fragmentation_counts_shared_blocks_once():
    a = BlockAllocator(num_blocks=16, block_size=8)
    # two tables sharing physical block 3 for their first 8 tokens;
    # fills 13 and 10 -> private tails waste (8-5) + (8-2), the shared
    # block wastes 0, counted once
    usage = [([3, 4], 13), ([3, 5], 10)]
    assert a.internal_fragmentation(usage) == 3 + 6
    # deepest fill wins for the shared block: 6 vs 3 tokens -> waste 2
    usage = [([3], 6), ([3], 3)]
    assert a.internal_fragmentation(usage) == 2
    # legacy int form still supported, mixed
    assert a.internal_fragmentation([5, ([3], 6)]) == 3 + 2


# ------------------- scheduler-level sharing driver ------------------- #
def _sim_token(rid, n_out):
    """Deterministic stand-in for sampling: a pure function of
    (request, position), like the server's per-(rid, position) keys --
    so recompute-style replay regenerates identical tokens."""
    return (rid * 7919 + n_out * 31 + 5) % VOCAB


def _read_through_table(pool, req, bs):
    return np.asarray([pool[req.table.blocks[p // bs], p % bs]
                       for p in range(req.ctx_len)])


def _check_invariants(sched, pool):
    a = sched.allocator
    # conservation: every allocatable block is in exactly one state
    assert a.num_used + a.num_free + a.num_evictable == a.capacity
    assert not (set(a._evictable) & a._used), "evictable ∩ used"
    assert not (set(a._evictable) & set(a._free)), "evictable ∩ free"
    assert not (a._used & set(a._free)), "used ∩ free"
    # refcounts == table multiplicity (no leaks, no phantom refs)
    refs = Counter()
    for _, req in sched.active():
        for blk in req.table.blocks:
            refs[blk] += 1
    assert dict(refs) == {b: a.refcount(b) for b in a._used}
    # content: each request reads its own token stream through its
    # table -- shared, copied-on-write, and revived blocks included
    for _, req in sched.active():
        full = req.replay_tokens
        got = _read_through_table(pool, req, a.block_size)
        np.testing.assert_array_equal(got, full[:req.ctx_len])


def _drive(sched, trace, max_steps=3000):
    """Mimic ContinuousBatchingServer.run() against a host-side token
    pool (pool[block, slot] = token written there): prefill chunks and
    decode steps write tokens instead of KV, copy-on-write copies rows.
    Returns ({rid: tokens}, {rid: final through-table read}, stats)."""
    bs = sched.allocator.block_size
    pool = np.full((sched.allocator.num_blocks, bs), -1, np.int64)
    pending = deque(trace)
    results, final_read = {}, {}
    stats = {"cow": 0, "preempt": 0}
    step = 0

    def append(req, tok):
        req.out.append(int(tok))
        if len(req.out) >= req.max_new_tokens:
            req.done = True

    while pending or sched.has_work():
        assert step < max_steps, "driver did not converge"
        while pending and pending[0][0] <= step:
            _, req = pending.popleft()
            sched.submit(req, now=float(step))
        for _, req in sched.active():
            if req.done:
                final_read[req.rid] = _read_through_table(pool, req, bs)
        for req in sched.retire_finished():
            results[req.rid] = list(req.out)
        sched.admit(step)
        cows = sched.drain_cow_copies()
        for src, dst in cows:
            pool[dst] = pool[src].copy()
        stats["cow"] += len(cows)
        if not sched.active():
            assert not sched.queue, "stalled: queued request unadmittable"
            step += 1
            continue
        for chunk in sched.prefill_plan():
            req, replay = chunk.req, chunk.req.replay_tokens
            for p in range(chunk.start, chunk.start + chunk.length):
                pool[req.table.blocks[p // bs], p % bs] = replay[p]
            req.prefilled += chunk.length
            req.ctx_len += chunk.length
            sched.note_prefilled(req)
            if req.prefilled == len(replay):
                req.state = RUNNING
                append(req, _sim_token(req.rid, len(req.out)))
        if sched.any_running():
            stats["preempt"] += len(sched.grow_for_decode())
            for _, req in sched.running():
                pool[req.table.blocks[req.ctx_len // bs],
                     req.ctx_len % bs] = req.out[-1]
                req.ctx_len += 1
                append(req, _sim_token(req.rid, len(req.out)))
        _check_invariants(sched, pool)
        step += 1
    return results, final_read, stats


def _mk_sched(batch, capacity, bs, max_blocks, chunk, cache=True):
    alloc = BlockAllocator(capacity + RESERVED_BLOCKS, bs)
    pc = PrefixCache(alloc) if cache else None
    return Scheduler(batch, alloc, max_blocks, chunk, prefix_cache=pc)


def _mk_trace(specs):
    """specs: (submit_step, rid, prompt tokens, max_new)."""
    return [(step, Request(rid=rid,
                           prompt=np.asarray(prompt, np.int32),
                           max_new_tokens=max_new))
            for step, rid, prompt, max_new in specs]


def _trace_vs_oracle(mk_trace, *, batch, capacity, bs, max_blocks,
                     chunk):
    """Run a trace with sharing on, then the no-sharing oracle, and
    require identical token streams and bytes-identical final
    through-table reads."""
    sched = _mk_sched(batch, capacity, bs, max_blocks, chunk, cache=True)
    res, reads, stats = _drive(sched, mk_trace())
    oracle = _mk_sched(batch, capacity, bs, max_blocks, chunk,
                       cache=False)
    o_res, o_reads, _ = _drive(oracle, mk_trace())
    assert res == o_res
    assert reads.keys() == o_reads.keys()
    for rid in reads:
        np.testing.assert_array_equal(reads[rid], o_reads[rid])
    return sched, stats


def test_scheduler_sharing_seeded_traffic():
    bs = 4
    rng = np.random.default_rng(0)
    base_a = rng.integers(0, VOCAB, 2 * bs)      # 2 full shared blocks
    base_b = rng.integers(0, VOCAB, 2 * bs)

    def suffix(n, seed):
        return np.random.default_rng(seed).integers(0, VOCAB, n)

    def trace():
        specs = [
            # tenant A seeds the cache, later A requests share it
            (0, 0, np.concatenate([base_a, suffix(3, 1)]), 5),
            (1, 1, np.concatenate([base_a, suffix(2, 2)]), 4),
            (2, 2, base_a.copy(), 4),             # full hit -> CoW
            # tenant B's decode growth exhausts the free list while
            # A's cached blocks sit evictable -> LRU eviction
            (3, 3, np.concatenate([base_b, suffix(3, 3)]), 7),
            (4, 4, base_b.copy(), 3),             # full hit again
        ]
        return _mk_trace(specs)

    max_total = max(len(req.prompt) + req.max_new_tokens
                    for _, req in trace())
    max_blocks = -(-max_total // bs)
    # tight pool: real LRU eviction pressure, still >= one request
    capacity = max_blocks + 1
    sched, stats = _trace_vs_oracle(
        trace, batch=1, capacity=capacity, bs=bs, max_blocks=max_blocks,
        chunk=2 * bs)
    # the trace must actually exercise the machinery it claims to
    assert sched.prefix_cache.hits > 0
    assert stats["cow"] >= 1, "full-hit admissions must copy-on-write"
    assert sched.allocator.evictions > 0, "tight pool must evict"


def test_scheduler_sharing_preemption_traffic():
    bs = 4
    rng = np.random.default_rng(7)
    base = rng.integers(0, VOCAB, bs)

    def trace():
        specs = [(0, rid,
                  np.concatenate([base,
                                  np.random.default_rng(20 + rid)
                                  .integers(0, VOCAB, 3)]),
                  8) for rid in range(4)]
        return _mk_trace(specs)

    max_blocks = -(-(bs + 3 + 8) // bs)
    sched, stats = _trace_vs_oracle(
        trace, batch=3, capacity=max_blocks + 1, bs=bs,
        max_blocks=max_blocks, chunk=bs)
    assert stats["preempt"] > 0, \
        "tight pool + concurrent decode must preempt"
    assert sched.prefix_cache.hits > 0


try:        # optional dev dep; see requirements-dev.txt
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False
    given = settings = lambda *a, **k: (lambda f: f)

    class st:       # placeholder so decorator args still evaluate
        @staticmethod
        def data():
            return None


@pytest.mark.skipif(not _HAVE_HYPOTHESIS, reason="hypothesis not "
                    "installed (optional dev dep)")
@given(st.data())
@settings(max_examples=25, deadline=None)
def test_scheduler_sharing_random_traffic(data):
    """Random admit/extend/CoW/preempt/evict sequences: refcount
    invariants hold after every step (checked inside the driver) and
    the shared-pool run is bytes-identical to the no-sharing oracle."""
    bs = data.draw(st.sampled_from([2, 4]), label="block_size")
    n_base = data.draw(st.integers(1, 3), label="n_base_prompts")
    n_reqs = data.draw(st.integers(2, 8), label="n_requests")
    seed = data.draw(st.integers(0, 1 << 16), label="rng_seed")
    rng = np.random.default_rng(seed)
    bases = [rng.integers(0, VOCAB,
                          bs * data.draw(st.integers(1, 3),
                                         label=f"base_blocks_{i}"))
             for i in range(n_base)]
    specs = []
    for rid in range(n_reqs):
        base = bases[data.draw(st.integers(0, n_base - 1),
                               label=f"tenant_{rid}")]
        # suffix 0 on a repeated base prompt is the full-hit CoW path
        sfx = data.draw(st.integers(0, 2 * bs), label=f"suffix_{rid}")
        prompt = np.concatenate([base, rng.integers(0, VOCAB, sfx)])
        max_new = data.draw(st.integers(1, 2 * bs),
                            label=f"max_new_{rid}")
        step = data.draw(st.integers(0, 6), label=f"submit_{rid}")
        specs.append((step, rid, prompt, max_new))
    specs.sort(key=lambda s: (s[0], s[1]))
    max_blocks = max(-(-(len(p) + mn) // bs) for _, _, p, mn in specs)
    # capacity >= blocks_for(prompt + max_new) guarantees no stall
    # (see scheduler admission analysis); the slack dial sets how much
    # eviction/preemption pressure the run sees
    capacity = max_blocks + data.draw(st.integers(0, 4), label="slack")
    batch = data.draw(st.integers(1, 3), label="batch")
    chunk = bs * data.draw(st.integers(1, 2), label="chunk_blocks")
    _trace_vs_oracle(lambda: _mk_trace(specs), batch=batch,
                     capacity=capacity, bs=bs, max_blocks=max_blocks,
                     chunk=chunk)


# --------------------------- server (jitted) -------------------------- #
@pytest.fixture(scope="module")
def tiny_params():
    return init_params(jax.random.PRNGKey(0), TINY)


def _server(params, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_chunk", 8)
    return ContinuousBatchingServer(TINY, params, **kw)


_SHARED = np.random.default_rng(42).integers(0, VOCAB, 8).astype(np.int32)


def _shared_req(rid, suffix_len=3, max_new=4):
    rng = np.random.default_rng(1000 + rid)
    prompt = np.concatenate(
        [_SHARED, rng.integers(0, VOCAB, suffix_len)]).astype(np.int32)
    return Request(rid=rid, prompt=prompt, max_new_tokens=max_new)


def test_server_greedy_streams_identical_cache_on_off(tiny_params):
    def serve(on):
        server = _server(tiny_params, prefix_cache=on)
        for rid in range(6):
            server.submit(_shared_req(rid))
        return server.run(), server

    res_on, s_on = serve(True)
    res_off, s_off = serve(False)
    assert res_on == res_off, \
        "prefix cache changed greedy token streams"
    snap_on, snap_off = s_on.snapshot(), s_off.snapshot()
    assert snap_on.cached_prefix_tokens > 0
    assert snap_on.prefill_tokens_computed < \
        snap_off.prefill_tokens_computed
    assert snap_on.cached_token_fraction > 0
    assert snap_off.cached_prefix_tokens == 0
    assert snap_off.cached_token_fraction == 0.0


def test_server_full_hit_recomputes_final_token_cow(tiny_params):
    prompt = _SHARED.copy()              # exactly 2 full blocks
    on = _server(tiny_params, prefix_cache=True)
    on.submit(Request(rid=0, prompt=prompt.copy(), max_new_tokens=4))
    first = on.run()
    on.submit(Request(rid=1, prompt=prompt.copy(), max_new_tokens=4))
    second = on.run()
    snap = on.snapshot()
    # full hit drops back one token so first-step logits exist
    assert snap.cached_prefix_tokens == len(prompt) - 1
    off = _server(tiny_params, prefix_cache=False)
    off.submit(Request(rid=0, prompt=prompt.copy(), max_new_tokens=4))
    off.submit(Request(rid=1, prompt=prompt.copy(), max_new_tokens=4))
    ref = off.run()
    assert first[0] == ref[0] and second[1] == ref[1]


def test_server_preempt_resume_with_shared_blocks(tiny_params):
    # pool fits ~1.5 requests: decode growth preempts a request that
    # holds shared cached blocks; its replay must re-match them and
    # regenerate the same greedy tokens
    kw = dict(max_len=24, num_blocks=1 + 7)

    def serve(on):
        server = _server(tiny_params, prefix_cache=on, **kw)
        for rid in range(3):
            server.submit(_shared_req(rid, suffix_len=3, max_new=8))
        return server.run(), server

    res_on, s_on = serve(True)
    res_off, s_off = serve(False)
    assert res_on == res_off
    assert max(s_on.snapshot().preemptions,
               s_off.snapshot().preemptions) > 0, \
        "pool was roomy enough that preemption never happened"
    assert s_on.snapshot().cached_prefix_tokens > 0


def test_server_telemetry_occupancy_split_and_export(tiny_params):
    from repro.obs.registry import MetricsRegistry, \
        export_prefix_cache_stats
    server = _server(tiny_params, prefix_cache=True)
    for rid in range(3):
        server.submit(_shared_req(rid))
    server.run()
    snap = server.snapshot()
    # drained: nothing live, retired cached blocks parked evictable
    assert snap.kv_blocks_live == 0
    assert snap.kv_blocks_evictable > 0
    assert snap.kv_blocks_evictable <= snap.kv_blocks_total
    assert snap.prefix_evictions == server.allocator.evictions
    reg = MetricsRegistry()
    export_prefix_cache_stats(server, reg)
    gauges = reg.snapshot()["gauges"]
    assert gauges["kv_pool_blocks_live"] == 0
    assert gauges["kv_pool_blocks_evictable"] == \
        snap.kv_blocks_evictable
    assert gauges["prefix_cache_block_hits"] > 0
    assert gauges["prefix_cache_entries"] == len(server.prefix_cache)
