"""Paged KV cache numerics: the Pallas block-indexed decode kernel vs
its jnp oracle, and the paged model path (chunked prefill + decode) vs
the dense-cache ``decode_step`` logits on two model families
(decoder-only + vision), fp32 tolerance.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref
from repro.models import (decode_step, decode_step_paged, forward_paged,
                          forward_train, init_pages, init_params, prefill)


# ------------------------- kernel vs oracle --------------------------- #
@pytest.mark.parametrize("b,h,hkv,d,bs,n,m", [
    (2, 4, 2, 16, 8, 10, 3),
    (3, 8, 1, 32, 16, 12, 2),     # MQA
    (1, 4, 4, 64, 8, 6, 4),       # MHA (group = 1)
    (4, 8, 2, 128, 16, 24, 5),
])
def test_paged_kernel_matches_ref(b, h, hkv, d, bs, n, m):
    rng = np.random.default_rng(b * 31 + n)
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n, bs, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n, bs, hkv, d)), jnp.float32)
    ids = rng.permutation(np.arange(1, n))[:b * m]
    bt = jnp.asarray(np.resize(ids, (b, m)).astype(np.int32))
    lengths = jnp.asarray(rng.integers(1, m * bs + 1, size=(b,)), jnp.int32)
    got = paged_attention(q, kp, vp, bt, lengths, interpret=True)
    want = paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_kernel_bf16():
    rng = np.random.default_rng(7)
    b, h, hkv, d, bs, n, m = 2, 4, 2, 32, 8, 8, 2
    q = jnp.asarray(rng.normal(size=(b, h, d))).astype(jnp.bfloat16)
    kp = jnp.asarray(rng.normal(size=(n, bs, hkv, d))).astype(jnp.bfloat16)
    vp = jnp.asarray(rng.normal(size=(n, bs, hkv, d))).astype(jnp.bfloat16)
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    lengths = jnp.asarray([9, 16], jnp.int32)
    got = paged_attention(q, kp, vp, bt, lengths, interpret=True)
    want = paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_paged_kernel_short_rows_ignore_stale_blocks():
    """Slots past a row's length must not leak into the output even
    when the pool holds other requests' live data there."""
    rng = np.random.default_rng(3)
    b, h, hkv, d, bs, n, m = 2, 4, 2, 16, 8, 6, 2
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n, bs, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n, bs, hkv, d)), jnp.float32)
    bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    lengths = jnp.asarray([3, 11], jnp.int32)
    base = paged_attention(q, kp, vp, bt, lengths, interpret=True)
    # clobber everything outside the valid prefixes
    kp2 = kp.at[2].set(99.0).at[4, 3:].set(-99.0).at[5].set(99.0)
    vp2 = vp.at[2].set(99.0).at[4, 3:].set(-99.0).at[5].set(99.0)
    again = paged_attention(q, kp2, vp2, bt, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(again),
                               rtol=1e-6, atol=1e-6)


# ------------------ paged model path vs dense cache ------------------- #
def _paged_vs_dense(arch, chunk):
    """Chunked paged prefill + decode vs dense prefill/decode + full
    forward ground truth; returns max abs logit errors."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 1, 12
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    n_soft = 0
    if cfg.frontend == "vision":
        from repro.models.frontend import vision_patches
        batch["soft_emb"] = vision_patches(key, cfg, B)
        n_soft = batch["soft_emb"].shape[1]

    lg_dense, cache = prefill(params, cfg, batch)
    nxt = jnp.argmax(lg_dense[:, -1], -1).astype(jnp.int32)
    # headroom so the dense decode does not overwrite the last prompt KV
    pad = [(0, 0), (0, 0), (0, 8), (0, 0), (0, 0)]
    cache = dict(cache, k=jnp.pad(cache["k"], pad),
                 v=jnp.pad(cache["v"], pad))
    lg_dense2, _ = decode_step(params, cfg, cache, {"tokens": nxt[:, None]})
    full = dict(batch, tokens=jnp.concatenate([toks, nxt[:, None]], 1))
    lg_full, _ = forward_train(params, cfg, full)

    bs = 8
    pages = init_pages(cfg, 10, bs)
    bt = jnp.asarray([[1, 2, 3, 4, 5, 6]], jnp.int32)
    ctx = jnp.zeros((B,), jnp.int32)
    off, first, lg_paged = 0, True, None
    while off < S:
        n = min(chunk, S - off)
        tb = jnp.zeros((B, chunk), jnp.int32).at[:, :n].set(
            toks[:, off:off + n])
        cb = {"tokens": tb}
        if first and n_soft:
            cb["soft_emb"] = batch["soft_emb"]
        lg_paged, pages = forward_paged(
            params, cfg, pages, cb, bt, ctx, jnp.full((B,), n, jnp.int32))
        ctx = ctx + n + (n_soft if first else 0)
        off += n
        first = False
    errs = {"prefill": float(jnp.max(jnp.abs(
        lg_paged[:, (S % chunk or chunk) - 1] - lg_dense[:, -1])))}
    for uk in (False, True):
        lg_p2, _ = decode_step_paged(params, cfg, pages,
                                     {"tokens": nxt[:, None]}, bt, ctx,
                                     use_kernel=uk)
        name = "kernel" if uk else "jnp"
        errs[f"decode_{name}_vs_dense"] = float(jnp.max(jnp.abs(
            lg_p2[:, 0] - lg_dense2[:, 0])))
        errs[f"decode_{name}_vs_full"] = float(jnp.max(jnp.abs(
            lg_p2[:, 0] - lg_full[:, -1])))
    return errs


@pytest.mark.parametrize("arch", ["minicpm-2b", "llava-next-34b"])
@pytest.mark.parametrize("chunk", [8, 12])
def test_paged_matches_dense_cache(arch, chunk):
    """Decoder-only + vision families: paged chunked prefill and both
    decode paths (gathered jnp and the Pallas kernel) reproduce the
    dense-cache logits at fp32 tolerance."""
    errs = _paged_vs_dense(arch, chunk)
    for name, err in errs.items():
        assert err < 2e-4, (name, err, errs)


def test_paged_rejects_constant_state_families():
    cfg = get_config("falcon-mamba-7b").reduced()
    with pytest.raises(NotImplementedError):
        init_pages(cfg, 8, 16)


def test_scratch_block_isolates_invalid_writes():
    """Padded tail positions must land in scratch block 0, leaving
    allocated blocks untouched."""
    cfg = dataclasses.replace(get_config("minicpm-2b").reduced(),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    pages = init_pages(cfg, 6, 8)
    bt = jnp.asarray([[1, 2]], jnp.int32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                              cfg.vocab_size)
    # only 5 of 8 positions valid
    _, pages2 = forward_paged(params, cfg, pages, {"tokens": toks}, bt,
                              jnp.zeros((1,), jnp.int32),
                              jnp.asarray([5], jnp.int32))
    k = np.asarray(pages2["k"])
    assert np.any(k[:, 1, :5] != 0), "valid positions must be written"
    assert np.all(k[:, 1, 5:] == 0), "padded tail leaked into block 1"
    assert np.all(k[:, 2] == 0), "padded tail leaked into block 2"
    assert np.any(k[:, 0] != 0), "scratch block should absorb the tail"
    assert np.all(k[:, 3:] == 0), "unallocated blocks must stay clean"
