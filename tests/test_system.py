"""End-to-end behaviour tests: train loop learns, checkpoint-resume is
bit-stable, serving loop decodes."""

import tempfile

import numpy as np
import jax
import pytest

from repro.configs.base import ArchConfig

pytestmark = pytest.mark.slow


def tiny_cfg() -> ArchConfig:
    return ArchConfig(name="tiny-dense", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                      vocab_size=128, dtype="float32")


def test_train_loss_decreases_and_resumes():
    import repro.launch.train as T
    cfg = tiny_cfg()
    orig = T.get_config
    T.get_config = lambda name: cfg if name == cfg.name else orig(name)
    try:
        with tempfile.TemporaryDirectory() as ckpt:
            losses = T.run(cfg.name, steps=30, batch_size=4, seq_len=64,
                           reduced=False, ckpt_dir=ckpt, ckpt_every=10,
                           lr=3e-3, log_every=100)
            assert losses[-1] < losses[0], (losses[0], losses[-1])
            # resume continues from the last committed step
            more = T.run(cfg.name, steps=35, batch_size=4, seq_len=64,
                         reduced=False, ckpt_dir=ckpt, ckpt_every=100,
                         lr=3e-3, log_every=100)
            assert len(more) == 5  # only the new steps ran
    finally:
        T.get_config = orig


def test_serving_loop_decodes():
    from repro.launch.serve import BatchedServer, Request
    from repro.models import init_params
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(4):
        server.submit(Request(rid=rid,
                              prompt=rng.integers(0, 128, 8,
                                                  dtype=np.int32),
                              max_new_tokens=4))
    results = server.run()
    assert sorted(results) == [0, 1, 2, 3]
    assert all(len(v) == 4 for v in results.values())


def test_microbatched_step_matches_full_batch():
    from repro.models import init_params
    from repro.optim.adamw import AdamWConfig
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step
    cfg = tiny_cfg()
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    batch = {
        "tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(key, 1), (4, 32),
                                     0, cfg.vocab_size),
    }
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    s1, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1))(
        init_train_state(params), batch)
    s2, m2 = jax.jit(make_train_step(cfg, opt, microbatches=2))(
        init_train_state(params), batch)
    a = jax.tree.leaves(s1.params)[2]
    b = jax.tree.leaves(s2.params)[2]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-4,
                               atol=2e-5)


def test_elastic_restart_end_to_end(tmp_path=None):
    """Node-failure drill: train -> fail a host -> plan the shrunken mesh
    -> restore the committed checkpoint -> continue training on the
    smaller data axis with bit-identical parameters."""
    import tempfile
    from repro.checkpoint import CheckpointManager
    from repro.data.pipeline import DataConfig, SyntheticLMDataset
    from repro.models import init_params
    from repro.optim.adamw import AdamWConfig
    from repro.runtime import plan_elastic_remesh
    from repro.train.state import init_train_state
    from repro.train.step import make_train_step

    cfg = tiny_cfg()
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    step_fn = jax.jit(make_train_step(cfg, opt))
    state = init_train_state(init_params(jax.random.PRNGKey(0), cfg))
    data16 = SyntheticLMDataset(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=16))

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for step in range(6):
            b = data16.batch(step)
            state, m = step_fn(state, {k: jax.numpy.asarray(v)
                                       for k, v in b.items()})
        mgr.save(6, state)

        # host h3 dies: plan the shrunken mesh
        plan = plan_elastic_remesh(
            mesh_shape=(16, 16), axis_names=("data", "model"),
            hosts_per_slice=1, failed_hosts={"h3"},
            all_hosts=[f"h{i}" for i in range(16)], restore_step=6)
        assert plan.new_mesh == (8, 16)       # data axis 16 -> 8
        assert plan.restore_step == 6

        # restart: restore + continue with the smaller data degree
        step_r, state_r, _ = mgr.restore(state)
        assert step_r == 6
        a = jax.tree.leaves(state.params)[1]
        b_ = jax.tree.leaves(state_r.params)[1]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
        data8 = SyntheticLMDataset(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                       global_batch=8))
        losses = []
        for step in range(6, 12):
            b = data8.batch(step)
            state_r, m = step_fn(state_r, {k: jax.numpy.asarray(v)
                                           for k, v in b.items()})
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses))
