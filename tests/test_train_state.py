"""FSDP -> GSPMD resume conversion: the flat fp32 optimizer/master
shards round-trip back to the tree layout (fast tier, no devices)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.collectives.overlap import flatten_tree
from repro.optim.adamw import AdamWConfig, AdamWState, apply_updates
from repro.train.state import (TrainState, fsdp_state_to_tree,
                               init_train_state)


def _params(key):
    ks = jax.random.split(key, 3)
    return {
        "emb": jax.random.normal(ks[0], (13, 8), jnp.float32),
        "blk": {"w": jax.random.normal(ks[1], (8, 8),
                                       jnp.float32).astype(jnp.bfloat16),
                "b": jax.random.normal(ks[2], (8,), jnp.float32)},
    }


def _flatten_like_fsdp(tree, n_world: int):
    """What fsdp_sync_apply persists: one flat fp32 vector padded to a
    multiple of the DP world."""
    flat, _ = flatten_tree(tree)
    pad = (-flat.size) % n_world
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat


def test_fsdp_state_round_trips_to_tree():
    key = jax.random.PRNGKey(0)
    params = _params(key)
    # non-trivial moments (zeros would hide permutation bugs)
    mu = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(p.size),
                                    p.shape, jnp.float32), params)
    nu = jax.tree.map(lambda m: jnp.abs(m) + 0.5, mu)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    tree_state = TrainState(
        params=params,
        opt=AdamWState(mu=mu, nu=nu, count=jnp.asarray(7, jnp.int32),
                       master=master))

    n_world = 8
    flat_state = TrainState(
        params=params,
        opt=AdamWState(mu=_flatten_like_fsdp(mu, n_world),
                       nu=_flatten_like_fsdp(nu, n_world),
                       count=tree_state.opt.count,
                       master=_flatten_like_fsdp(master, n_world)))

    back = fsdp_state_to_tree(flat_state)
    for name, ref, got in (("mu", mu, back.opt.mu),
                           ("nu", nu, back.opt.nu),
                           ("master", master, back.opt.master)):
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            assert b.dtype == jnp.float32, name
            assert a.shape == b.shape, name
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b), err_msg=name)
    assert int(back.opt.count) == 7
    assert back.params is params

    # the converted state drives the tree-layout (GSPMD-mode) update
    grads = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32), params)
    params2, opt2, metrics = apply_updates(
        AdamWConfig(master_weights=True), params, grads, back.opt)
    assert jax.tree.structure(params2) == jax.tree.structure(params)
    assert np.isfinite(float(metrics["grad_norm"]))


def test_fsdp_state_to_tree_passthrough():
    """Tree-shaped (allreduce-mode) states pass through untouched, and
    master=None stays None -- safe to call on any restored state."""
    params = _params(jax.random.PRNGKey(1))
    state = init_train_state(params)
    out = fsdp_state_to_tree(state)
    assert out.opt.master is None
    for a, b in zip(jax.tree.leaves(state.opt.mu),
                    jax.tree.leaves(out.opt.mu)):
        assert a is b
