"""FabricTopology: per-axis fabric constants (core model layer).

Fast tier.  Covers the uniform fast path (same object, same prices),
axis resolution incl. folded tuples, the CLI/JSON spec parser, and the
link_bw scaling of Eq. (1) and the closed-form pattern prices.
"""

import dataclasses
import json

import pytest

from repro.core import patterns as pat
from repro.core.model import (CostTerms, FabricTopology, TPU_V5E_AXIS, WSE2,
                              as_topology, parse_fabric_topology,
                              slowest_fabric)

SLOW = dataclasses.replace(TPU_V5E_AXIS, name="slow", link_bw=0.25,
                           t_r=TPU_V5E_AXIS.t_r * 4)


# ------------------------------ topology ------------------------------ #
def test_uniform_topology_fast_path():
    topo = FabricTopology.uniform(TPU_V5E_AXIS)
    assert topo.is_uniform
    assert topo.for_axis("data") is TPU_V5E_AXIS
    assert topo.for_axis(("pod", "data")) is TPU_V5E_AXIS
    assert topo.for_axis(None) is TPU_V5E_AXIS
    assert as_topology(TPU_V5E_AXIS) == topo
    assert as_topology(topo) is topo


def test_axis_overrides_and_normalization():
    topo = FabricTopology(default=TPU_V5E_AXIS,
                          axis_fabrics=(("pod", SLOW),))
    assert not topo.is_uniform
    assert topo.for_axis("pod") is SLOW
    assert topo.for_axis("data") is TPU_V5E_AXIS
    # folded tuples resolve to the slowest member
    assert topo.for_axis(("pod", "data")) is SLOW
    assert topo.for_axis(("data", "model")) is TPU_V5E_AXIS
    # an override equal to the default is dropped (stays uniform)
    same = FabricTopology(default=TPU_V5E_AXIS,
                          axis_fabrics=(("data", TPU_V5E_AXIS),))
    assert same.is_uniform
    # construction order does not matter for equality/hash
    a = FabricTopology(TPU_V5E_AXIS, (("a", SLOW), ("b", WSE2)))
    b = FabricTopology(TPU_V5E_AXIS, (("b", WSE2), ("a", SLOW)))
    assert a == b and hash(a) == hash(b)
    # with_axis replaces in place
    assert a.with_axis("a", TPU_V5E_AXIS).for_axis("a") is TPU_V5E_AXIS


def test_slowest_fabric():
    assert slowest_fabric(TPU_V5E_AXIS) is TPU_V5E_AXIS
    assert slowest_fabric(TPU_V5E_AXIS, SLOW) is SLOW
    assert slowest_fabric(SLOW, TPU_V5E_AXIS) is SLOW
    # uniform input returns the shared object (bit-for-bit pricing)
    assert slowest_fabric(TPU_V5E_AXIS, TPU_V5E_AXIS) is TPU_V5E_AXIS
    with pytest.raises(ValueError):
        slowest_fabric()


# ------------------------------ spec parser ---------------------------- #
def test_parse_spec_presets_and_floats():
    topo = parse_fabric_topology("pod=slow,data=fast")
    assert topo.for_axis("data") == TPU_V5E_AXIS
    assert topo.for_axis("pod").link_bw == pytest.approx(0.25)
    assert topo.for_axis("pod").t_r == pytest.approx(4 * 88.0)
    # bare float = link_bw multiplier
    topo = parse_fabric_topology("pod=0.5")
    assert topo.for_axis("pod").link_bw == pytest.approx(0.5)
    assert topo.for_axis("pod").t_r == TPU_V5E_AXIS.t_r
    # default override applies to unnamed axes
    topo = parse_fabric_topology("default=slow,pod=dcn")
    assert topo.default.link_bw == pytest.approx(0.25)
    assert topo.for_axis("pod").link_bw == pytest.approx(1.0 / 16.0)
    # duplicate axis entries collapse last-wins instead of crashing
    topo = parse_fabric_topology("pod=slow,pod=dcn")
    assert topo.for_axis("pod").link_bw == pytest.approx(1.0 / 16.0)
    with pytest.raises(ValueError):
        parse_fabric_topology("pod:slow")
    with pytest.raises(ValueError):
        parse_fabric_topology("pod=warp9")
    # zero/negative bandwidth multipliers fail at parse time, not with
    # a ZeroDivisionError deep in pattern pricing
    with pytest.raises(ValueError, match="must be > 0"):
        parse_fabric_topology("pod=0")
    with pytest.raises(ValueError, match="must be > 0"):
        parse_fabric_topology("pod=-1")


def test_parse_spec_json_file(tmp_path):
    path = tmp_path / "topo.json"
    path.write_text(json.dumps({
        "default": {"t_r": 100.0, "multicast": False},
        "axes": {"pod": {"name": "pod_link", "link_bw": 0.125},
                 "data": {"t_r": 90.0}},
    }))
    topo = parse_fabric_topology(str(path))
    assert topo.default.t_r == 100.0
    assert topo.default.multicast is False
    assert topo.for_axis("pod").name == "pod_link"
    assert topo.for_axis("pod").link_bw == 0.125
    assert topo.for_axis("pod").t_r == 100.0      # inherits default
    assert topo.for_axis("data").t_r == 90.0


# ---------------------------- link_bw pricing -------------------------- #
def test_cost_terms_scale_with_link_bw():
    terms = CostTerms(depth=2, distance=10, energy=4096, contention=512,
                      links=8)
    full = terms.cycles(WSE2)
    half = terms.cycles(dataclasses.replace(WSE2, link_bw=0.5))
    assert half > full
    # depth/distance terms do not scale; wire terms double
    assert half == pytest.approx(
        max(512 / 0.5, 4096 / (8 * 0.5) + 10) + WSE2.per_depth_cost * 2)
    # bw=1.0 is exactly the unscaled arithmetic
    assert terms.cycles(dataclasses.replace(WSE2, link_bw=1.0)) == full


@pytest.mark.parametrize("fn", [
    pat.t_chain, pat.t_ring_allreduce, pat.t_ring_reduce_scatter,
    pat.t_doubling_allgather, pat.t_doubling_broadcast,
    pat.t_chain_broadcast, pat.t_star, pat.t_tree, pat.t_two_phase,
])
def test_pattern_prices_monotone_in_link_bw(fn):
    p, b = 8, 4096
    fast = fn(p, b, TPU_V5E_AXIS)
    slow = fn(p, b, dataclasses.replace(TPU_V5E_AXIS, link_bw=0.25))
    assert slow > fast, fn.__name__
    # at bandwidth-bound sizes a 4x slower link costs ~4x the wire term
    assert slow <= 4.0 * fast + 1e-9, fn.__name__


def test_xy_reduce_per_axis_fabrics():
    m, n, b = 4, 8, 4096
    uni = pat.t_xy_reduce("chain", m, n, b, TPU_V5E_AXIS)
    # explicit per-axis fabrics equal to the base: identical price
    assert pat.t_xy_reduce("chain", m, n, b, TPU_V5E_AXIS,
                           fabric_m=TPU_V5E_AXIS,
                           fabric_n=TPU_V5E_AXIS) == uni
    # slowing only the m (outer) dimension raises the price by the m
    # leg's wire delta, not the n leg's
    slow_m = pat.t_xy_reduce("chain", m, n, b, TPU_V5E_AXIS,
                             fabric_m=SLOW)
    slow_n = pat.t_xy_reduce("chain", m, n, b, TPU_V5E_AXIS,
                             fabric_n=SLOW)
    assert slow_m > uni and slow_n > uni
    delta_m = pat.t_chain(m, b, SLOW) - pat.t_chain(m, b, TPU_V5E_AXIS)
    assert slow_m - uni == pytest.approx(delta_m)
