"""Dry-run plumbing on an 8-virtual-device debug mesh (subprocess; the
512-device production sweep is exercised by repro.launch.dryrun itself
and its artifacts are validated in test_dryrun_artifacts.py)."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.multidev, pytest.mark.slow]

_SCRIPT = r"""
import os, json, dataclasses
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, SHAPES
from repro.launch import specs as sp
from repro.launch.mesh import make_debug_mesh
from repro.launch.roofline import parse_collective_bytes, collective_total
from repro.sharding import rules
from repro.train.state import abstract_train_state, train_state_shardings
from repro.train.step import make_train_step, make_prefill_step, make_decode_step
from repro.optim.adamw import AdamWConfig
from repro.models import transformer as tf

results = {}
mesh = make_debug_mesh(multi_pod=True)   # (2,2,2): pod axis proof
policy = rules.for_mesh(mesh)

for name in ("yi-34b", "olmoe-1b-7b", "falcon-mamba-7b",
             "recurrentgemma-9b", "whisper-medium"):
    cfg = get_config(name).reduced()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=256,
                                global_batch=4)
    state_specs = abstract_train_state(cfg)
    state_sh = train_state_shardings(state_specs, mesh, policy)
    bs = sp.train_input_specs(cfg, shape)
    bsh = {k: NamedSharding(mesh, s)
           for k, s in rules.batch_sharding_specs(policy, bs).items()}
    step = make_train_step(cfg, AdamWConfig())
    with mesh:
        compiled = jax.jit(step, in_shardings=(state_sh, bsh),
                           donate_argnums=(0,)).lower(state_specs, bs).compile()
    ca = compiled.cost_analysis()
    # jax used to return [dict]; newer versions return the dict itself
    cost = dict(ca[0] if isinstance(ca, (list, tuple)) else ca)
    coll = parse_collective_bytes(compiled.as_text())
    results[f"{name}/train"] = {
        "flops_positive": float(cost.get("flops", 0)) > 0,
        "has_collectives": collective_total(coll) > 0,
        "mem_ok": compiled.memory_analysis() is not None,
    }
print("JSON" + json.dumps(results))
"""


def test_dryrun_debug_mesh_multipod():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][-1]
    results = json.loads(line[4:])
    for cell, checks in results.items():
        for k, ok in checks.items():
            assert ok, (cell, k)
