"""Substrate tests: optimizer, data pipeline, checkpointing, fault
tolerance, configs."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, all_configs, cell_is_runnable, get_config
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.optim.adamw import AdamWConfig, apply_updates, init_state, lr_at
from repro.runtime import (HeartbeatMonitor, PreemptionGuard,
                           StragglerDetector, plan_elastic_remesh)


# ------------------------------ optimizer ----------------------------- #
def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, schedule="constant",
                      warmup_steps=0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_state(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = apply_updates(cfg, params, grads, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_wsd_schedule_shape():
    cfg = AdamWConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                      total_steps=100, decay_fraction=0.2, min_lr_ratio=0.1)
    warm = float(lr_at(cfg, jnp.asarray(5)))
    stable = float(lr_at(cfg, jnp.asarray(50)))
    late = float(lr_at(cfg, jnp.asarray(100)))
    assert warm < stable
    assert stable == pytest.approx(1.0)
    assert late == pytest.approx(0.1, rel=0.05)


def test_grad_clip_applied():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = init_state(params)
    _, _, m = apply_updates(cfg, params, {"w": jnp.full(4, 100.0)}, state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# ------------------------------ data ---------------------------------- #
def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    ds0 = SyntheticLMDataset(cfg, num_shards=2, shard_index=0)
    ds1 = SyntheticLMDataset(cfg, num_shards=2, shard_index=1)
    b0a, b0b = ds0.batch(7), ds0.batch(7)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
    b1 = ds1.batch(7)
    assert not np.array_equal(b0a["tokens"], b1["tokens"])
    # labels are next tokens
    full = SyntheticLMDataset(cfg).batch(0)
    assert full["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(full["tokens"][:, 1:],
                                  full["labels"][:, :-1])


# ------------------------------ checkpoint ---------------------------- #
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones(4, np.int32)}}
    for step in (1, 2, 3):
        mgr.save(step, tree, metadata={"step": step})
    assert mgr.committed_steps() == [2, 3]
    template = jax.tree.map(lambda a: np.zeros_like(a), tree)
    step, restored, meta = mgr.restore(template)
    assert step == 3 and meta["step"] == 3
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_checkpoint_torn_write_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"a": np.ones(3)}
    mgr.save(1, tree)
    # simulate a torn step: shard written, COMMIT missing
    os.makedirs(tmp_path / "step_00000002", exist_ok=True)
    np.savez(tmp_path / "step_00000002" / "shard_0.npz", a=np.zeros(3))
    assert mgr.committed_steps() == [1]
    step, restored, _ = mgr.restore({"a": np.zeros(3)})
    assert step == 1


# ------------------------------ fault tolerance ----------------------- #
def test_heartbeat_detects_dead_hosts():
    clock = [0.0]
    mon = HeartbeatMonitor(["h0", "h1"], timeout_s=10,
                           clock=lambda: clock[0])
    clock[0] = 5.0
    mon.beat("h0")
    clock[0] = 12.0
    assert mon.dead_hosts() == ["h1"]


def test_straggler_detection():
    det = StragglerDetector(window=4, threshold=1.5)
    for t in range(4):
        for h in ("h0", "h1", "h2", "h3"):
            det.record(h, 1.0 if h != "h2" else 2.5)
    s = det.stragglers()
    assert len(s) == 1 and s[0][0] == "h2" and s[0][1] > 2.0


def test_elastic_remesh_plan():
    plan = plan_elastic_remesh(
        mesh_shape=(2, 16, 16), axis_names=("pod", "data", "model"),
        hosts_per_slice=4, failed_hosts={"h3"},
        all_hosts=[f"h{i}" for i in range(128)], restore_step=1000)
    assert plan.new_mesh[2] == 16          # model axis untouched
    assert plan.new_mesh[1] <= 16
    assert plan.restore_step == 1000
    assert 0 < plan.shrink_factor <= 1.0


def test_preemption_guard():
    g = PreemptionGuard(install=False)
    assert not g.should_stop
    g.request_stop()
    assert g.should_stop


# ------------------------------ configs ------------------------------- #
def test_exact_assigned_configs():
    c = get_config("arctic-480b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (35, 7168, 56, 8, 4864, 32000)
    assert (c.num_experts, c.experts_per_token) == (128, 2)
    assert c.moe_dense_ff > 0  # dense residual

    c = get_config("olmoe-1b-7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.num_experts, c.experts_per_token) == (
        16, 2048, 16, 16, 1024, 50304, 64, 8)

    c = get_config("falcon-mamba-7b")
    assert (c.num_layers, c.d_model, c.vocab_size, c.ssm_state) == (
        64, 4096, 65024, 16)
    assert c.family == "ssm"

    c = get_config("whisper-medium")
    assert (c.num_layers, c.encoder_layers, c.d_model, c.num_heads,
            c.d_ff, c.vocab_size) == (24, 24, 1024, 16, 4096, 51865)

    c = get_config("phi3-mini-3.8b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (32, 3072, 32, 32, 8192, 32064)

    c = get_config("mistral-nemo-12b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (40, 5120, 32, 8, 14336, 131072)

    c = get_config("yi-34b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (60, 7168, 56, 8, 20480, 64000)

    c = get_config("minicpm-2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (40, 2304, 36, 36, 5760, 122753)

    c = get_config("llava-next-34b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (60, 7168, 56, 8, 20480, 64000)
    assert c.frontend == "vision"

    c = get_config("recurrentgemma-9b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (38, 4096, 16, 1, 12288, 256000)
    assert c.block_pattern == ("rglru", "rglru", "local")
    assert c.local_window == 2048


def test_long_context_skip_rules():
    long = SHAPES["long_500k"]
    for name, cfg in all_configs().items():
        runnable, why = cell_is_runnable(cfg, long)
        if cfg.family in ("ssm", "hybrid"):
            assert runnable, name
        else:
            assert not runnable and "full-attention" in why, name


def test_param_counts_match_names():
    expect = {"arctic-480b": 480e9, "olmoe-1b-7b": 6.9e9,
              "falcon-mamba-7b": 7.3e9, "yi-34b": 34.4e9,
              "mistral-nemo-12b": 12.2e9, "phi3-mini-3.8b": 3.8e9,
              "minicpm-2b": 2.7e9, "recurrentgemma-9b": 9.0e9}
    for name, n in expect.items():
        got = get_config(name).param_count()
        assert 0.65 * n <= got <= 1.35 * n, (name, got, n)


def test_checkpoint_bf16_roundtrip(tmp_path):
    """bfloat16 leaves must survive the npz round-trip (encoded as raw
    uint16 + dtype sidecar)."""
    import ml_dtypes
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": np.arange(8, dtype=np.float32).astype(ml_dtypes.bfloat16),
            "b": np.ones(3, np.float32)}
    mgr.save(1, tree)
    template = {"w": np.zeros(8, ml_dtypes.bfloat16),
                "b": np.zeros(3, np.float32)}
    _, restored, _ = mgr.restore(template)
    assert restored["w"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(
        restored["w"].astype(np.float32), tree["w"].astype(np.float32))


def test_master_weights_mixed_precision():
    """The classic bf16 stall: updates far below the parameter's ulp
    vanish without an fp32 master (w ~ 1000 has ulp 4 in bf16; Adam
    steps of ~0.01 round away).  The master accumulates them."""
    target = jnp.array([1001.0, 999.0])
    cfg = AdamWConfig(lr=0.01, weight_decay=0.0, schedule="constant",
                      warmup_steps=0, grad_clip=1e9)

    def run(master):
        params = {"w": jnp.full(2, 1000.0, jnp.bfloat16)}
        state = init_state(params, master_weights=master)
        best = np.inf
        for _ in range(300):
            g = {"w": 2 * (params["w"].astype(jnp.float32) - target)}
            params, state, _ = apply_updates(cfg, params, g, state)
            ref = state.master["w"] if master else params["w"].astype(
                jnp.float32)
            best = min(best, float(np.abs(np.asarray(ref)
                                          - np.asarray(target)).max()))
        return best

    best_master = run(True)
    best_plain = run(False)
    # bf16-only never leaves 1000 (updates below the ulp round away);
    # the fp32 master passes within Adam-step distance of the target
    # (it oscillates around it because the *gradient* is still computed
    # from the quantized bf16 param -- the stall is what we demonstrate)
    assert best_plain >= 0.9, best_plain
    assert best_master < 0.2, best_master
