"""Expert-parallel MoE correctness on 8 virtual devices (subprocess):

* the replicated-token shard_map path == GSPMD MoE (logits + grads);
* the true EP dispatch (``models/moe_ep.py``: tokens sharded over the
  EP axes, dispatch/combine as explicit all-to-all) matches the dense
  reference to fp32 tolerance under both the bare-lax single-shot and
  the engine-routed exchange, on the ("data","model") mesh and the
  folded ("pod","data") expert mesh, logits and grads."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.multidev, pytest.mark.slow]

_SCRIPT = r"""
import json, dataclasses
import numpy as np, jax
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.models import init_params, forward_train
from repro.train.step import loss_fn

cfg = get_config("olmoe-1b-7b").reduced()
cfg = dataclasses.replace(cfg, dtype="float32", num_experts=8,
                          experts_per_token=2)
key = jax.random.PRNGKey(0)
params = init_params(key, cfg)
batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.fold_in(key, 1), (4, 32),
                                      0, cfg.vocab_size)}
cfg_ep = dataclasses.replace(cfg, moe_shardmap_ep=True)
mesh = make_debug_mesh()

lp, _ = forward_train(params, cfg, batch)
with mesh:
    le, _ = jax.jit(lambda p, b: forward_train(p, cfg_ep, b))(params, batch)
logit_err = float(np.max(np.abs(np.asarray(lp) - np.asarray(le))))

g1 = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
with mesh:
    g2 = jax.jit(jax.grad(lambda p: loss_fn(p, cfg_ep, batch)[0]))(params)
grad_err = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                   - np.asarray(b, np.float32))))
               for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
print("JSON" + json.dumps({"logit_err": logit_err, "grad_err": grad_err}))
"""


def _run_sub(script: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][-1]
    return json.loads(line[4:])


def test_moe_ep_matches_gspmd():
    res = _run_sub(_SCRIPT)
    assert res["logit_err"] < 1e-3, res
    assert res["grad_err"] < 5e-3, res


_EP_SCRIPT = r"""
import json, dataclasses, functools
import numpy as np, jax, jax.numpy as jnp
from repro.models.moe import moe_ffn
from repro.models.moe_ep import moe_ffn_ep

key = jax.random.PRNGKey(0)
G, gs, D, E, F, K = 8, 16, 12, 8, 24, 2
ks = jax.random.split(key, 5)
x = jax.random.normal(ks[0], (G, gs, D), jnp.float32)
router = jax.random.normal(ks[1], (D, E)) * 0.5
wg = jax.random.normal(ks[2], (E, D, F)) * 0.1
wu = jax.random.normal(ks[3], (E, D, F)) * 0.1
wd = jax.random.normal(ks[4], (E, F, D)) * 0.1
ref, _ = moe_ffn(x, router, wg, wu, wd, top_k=K)
ref = np.asarray(ref)

res = {}
for mesh_shape, mesh_axes in (((2, 4), ("data", "model")),
                              ((2, 4), ("pod", "data"))):
    mesh = jax.make_mesh(mesh_shape, mesh_axes)
    outs = {}
    for algo in ("lax", "auto", "hierarchical", "flat"):
        with mesh:
            out, _ = jax.jit(functools.partial(
                moe_ffn_ep, top_k=K, algorithm=algo))(x, router, wg,
                                                      wu, wd)
        outs[algo] = np.asarray(out)
    tag = "x".join(mesh_axes)
    res[f"dense_err_{tag}"] = max(
        float(np.max(np.abs(o - ref))) for o in outs.values())
    res[f"lax_vs_engine_{tag}"] = float(
        np.max(np.abs(outs["auto"] - outs["lax"])))

# gradient flow through the engine exchange == through bare lax
mesh = jax.make_mesh((2, 4), ("pod", "data"))
def loss(params, algo):
    r, a, b, c = params
    with mesh:
        out, _ = moe_ffn_ep(x, r, a, b, c, top_k=K, algorithm=algo)
    return jnp.sum(out ** 2)
g_lax = jax.jit(jax.grad(lambda p: loss(p, "lax")))((router, wg, wu, wd))
g_eng = jax.jit(jax.grad(lambda p: loss(p, "auto")))((router, wg, wu, wd))
res["grad_err"] = max(
    float(np.max(np.abs(np.asarray(u) - np.asarray(v))))
    for u, v in zip(jax.tree.leaves(g_lax), jax.tree.leaves(g_eng)))
print("JSON" + json.dumps(res))
"""


def test_moe_ep_engine_matches_bare_lax():
    """Acceptance: the engine-routed EP forward matches the bare-lax EP
    path (and the dense moe_ffn reference) to fp32 tolerance on 8
    devices, on both the ("data","model") and the folded ("pod","data")
    expert mesh; gradients agree through the exchange."""
    res = _run_sub(_EP_SCRIPT)
    for tag in ("dataxmodel", "podxdata"):
        assert res[f"dense_err_{tag}"] < 1e-4, res
        assert res[f"lax_vs_engine_{tag}"] < 1e-5, res
    assert res["grad_err"] < 1e-4, res
