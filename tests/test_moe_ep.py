"""shard_map expert-parallel MoE == GSPMD MoE (logits + grads), via an
8-device subprocess."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.multidev, pytest.mark.slow]

_SCRIPT = r"""
import json, dataclasses
import numpy as np, jax
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.models import init_params, forward_train
from repro.train.step import loss_fn

cfg = get_config("olmoe-1b-7b").reduced()
cfg = dataclasses.replace(cfg, dtype="float32", num_experts=8,
                          experts_per_token=2)
key = jax.random.PRNGKey(0)
params = init_params(key, cfg)
batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.fold_in(key, 1), (4, 32),
                                      0, cfg.vocab_size)}
cfg_ep = dataclasses.replace(cfg, moe_shardmap_ep=True)
mesh = make_debug_mesh()

lp, _ = forward_train(params, cfg, batch)
with mesh:
    le, _ = jax.jit(lambda p, b: forward_train(p, cfg_ep, b))(params, batch)
logit_err = float(np.max(np.abs(np.asarray(lp) - np.asarray(le))))

g1 = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
with mesh:
    g2 = jax.jit(jax.grad(lambda p: loss_fn(p, cfg_ep, batch)[0]))(params)
grad_err = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                   - np.asarray(b, np.float32))))
               for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
print("JSON" + json.dumps({"logit_err": logit_err, "grad_err": grad_err}))
"""


def test_moe_ep_matches_gspmd():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][-1]
    res = json.loads(line[4:])
    assert res["logit_err"] < 1e-3, res
    assert res["grad_err"] < 5e-3, res
