"""CollectiveEngine: cached model-driven dispatch.

Fast tier: decision-cache hit/miss + persistence, calibration
round-trip, selection sanity -- no devices needed.  Multidev tier: the
new reduce_scatter/allgather/broadcast backends against their jax.lax
references on 8 virtual devices, plus trace-level cache behavior and
the engine-backed train/serve wiring.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.collectives.engine import (CollectiveEngine, SCHEMA_VERSION,
                                      fit_fabric, load_topology,
                                      ICI_ELEMENT_BYTES)
from repro.core.model import FabricTopology, TPU_V5E_AXIS, Fabric


# ------------------------------ decision cache ------------------------ #
def _engine(tmp_path, **kw):
    return CollectiveEngine(cache_path=str(tmp_path / "decisions.json"),
                            **kw)


def test_selection_cache_hit_miss(tmp_path):
    eng = _engine(tmp_path)
    d1 = eng.select("allreduce", 1 << 20, 8)
    assert eng.stats == {"hits": 0, "misses": 1, "dp_runs": 0,
                         "persisted_loads": 0, "plan_hits": 0,
                         "plan_misses": 0, "latency_dispatches": 0}
    d2 = eng.select("allreduce", 1 << 20, 8)
    assert eng.stats["hits"] == 1 and eng.stats["misses"] == 1
    assert d1 == d2
    # a different shape is a fresh miss
    eng.select("allreduce", 1 << 10, 8)
    assert eng.stats["misses"] == 2
    # a different op for the same shape too
    eng.select("broadcast", 1 << 20, 8)
    assert eng.stats["misses"] == 3


def test_autogen_dp_runs_once_per_shape(tmp_path):
    eng = _engine(tmp_path)
    r1 = eng.tree_rounds(8, 64)
    assert eng.stats["dp_runs"] == 1
    r2 = eng.tree_rounds(8, 64)
    assert eng.stats["dp_runs"] == 1 and r1 is r2
    eng.tree_rounds(8, 4096)
    assert eng.stats["dp_runs"] == 2
    # ops whose candidate set includes autogen reuse the cached DP
    eng.select("reduce", 64 * ICI_ELEMENT_BYTES, 8)
    eng.select("allgather", 64 * ICI_ELEMENT_BYTES, 8)
    assert eng.stats["dp_runs"] <= 3


def test_decisions_persist_across_engines(tmp_path):
    eng = _engine(tmp_path)
    d1 = eng.select("allreduce", 1 << 22, 8)
    d2 = eng.select("broadcast", 1 << 12, 8)
    eng.flush()   # saves are write-behind; force the tail out

    eng2 = _engine(tmp_path)
    e1 = eng2.select("allreduce", 1 << 22, 8)
    e2 = eng2.select("broadcast", 1 << 12, 8)
    assert eng2.stats["misses"] == 0, "persisted decisions were recomputed"
    assert eng2.stats["hits"] == 2
    assert eng2.stats["persisted_loads"] >= 2
    assert (e1.algorithm, e2.algorithm) == (d1.algorithm, d2.algorithm)
    assert e1.predictions == pytest.approx(d1.predictions)
    # autogen schedules survive the round-trip intact
    if e2.rounds is not None:
        assert e2.rounds == d2.rounds


def test_selection_matches_model_argmin(tmp_path):
    from repro.core import selector
    eng = _engine(tmp_path)
    for op in ("reduce_scatter", "allgather", "broadcast"):
        for nbytes in (1 << 10, 1 << 24):
            d = eng.select(op, nbytes, 8)
            b = max(1, nbytes // ICI_ELEMENT_BYTES)
            preds = selector.predict_collective(op, 8, b, TPU_V5E_AXIS)
            assert d.algorithm == min(preds, key=preds.get)
            assert d.predictions == pytest.approx(preds)


def test_identity_on_single_device(tmp_path):
    eng = _engine(tmp_path)
    assert eng.select("allreduce", 1 << 20, 1).algorithm == "identity"


# ------------------------------ calibration --------------------------- #
def test_calibration_round_trip(tmp_path):
    true = Fabric(name="truth", t_r=42.0, store_cost=1.0)
    cycle = 11.4e-9  # seconds per element, arbitrary
    sizes = [1 << 12, 1 << 16, 1 << 20, 1 << 22]
    meas = [(nb, (2 * true.t_r + nb // ICI_ELEMENT_BYTES) * cycle)
            for nb in sizes]
    fitted = fit_fabric(meas, base=TPU_V5E_AXIS)
    assert fitted.t_r == pytest.approx(true.t_r, rel=1e-6)

    eng = _engine(tmp_path)
    eng.select("allreduce", 1 << 20, 8)
    assert eng.stats["misses"] == 1
    out = eng.calibrate(measurements=meas)
    assert out.t_r == pytest.approx(true.t_r, rel=1e-6)
    assert eng.fabric is out
    # stale decisions dropped: same query is a fresh miss under the new
    # constants
    eng.select("allreduce", 1 << 20, 8)
    assert eng.stats["misses"] == 2


def _synthetic_measurements(t_r: float, bw: float, cycle: float = 11.4e-9):
    """Per-axis ppermute timings for a link with the given constants:
    seconds = 2*t_r*cycle + B * (cycle / bw)."""
    return [(nb, 2 * t_r * cycle + max(1, nb // ICI_ELEMENT_BYTES)
             * cycle / bw)
            for nb in (1 << 12, 1 << 16, 1 << 20, 1 << 22)]


def test_per_axis_calibration_round_trip(tmp_path):
    """Fit two axes from synthetic timings with different link speeds:
    the topology recovers both sets of constants on a shared time base
    (fast axis anchors link_bw=1), and the planner flips the 128 KiB
    (2, 16) plan from sequential to hierarchical -- the slow cross-pod
    link is exactly what makes the hierarchy pay."""
    eng = _engine(tmp_path)
    before = eng.plan_multi("allreduce", ("pod", "data"), (2, 16),
                            1 << 17)
    assert before.shape != "hierarchical"

    topo = eng.calibrate(measurements={
        "pod": _synthetic_measurements(t_r=300.0, bw=1.0 / 8.0),
        "data": _synthetic_measurements(t_r=88.0, bw=1.0),
    })
    assert isinstance(topo, FabricTopology)
    assert eng.topology is topo
    data_f, pod_f = topo.for_axis("data"), topo.for_axis("pod")
    assert data_f != pod_f
    assert data_f.t_r == pytest.approx(88.0, rel=1e-6)
    assert data_f.link_bw == pytest.approx(1.0, rel=1e-6)
    assert pod_f.t_r == pytest.approx(300.0, rel=1e-6)
    assert pod_f.link_bw == pytest.approx(1.0 / 8.0, rel=1e-6)

    after = eng.plan_multi("allreduce", ("pod", "data"), (2, 16), 1 << 17)
    assert after.shape == "hierarchical", after.predictions
    # and the modeled cross-pod bytes of the winner stay strictly below
    # the volume-shipping shapes'
    ab = after.cost_terms
    assert (ab["hierarchical"]["axis_bytes"]["pod"]
            < ab["flat"]["axis_bytes"]["pod"])


def test_per_axis_calibration_persists_v3_topology(tmp_path):
    """The v3 cache file records the calibrated per-axis fabrics, and
    ``load_topology`` restores them for a fresh process."""
    eng = _engine(tmp_path)
    topo = eng.calibrate(measurements={
        "pod": _synthetic_measurements(t_r=300.0, bw=0.25),
        "data": _synthetic_measurements(t_r=88.0, bw=1.0),
    })
    eng.plan_multi("allreduce", ("pod", "data"), (2, 4), 1 << 20)
    eng.flush()
    path = str(tmp_path / "decisions.json")
    with open(path) as f:
        payload = json.load(f)
    assert payload["schema"] == SCHEMA_VERSION == 3
    axes = payload["topology"]["axes"]
    assert set(axes) == {"pod", "data"}
    assert axes["pod"]["link_bw"] != axes["data"]["link_bw"]

    restored = load_topology(path)
    assert restored == topo
    # an engine rebuilt on the restored topology serves the persisted
    # plans as hits
    eng2 = CollectiveEngine(cache_path=path, fabric=restored)
    eng2.plan_multi("allreduce", ("pod", "data"), (2, 4), 1 << 20)
    assert eng2.stats["plan_hits"] == 1
    assert eng2.stats["plan_misses"] == 0


def test_get_engine_auto_restores_calibrated_topology(tmp_path,
                                                      monkeypatch):
    """A per-axis calibration persisted under REPRO_CACHE_DIR is
    auto-restored by ``api.get_engine()`` in a fresh process: the
    default engine comes up on the calibrated constants without the
    caller re-installing them.  ``REPRO_RESTORE_TOPOLOGY=0`` opts out."""
    from repro.collectives import api

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_RESTORE_TOPOLOGY", raising=False)
    eng = CollectiveEngine()
    topo = eng.calibrate(measurements={
        "pod": _synthetic_measurements(t_r=300.0, bw=0.25),
        "data": _synthetic_measurements(t_r=88.0, bw=1.0),
    })
    eng.select("allreduce", 1 << 20, 8)
    eng.flush()

    # fresh process: empty engine registry, stock default requested
    monkeypatch.setattr(api, "_ENGINES", {})
    restored = api.get_engine()
    assert restored.topology == topo
    assert not restored.topology.is_uniform
    # the registry caches the restored engine under the stock key
    assert api.get_engine() is restored

    # env opt-out: the stock constants, calibration file ignored
    monkeypatch.setattr(api, "_ENGINES", {})
    monkeypatch.setenv("REPRO_RESTORE_TOPOLOGY", "0")
    stock = api.get_engine()
    assert stock.topology.is_uniform
    assert stock.topology.default == TPU_V5E_AXIS

    # an explicitly requested FabricTopology key is never overridden
    monkeypatch.delenv("REPRO_RESTORE_TOPOLOGY")
    monkeypatch.setattr(api, "_ENGINES", {})
    explicit = FabricTopology.uniform(TPU_V5E_AXIS)
    assert api.get_engine(explicit).topology == explicit


def test_find_calibrated_topology_ignores_other_fabric_families(
        tmp_path, monkeypatch):
    """A cache written under different base constants (say WSE2) must
    not leak into the TPU default engine."""
    from repro.collectives.engine import find_calibrated_topology
    from repro.core.model import WSE2

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_RESTORE_TOPOLOGY", raising=False)
    eng = CollectiveEngine(fabric=WSE2)
    eng.calibrate(measurements={
        "pod": _synthetic_measurements(t_r=300.0, bw=0.25),
        "data": _synthetic_measurements(t_r=88.0, bw=1.0),
    })
    eng.select("allreduce", 1 << 20, 8)
    eng.flush()
    assert find_calibrated_topology(base=TPU_V5E_AXIS) is None
    assert find_calibrated_topology(base=WSE2) is not None


def test_find_calibrated_topology_ignores_declared_specs(tmp_path,
                                                         monkeypatch):
    """A topology installed from a --fabric spec (declared, not
    measured) persists with the cache but must not auto-restore into
    unrelated processes."""
    from repro.collectives.engine import find_calibrated_topology
    from repro.core.model import parse_fabric_topology

    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_RESTORE_TOPOLOGY", raising=False)
    eng = CollectiveEngine(fabric=parse_fabric_topology("pod=slow"))
    eng.select("allreduce", 1 << 20, 8)
    eng.flush()
    assert find_calibrated_topology(base=TPU_V5E_AXIS) is None


def test_per_axis_calibration_rejects_noise_dominated_axis(tmp_path):
    """A flat-line (or inverted) timing fit has no bandwidth signal;
    anchoring the shared time base on its clamped slope would hand
    every axis absurd constants -- calibrate must fail loudly
    instead, naming the axis, and leave the engine untouched."""
    eng = _engine(tmp_path)
    before = eng.topology
    flat = [(nb, 1e-6) for nb in (1 << 12, 1 << 16, 1 << 20, 1 << 22)]
    with pytest.raises(ValueError, match="pod"):
        eng.calibrate(measurements={
            "pod": flat,
            "data": _synthetic_measurements(t_r=88.0, bw=1.0)})
    assert eng.topology is before
    with pytest.raises(ValueError, match="empty"):
        eng.calibrate(measurements={})


def test_schema_v2_cache_migrates(tmp_path):
    """A v2 file (schema 2, no topology section, single-fabric tag)
    loads into the v3 engine without error: a uniform topology's tag
    equals the v2 tag and the keys are unchanged."""
    eng = _engine(tmp_path)
    d = eng.select("allreduce", 1 << 20, 8)
    eng.plan_multi("allreduce", ("pod", "data"), (2, 8), 1 << 20)
    eng.flush()
    path = str(tmp_path / "decisions.json")
    with open(path) as f:
        payload = json.load(f)
    legacy = {"schema": 2, "fabric": payload["fabric"],
              "decisions": payload["decisions"],
              "plans": payload["plans"]}
    with open(path, "w") as f:
        json.dump(legacy, f)

    eng2 = _engine(tmp_path)
    d2 = eng2.select("allreduce", 1 << 20, 8)
    eng2.plan_multi("allreduce", ("pod", "data"), (2, 8), 1 << 20)
    assert eng2.stats["misses"] == 0, "v2 decisions were not served"
    assert eng2.stats["plan_misses"] == 0, "v2 plans were not served"
    assert d2.algorithm == d.algorithm
    # a file from a newer schema than this build is ignored, not crashed
    legacy["schema"] = SCHEMA_VERSION + 1
    with open(path, "w") as f:
        json.dump(legacy, f)
    eng3 = _engine(tmp_path)
    eng3.select("allreduce", 1 << 20, 8)
    assert eng3.stats["misses"] == 1


def test_calibration_shifts_selection(tmp_path):
    """Higher measured launch latency pushes `auto` away from deep
    chains toward low-depth patterns -- the selector actually adapts."""
    nbytes = 1 << 19
    fast = CollectiveEngine(
        fabric=Fabric(name="fast", t_r=1.0, store_cost=1.0), persist=False)
    slow = CollectiveEngine(
        fabric=Fabric(name="slow", t_r=5e4, store_cost=1.0), persist=False)
    d_fast = fast.select("allreduce", nbytes, 64)
    d_slow = slow.select("allreduce", nbytes, 64)
    assert d_fast.algorithm == "chain"
    assert d_slow.algorithm != "chain"


# --------------------- multidev: numerics + wiring -------------------- #
_SCRIPT = r"""
import functools, json
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.collectives.engine import CollectiveEngine

results = {}
eng = CollectiveEngine(persist=False)
mesh = jax.make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (64, 24))

def run(fn, in_spec, out_spec):
    f = shard_map(fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec,
                  check_rep=False)
    return np.asarray(jax.jit(f)(x))

# reduce_scatter vs lax.psum_scatter
ref = run(lambda v: lax.psum_scatter(v, "data", scatter_dimension=0,
                                     tiled=True), P(), P("data"))
for algo in ("ring", "autogen", "auto"):
    out = run(functools.partial(eng.reduce_scatter_inside, axis="data",
                                algorithm=algo), P(), P("data"))
    results[f"reduce_scatter_{algo}"] = bool(
        np.allclose(out, ref, rtol=1e-4, atol=1e-4))

# allgather vs lax.all_gather
ref = run(lambda v: lax.all_gather(v, "data", tiled=True), P("data"), P())
for algo in ("ring", "doubling", "autogen", "auto"):
    out = run(functools.partial(eng.allgather_inside, axis="data",
                                algorithm=algo), P("data"), P())
    results[f"allgather_{algo}"] = bool(np.allclose(out, ref))

# broadcast from a non-zero root: everyone must end with root's value
def bc(v, algo):
    idx = lax.axis_index("data")
    seeded = jnp.where(idx == 3, v, jnp.zeros_like(v))
    return eng.broadcast_inside(seeded, "data", root=3, algorithm=algo)
for algo in ("doubling", "chain", "autogen", "auto"):
    out = run(functools.partial(bc, algo=algo), P(), P("data", None))
    results[f"broadcast_{algo}"] = bool(
        np.allclose(out, np.tile(np.asarray(x), (8, 1))))

# allreduce auto vs psum
ref = run(lambda v: lax.psum(v, "data"), P(), P())
out = run(functools.partial(eng.allreduce_inside, axis="data",
                            algorithm="auto"), P(), P())
results["allreduce_auto"] = bool(np.allclose(out, ref, rtol=1e-4,
                                             atol=1e-4))

# trace-level caching: a second trace of the same shape must not re-run
# selection or the Auto-Gen DP
eng2 = CollectiveEngine(persist=False)
g = shard_map(functools.partial(eng2.allreduce_inside, axis="data",
                                algorithm="auto"),
              mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
jax.jit(g).lower(x)
first = dict(eng2.stats)
g2 = shard_map(functools.partial(eng2.allreduce_inside, axis="data",
                                 algorithm="auto"),
               mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
jax.jit(g2).lower(x)
results["retrace_no_new_miss"] = (eng2.stats["misses"] == first["misses"])
results["retrace_hits_cache"] = (eng2.stats["hits"] > first["hits"])
h = shard_map(functools.partial(eng2.allreduce_inside, axis="data",
                                algorithm="autogen"),
              mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
jax.jit(h).lower(x)
dp_after_first = eng2.stats["dp_runs"]
jax.jit(h).lower(x * 2.0)
results["autogen_dp_once"] = (eng2.stats["dp_runs"] == dp_after_first)

# engine-backed gradient sync must land on the same updated params as
# the plain GSPMD step (the allreduce+mean over the DP axis is exactly
# the sync GSPMD's sharding-implied reductions perform; a sum-vs-mean
# or axis bug would show up as an 8x-scaled update)
from repro.configs.base import ArchConfig
from repro.optim.adamw import AdamWConfig
from repro.train.state import init_train_state
from repro.train.step import GradSyncConfig, make_train_step

cfg = ArchConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                 num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                 dtype="float32")
from repro.models import init_params
params = init_params(jax.random.PRNGKey(0), cfg)
key = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
opt = AdamWConfig(warmup_steps=1, total_steps=10)
state = init_train_state(params)

ref_state, ref_metrics = jax.jit(make_train_step(cfg, opt))(
    init_train_state(params), batch)

sharded = {k: jax.device_put(v, NamedSharding(mesh, P("data")))
           for k, v in batch.items()}
step = make_train_step(cfg, opt, grad_sync=GradSyncConfig(mesh=mesh))
with mesh:
    state2, metrics = jax.jit(step)(init_train_state(params), sharded)
results["grad_sync_finite"] = bool(np.isfinite(float(metrics["loss"])))
ref_leaves = jax.tree.leaves(ref_state.params)
got_leaves = jax.tree.leaves(state2.params)
results["grad_sync_matches_gspmd"] = all(
    np.allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
    for a, b in zip(ref_leaves, got_leaves))

# FSDP mode: reduce-scatter grads -> flat-shard AdamW -> allgather
# params, over the hierarchical (pod, data) topology.  Loss and params
# must track the GSPMD baseline at fp32 tolerance across steps (incl.
# the step-0 tree->flat optimizer-state conversion).
mesh_h = jax.make_mesh((2, 4), ("pod", "data"))
sharded_h = {k: jax.device_put(v, NamedSharding(mesh_h, P(("pod", "data"))))
             for k, v in batch.items()}
fsdp_step = make_train_step(cfg, opt, grad_sync=GradSyncConfig(
    mesh=mesh_h, axes=("pod", "data"), mode="fsdp"))
state_ref = init_train_state(params)
state_f = init_train_state(params)
ref_jit = jax.jit(make_train_step(cfg, opt))
ok_loss, ok_gnorm = True, True
for _ in range(2):
    state_ref, m_ref = ref_jit(state_ref, batch)
    with mesh_h:
        state_f, m_f = jax.jit(fsdp_step)(state_f, sharded_h)
    ok_loss &= bool(np.allclose(float(m_ref["loss"]), float(m_f["loss"]),
                                rtol=1e-5, atol=1e-6))
    ok_gnorm &= bool(np.allclose(float(m_ref["grad_norm"]),
                                 float(m_f["grad_norm"]),
                                 rtol=1e-4, atol=1e-6))
results["fsdp_loss_matches_gspmd"] = ok_loss
results["fsdp_gnorm_matches_gspmd"] = ok_gnorm
results["fsdp_params_match_gspmd"] = all(
    np.allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(state_ref.params),
                    jax.tree.leaves(state_f.params)))
results["fsdp_state_is_flat_shards"] = (
    getattr(state_f.opt.mu, "ndim", None) == 1)

# FSDP + fp32 master weights (bf16 params): must track the GSPMD
# master-weights baseline, with the master living as one flat fp32
# shard instead of a param-shaped tree
params_bf = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
fsdp_m_step = make_train_step(cfg, opt, grad_sync=GradSyncConfig(
    mesh=mesh_h, axes=("pod", "data"), mode="fsdp"))
state_mref = init_train_state(params_bf, master_weights=True)
state_mf = init_train_state(params_bf, master_weights=True)
ref_jit_m = jax.jit(make_train_step(cfg, opt))
for _ in range(2):
    state_mref, _ = ref_jit_m(state_mref, batch)
    with mesh_h:
        state_mf, _ = jax.jit(fsdp_m_step)(state_mf, sharded_h)
results["fsdp_master_params_match_gspmd"] = all(
    np.allclose(np.asarray(a, dtype=np.float32),
                np.asarray(b, dtype=np.float32), rtol=1e-2, atol=1e-2)
    for a, b in zip(jax.tree.leaves(state_mref.params),
                    jax.tree.leaves(state_mf.params)))
results["fsdp_master_params_stay_bf16"] = all(
    l.dtype == jnp.bfloat16 for l in jax.tree.leaves(state_mf.params))
results["fsdp_master_is_flat_fp32_shard"] = (
    getattr(state_mf.opt.master, "ndim", None) == 1
    and state_mf.opt.master.dtype == jnp.float32)
# masters hold fp32 state the bf16 params cannot: the flat master must
# differ from the recast params (strictly more precision retained)
flat_masters = np.asarray(state_mf.opt.master)
results["fsdp_master_keeps_fp32_precision"] = bool(
    np.any(flat_masters[:64]
           != np.asarray(jax.tree.leaves(state_mf.params)[0],
                         dtype=np.float32).reshape(-1)[:64]))

# per-axis calibration on the real (2, 4) debug mesh: one fitted
# fabric per mesh axis, persisted under the v3 cache schema
import tempfile
from repro.collectives.engine import load_topology
cal_path = tempfile.mktemp(suffix=".json")
eng_cal = CollectiveEngine(cache_path=cal_path)
topo = eng_cal.calibrate(mesh=mesh_h,
                         sizes_bytes=(1 << 12, 1 << 14, 1 << 16, 1 << 18))
fpod, fdata = topo.for_axis("pod"), topo.for_axis("data")
results["calibrate_mesh_per_axis_fabrics"] = (
    len(dict(topo.axis_fabrics)) == 2
    and (fpod.t_r, fpod.link_bw) != (fdata.t_r, fdata.link_bw)
    and max(fpod.link_bw, fdata.link_bw) == 1.0)
eng_cal.select("allreduce", 1 << 20, 8)
eng_cal.plan_multi("allreduce", ("pod", "data"), (2, 4), 1 << 20)
eng_cal.flush()
with open(cal_path) as fh:
    payload = json.load(fh)
results["calibrate_v3_persisted"] = (
    payload["schema"] == 3
    and set(payload["topology"]["axes"]) == {"pod", "data"})
results["calibrate_topology_reloads"] = (load_topology(cal_path) == topo)

# engine-backed DP serving: tokens identical to single-device greedy
from repro.launch.serve import BatchedServer, Request
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, size=12).astype(np.int32)
           for _ in range(8)]
outs = {}
for m in (None, mesh):
    srv = BatchedServer(cfg, params, batch_size=8, max_len=64, mesh=m)
    for rid, pr in enumerate(prompts):
        srv.submit(Request(rid=rid, prompt=pr, max_new_tokens=4))
    outs[m is not None] = srv.run(max_steps=8)
results["serve_dp_matches_local"] = (outs[True] == outs[False])
print("JSON" + json.dumps(results))
"""


@pytest.mark.multidev
@pytest.mark.slow
def test_engine_collectives_on_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON")][-1]
    results = json.loads(line[4:])
    for key, ok in results.items():
        assert ok, (key, results)
