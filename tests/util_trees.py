"""Shared test helpers for generating random reduction trees.

Lives outside test_schedule.py so tests that don't need hypothesis
(e.g. the simulator property test) can import it even when the optional
hypothesis dependency is missing.
"""

from repro.core.schedule import ReduceTree


def random_pre_order_tree(p: int, rng) -> ReduceTree:
    """Random contiguous-interval ordered tree (the Auto-Gen search
    space)."""
    parent = [-1] * p
    children = [[] for _ in range(p)]

    def build(lo: int, hi: int):
        # vertex `lo` is the root of [lo, hi)
        rest_lo = lo + 1
        while rest_lo < hi:
            # extra draw kept to preserve the historical rng stream the
            # simulator property-test tolerances were validated against
            rng.randint(rest_lo, hi - 1)
            # children get contiguous blocks in order
            end = rng.randint(rest_lo + 1, hi)
            parent[rest_lo] = lo
            children[lo].append(rest_lo)
            build(rest_lo, end)
            rest_lo = end
        return

    build(0, p)
    return ReduceTree(parent, children, root=0, label="random")
