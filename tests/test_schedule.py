"""Property tests (hypothesis) on the Schedule IR invariants."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core.lowerbound import compute_lb_energy, t_lower_bound
from repro.core.model import WSE2
from repro.core import patterns as pat
from repro.core.schedule import (binary_tree, chain_tree, star_tree,
                                 two_phase_tree)
from tests.util_trees import random_pre_order_tree


@given(st.integers(2, 40), st.randoms(use_true_random=False))
@settings(max_examples=40, deadline=None)
def test_random_trees_validate_and_round(p, rng):
    tree = random_pre_order_tree(p, rng)
    tree.validate()
    rounds = tree.to_rounds()
    # every non-root vertex sends exactly once
    total_sends = sum(len(r) for r in rounds)
    assert total_sends == p - 1
    for sends in rounds:
        srcs = [s for s, _ in sends]
        dsts = [d for _, d in sends]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)


@given(st.integers(2, 40), st.integers(1, 4096),
       st.randoms(use_true_random=False))
@settings(max_examples=40, deadline=None)
def test_random_trees_cost_terms_sane(p, b, rng):
    tree = random_pre_order_tree(p, rng)
    terms = tree.cost_terms(b)
    assert 1 <= terms.depth <= p - 1
    assert terms.distance >= p - 1 or p == 1  # rightmost PE is p-1 hops out
    assert terms.energy >= b * (p - 1)        # every link used at least once
    assert terms.contention >= b
    assert terms.cycles(WSE2) > 0


@given(st.integers(2, 64), st.integers(1, 1 << 14))
@settings(max_examples=60, deadline=None)
def test_lower_bound_below_all_patterns(p, b):
    # LB assumes towards-root messages (links = P-1); compare patterns
    # under the same convention (Lemma 5.4's P-link variant differs by
    # O(1/P) and is handled by the Fig. 1 benchmark at P=512).
    lb_table = compute_lb_energy(64)
    lb = t_lower_bound(p, b, lb_table=lb_table)
    assert lb <= pat.t_chain(p, b) + 1e-6
    assert lb <= two_phase_tree(p).cost_terms(b).cycles() + 1e-6
    assert lb <= pat.t_star(p, b, refined=False) + 1e-6
    if p & (p - 1) == 0:
        assert lb <= pat.t_tree(p, b) + 1e-6


@given(st.integers(2, 40), st.integers(1, 4096),
       st.randoms(use_true_random=False))
@settings(max_examples=40, deadline=None)
def test_lower_bound_below_random_tree_cost(p, b, rng):
    """The LB is a bound over the whole algorithm class the trees span."""
    lb_table = compute_lb_energy(40)
    lb = t_lower_bound(p, b, lb_table=lb_table)
    tree = random_pre_order_tree(p, rng)
    assert lb <= tree.cost_terms(b).cycles(WSE2) + 1e-6


def test_fixed_pattern_trees_validate():
    for p in (2, 3, 4, 8, 15, 16, 31, 64):
        chain_tree(p).validate()
        star_tree(p).validate()
        two_phase_tree(p).validate()
        if p & (p - 1) == 0:
            binary_tree(p).validate()
