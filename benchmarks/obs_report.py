"""Offline observability report over a Chrome-trace export.

Reads the trace JSON written by ``--trace`` (launch/train.py,
launch/serve.py, or any :meth:`Tracer.export_chrome` call), feeds the
collective spans to the model-error monitor, and prints the
per-(op, topology, bytes-decile) predicted-vs-measured table with
drift flags.

    PYTHONPATH=src python benchmarks/obs_report.py TRACE.json
    PYTHONPATH=src python benchmarks/obs_report.py TRACE.json --json
    PYTHONPATH=src python benchmarks/obs_report.py TRACE.json --check

``--check`` is the CI schema gate: it validates that every collective
span carries the required args (op, axes, bytes, plan, cache,
predicted, measured_s, mode) and exits non-zero listing the
violations, printing nothing else on success.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs import load_chrome_trace, validate_spans
from repro.obs.model_error import DEFAULT_THRESHOLD, ModelErrorMonitor


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="model-error report over a --trace export")
    ap.add_argument("trace", help="Chrome-trace JSON written by --trace")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="drift threshold as a fraction "
                         f"(default {DEFAULT_THRESHOLD})")
    ap.add_argument("--min-samples", type=int, default=8,
                    help="samples a bin needs to anchor and to flag")
    ap.add_argument("--seconds-per-cycle", type=float, default=None,
                    help="known model-cycle duration; omit to let each "
                         "bin self-anchor")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of a table")
    ap.add_argument("--check", action="store_true",
                    help="schema gate: validate span conformance and "
                         "exit 1 on problems")
    args = ap.parse_args(argv)

    spans = load_chrome_trace(args.trace)

    if args.check:
        problems = validate_spans(spans)
        if problems:
            for p in problems:
                print(f"[obs-report] FAIL: {p}", file=sys.stderr)
            return 1
        n = sum(1 for sp in spans if sp.cat == "collective")
        print(f"[obs-report] OK: {n} collective spans conform")
        return 0

    mon = ModelErrorMonitor(threshold=args.threshold,
                            min_samples=args.min_samples,
                            seconds_per_cycle=args.seconds_per_cycle)
    fed = mon.observe_spans(spans)
    if args.json:
        report = mon.report()
        report["spans"] = len(spans)
        report["spans_scored"] = fed
        print(json.dumps(report, indent=2, sort_keys=True))
    else:
        print(f"[obs-report] {len(spans)} spans loaded, {fed} scored")
        print(mon.render_table())
    return 2 if mon.should_recalibrate else 0


if __name__ == "__main__":
    sys.exit(main())
