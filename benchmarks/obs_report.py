"""Offline observability report over a Chrome-trace export.

Reads the trace JSON written by ``--trace`` (launch/train.py,
launch/serve.py, or any :meth:`Tracer.export_chrome` call), feeds the
collective spans to the model-error monitor, and prints the
per-(op, topology, bytes-decile) predicted-vs-measured table with
drift flags.

    PYTHONPATH=src python benchmarks/obs_report.py TRACE.json
    PYTHONPATH=src python benchmarks/obs_report.py TRACE.json --json
    PYTHONPATH=src python benchmarks/obs_report.py TRACE.json --check

``--check`` is the CI schema gate: it validates that every collective
span carries the required args (op, axes, bytes, plan, cache,
predicted, measured_s, mode) and exits non-zero listing the
violations, printing nothing else on success.

``--check-small-b`` is the latency-regime gate: the decode-sized
payloads (bytes-decile <= ``--small-b-max-decile``, default 3 = under
10 KiB) are where per-phase launch overhead dominates and the planner's
one-shot latency plans run, so a trace must (a) contain at least one
scored small-B bin -- the hot path really was observed with
predicted+measured pairs -- and (b) show none of those bins drifted
past the threshold.  A drifting small-B bin means the launch constants
no longer describe the hardware: rerun ``engine.calibrate_launch``.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.obs import load_chrome_trace, validate_spans
from repro.obs.model_error import DEFAULT_THRESHOLD, ModelErrorMonitor


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="model-error report over a --trace export")
    ap.add_argument("trace", help="Chrome-trace JSON written by --trace")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="drift threshold as a fraction "
                         f"(default {DEFAULT_THRESHOLD})")
    ap.add_argument("--min-samples", type=int, default=8,
                    help="samples a bin needs to anchor and to flag")
    ap.add_argument("--seconds-per-cycle", type=float, default=None,
                    help="known model-cycle duration; omit to let each "
                         "bin self-anchor")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of a table")
    ap.add_argument("--check", action="store_true",
                    help="schema gate: validate span conformance and "
                         "exit 1 on problems")
    ap.add_argument("--check-small-b", action="store_true",
                    help="latency-regime gate: require scored small-B "
                         "bins and fail on small-B drift")
    ap.add_argument("--small-b-max-decile", type=int, default=3,
                    help="largest bytes-decile counted as small B "
                         "(default 3: payloads under 10 KiB)")
    args = ap.parse_args(argv)

    spans = load_chrome_trace(args.trace)

    if args.check:
        problems = validate_spans(spans)
        if problems:
            for p in problems:
                print(f"[obs-report] FAIL: {p}", file=sys.stderr)
            return 1
        n = sum(1 for sp in spans if sp.cat == "collective")
        print(f"[obs-report] OK: {n} collective spans conform")
        return 0

    if args.check_small_b:
        mon = ModelErrorMonitor(threshold=args.threshold,
                                min_samples=args.min_samples,
                                seconds_per_cycle=args.seconds_per_cycle)
        mon.observe_spans(spans)
        small = [b for (op, topo, decile), b in sorted(mon.bins.items())
                 if decile <= args.small_b_max_decile]
        if not any(b.n > 0 for b in small):
            print(f"[obs-report] FAIL: no small-B bins (decile <= "
                  f"{args.small_b_max_decile}) observed -- the decode "
                  f"hot path left no predicted+measured spans",
                  file=sys.stderr)
            return 1
        drifted = [b for b in small if b.drifted]
        if drifted:
            for b in drifted:
                print(f"[obs-report] FAIL: small-B drift {b.op}/{b.topo} "
                      f"decile {b.decile}: "
                      f"{(b.rolling_error or 0) * 100:.1f}% > "
                      f"{args.threshold * 100:.1f}% -- rerun "
                      f"engine.calibrate_launch()", file=sys.stderr)
            return 1
        print(f"[obs-report] OK: {len(small)} small-B bin(s), "
              f"{sum(b.n for b in small)} observation(s), none drifted")
        return 0

    mon = ModelErrorMonitor(threshold=args.threshold,
                            min_samples=args.min_samples,
                            seconds_per_cycle=args.seconds_per_cycle)
    fed = mon.observe_spans(spans)
    if args.json:
        report = mon.report()
        report["spans"] = len(spans)
        report["spans_scored"] = fed
        print(json.dumps(report, indent=2, sort_keys=True))
    else:
        print(f"[obs-report] {len(spans)} spans loaded, {fed} scored")
        print(mon.render_table())
    return 2 if mon.should_recalibrate else 0


if __name__ == "__main__":
    sys.exit(main())
