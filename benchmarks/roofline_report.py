"""Roofline report: aggregates var/dryrun/*.json into the per-(arch x
shape x mesh) table consumed by EXPERIMENTS.md Dry-run / Roofline."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import emit

ART_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "var", "dryrun"))


def load_records(tag: str | None = None) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag is None and r.get("tag"):
            continue
        if tag is not None and r.get("tag") != tag:
            continue
        recs.append(r)
    return recs


def markdown_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | compute s | memory s | collective s |"
            " dominant | useful-FLOPs | roofline frac | HBM/chip GB |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                        " skipped |  |  |  |  |  |  |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                        " FAILED |  |  |  |  |  |  |")
            continue
        t = r["roofline"]
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['dominant']} "
            f"| {t['useful_flops_ratio']:.3f} "
            f"| {t['roofline_fraction']:.3f} | {hbm:.1f} |")
    return "\n".join(rows)


def run(verbose: bool = True):
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    failed = [r for r in recs if r.get("status") not in ("ok", "skipped")]
    if verbose:
        emit("roofline/cells_ok", 0.0, str(len(ok)))
        emit("roofline/cells_skipped_by_rule", 0.0, str(len(skipped)))
        emit("roofline/cells_failed", 0.0, str(len(failed)))
        if ok:
            worst = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
            emit("roofline/worst_fraction", 0.0,
                 f"{worst['roofline']['roofline_fraction']:.3f}"
                 f"@{worst['arch']}/{worst['shape']}/{worst['mesh']}")
            coll = max(ok, key=lambda r: r["roofline"]["collective_s"])
            emit("roofline/most_collective_bound", 0.0,
                 f"{coll['roofline']['collective_s']:.4f}s"
                 f"@{coll['arch']}/{coll['shape']}/{coll['mesh']}")
    return {"ok": ok, "skipped": skipped, "failed": failed}


def main():
    run()


if __name__ == "__main__":
    main()
