"""Beyond-paper: the model-driven selector re-parameterized for TPU v5e
ICI, applied to gradient-bucket AllReduce (the framework's DP sync path).

Shows (a) the selection regions over (bucket bytes, axis size), (b) the
ppermute round counts per algorithm (the depth analogue on ICI), and
(c) a bucket plan for a real model's gradient tree.
"""

from __future__ import annotations

import jax

from repro.collectives.api import select_algorithm
from repro.core.autogen import autogen_tree, compute_tables
from repro.core.schedule import chain_tree, binary_tree, two_phase_tree
from benchmarks.common import emit

SIZES = [2 ** k for k in range(10, 31, 2)]   # 1 KiB .. 1 GiB
AXES = (8, 16, 32, 256)


def run(verbose: bool = True):
    regions = {p: [select_algorithm(n, p) for n in SIZES] for p in AXES}
    rounds = {}
    for p in (16, 32):
        rounds[f"chain_p{p}"] = len(chain_tree(p).to_rounds())
        rounds[f"tree_p{p}"] = len(binary_tree(p).to_rounds())
        rounds[f"two_phase_p{p}"] = len(two_phase_tree(p).to_rounds())
        tables = compute_tables(p)
        rounds[f"autogen_small_p{p}"] = len(
            autogen_tree(p, 1, tables=tables).to_rounds())
        rounds[f"autogen_big_p{p}"] = len(
            autogen_tree(p, 1 << 20, tables=tables).to_rounds())

    if verbose:
        for p in AXES:
            print(f"# axis={p}: " + ",".join(regions[p]))
        for k, v in sorted(rounds.items()):
            emit(f"tpu/rounds/{k}", 0.0, str(v))

    # gradient bucket plan for a small real model
    from repro.configs import get_config
    from repro.models import param_specs
    cfg = get_config("minicpm-2b")
    specs = param_specs(cfg)
    total_bytes = sum(s.size * 4 for s in jax.tree.leaves(specs))
    plan = []
    off = 0
    bucket = 32 << 20
    while off < total_bytes:
        b = min(bucket, total_bytes - off)
        plan.append(select_algorithm(b, 16))
        off += b
    if verbose:
        emit("tpu/minicpm_grad_buckets", 0.0,
             f"{len(plan)}x32MiB,algos={sorted(set(plan))}")
    return {"regions": regions, "rounds": rounds, "plan": plan}


def main():
    res = run()
    # latency-bound small buckets pick low-depth trees; large buckets pick
    # bandwidth-optimal patterns
    for p in AXES:
        assert res["regions"][p][0] in ("tree", "two_phase", "star")
    assert res["regions"][8][-1] in ("ring", "chain")
    # round counts: tree is log-depth, chain is linear
    assert res["rounds"]["tree_p16"] < res["rounds"]["chain_p16"]


if __name__ == "__main__":
    main()
