"""Shared benchmark helpers.

Cycle counts convert to wall time at the CS-2 clock (850 MHz, Sec. 8.1):
1 cycle = 1/850 us.
"""

from __future__ import annotations

import time

CLOCK_MHZ = 850.0


def cycles_to_us(cycles: float) -> float:
    return cycles / CLOCK_MHZ


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.4f},{derived}")


class StopWatch:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.elapsed = time.time() - self.t0


__all__ = ["CLOCK_MHZ", "cycles_to_us", "emit", "StopWatch"]
