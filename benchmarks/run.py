"""Benchmark runner: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us at the CS-2's 850 MHz for
cycle-denominated results; 0.0 for pure ratios)."""

from __future__ import annotations

import sys
import traceback

from benchmarks import (fig1_optimality, fig8_heatmap_1d, fig10_heatmap_2d,
                        fig11_scaling_B, fig12_scaling_P, fig13_2d,
                        grad_sync_bench, moe_ep_bench, roofline_report,
                        serve_bench, table_model_error, table_speedup,
                        tpu_collectives)

ALL = [
    ("fig1_optimality", fig1_optimality),
    ("fig8_heatmap_1d", fig8_heatmap_1d),
    ("fig10_heatmap_2d", fig10_heatmap_2d),
    ("fig11_scaling_B", fig11_scaling_B),
    ("fig12_scaling_P", fig12_scaling_P),
    ("fig13_2d", fig13_2d),
    ("table_speedup", table_speedup),
    ("table_model_error", table_model_error),
    ("tpu_collectives", tpu_collectives),
    ("grad_sync_bench", grad_sync_bench),
    ("moe_ep_bench", moe_ep_bench),
    ("serve_bench", serve_bench),
    ("roofline_report", roofline_report),
]


def main() -> None:
    failures = []
    for name, mod in ALL:
        print(f"# === {name} ===")
        try:
            mod.main()
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)
    print("# all benchmarks passed")


if __name__ == "__main__":
    main()
