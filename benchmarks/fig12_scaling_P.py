"""Fig. 12: fixed vector length of 1 KB (256 f32 values), increasing PE
count: broadcast / reduce / allreduce, model vs simulator.

Reproduces: chain best at few PEs (contention-bound), two-phase best at
many PEs (depth-bound), Auto-Gen fastest throughout (within the paper's
noted scalar-star exception)."""

from __future__ import annotations

import numpy as np

from repro.core.autogen import compute_tables
from repro.simulator.runner import compare_allreduce, compare_reduce
from benchmarks.common import cycles_to_us, emit

B = 256  # 1 KB of f32
P_VALUES = [4, 8, 16, 32, 64, 128, 256, 512]
PATTERNS = ("star", "chain", "tree", "two_phase", "autogen")


def run(verbose: bool = True):
    tables = compute_tables(max(P_VALUES))
    out = {"reduce": {}, "allreduce": {}}
    for pattern in PATTERNS:
        out["reduce"][pattern] = [
            compare_reduce(pattern, p, B, tables=tables) for p in P_VALUES]
        out["allreduce"][pattern] = [
            compare_allreduce(pattern, p, B, tables=tables)
            for p in P_VALUES]
    if verbose:
        for pattern in PATTERNS:
            sims = out["reduce"][pattern]
            err = float(np.mean([c.rel_error for c in sims]))
            emit(f"fig12b/reduce/{pattern}/P512",
                 cycles_to_us(sims[-1].sim_cycles), f"err={err:.3f}")
    return out


def main():
    out = run()
    # chain wins at P=4; two-phase beats chain at P=512 (simulated)
    r = out["reduce"]
    assert r["chain"][0].sim_cycles <= r["two_phase"][0].sim_cycles + 8
    assert r["two_phase"][-1].sim_cycles < r["chain"][-1].sim_cycles
    # autogen within a whisker of the best fixed pattern everywhere
    for i, p in enumerate(P_VALUES):
        best_fixed = min(r[k][i].sim_cycles
                         for k in ("star", "chain", "tree", "two_phase"))
        assert r["autogen"][i].sim_cycles <= best_fixed * 1.15 + 120, (
            p, r["autogen"][i].sim_cycles, best_fixed)


if __name__ == "__main__":
    main()
