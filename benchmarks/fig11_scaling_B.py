"""Fig. 11: 1D row of 512 PEs, increasing vector length.

(a) Broadcast, (b) Reduce, (c) AllReduce -- model prediction vs the flow
simulator (our deterministic CS-2 stand-in), with relative errors, per
pattern.  Mirrors the paper's model-accuracy claims (bcast <= 21% error;
reduce patterns 12-35% mean error).
"""

from __future__ import annotations

import numpy as np

from repro.core.autogen import compute_tables
from repro.simulator.runner import (compare_allreduce, compare_broadcast,
                                    compare_reduce)
from benchmarks.common import cycles_to_us, emit

P = 512
B_VALUES = [2 ** k for k in range(0, 17, 2)]
PATTERNS = ("star", "chain", "tree", "two_phase", "autogen")


def run(verbose: bool = True):
    tables = compute_tables(P)
    out = {"bcast": [], "reduce": {}, "allreduce": {}}
    for b in B_VALUES:
        out["bcast"].append(compare_broadcast(P, b))
    for pattern in PATTERNS:
        out["reduce"][pattern] = [
            compare_reduce(pattern, P, b, tables=tables) for b in B_VALUES]
        out["allreduce"][pattern] = [
            compare_allreduce(pattern, P, b, tables=tables)
            for b in B_VALUES]

    if verbose:
        errs = [c.rel_error for c in out["bcast"]]
        emit("fig11a/bcast_err_max", 0.0, f"{max(errs):.3f}")
        for pattern in PATTERNS:
            sims = out["reduce"][pattern]
            mean_err = float(np.mean([c.rel_error for c in sims]))
            last = sims[-1]
            emit(f"fig11b/reduce/{pattern}",
                 cycles_to_us(last.sim_cycles),
                 f"B={B_VALUES[-1]},err={mean_err:.3f}")
        for pattern in PATTERNS:
            sims = out["allreduce"][pattern]
            mean_err = float(np.mean([c.rel_error for c in sims]))
            emit(f"fig11c/allreduce/{pattern}",
                 cycles_to_us(sims[-1].sim_cycles),
                 f"err={mean_err:.3f}")
    return out


def main():
    out = run()
    # model accuracy in the paper's reported range
    bcast_err = max(c.rel_error for c in out["bcast"])
    assert bcast_err <= 0.21, bcast_err
    for pattern in ("chain", "tree", "two_phase", "autogen"):
        m = np.mean([c.rel_error for c in out["reduce"][pattern]])
        assert m <= 0.35, (pattern, m)


if __name__ == "__main__":
    main()
