"""Fleet benchmark: multi-replica routing + admission under a Zipf
multi-tenant, bursty (Markov-modulated Poisson) arrival trace.

Drives a 2-replica ``FleetServer`` over one wave-stamped trace
(``serve_bench.make_trace(arrival="bursty")``) once per router policy,
plus a capped admission run and a 1-replica determinism reference.
Emits ``BENCH_fleet.json`` with deterministic counters gated by
``bench_gate`` against ``baselines/fleet_small.json``:

* ``affinity_gain``     -- fleet ``cached_token_fraction`` under
  ``prefix_affinity`` minus under ``round_robin``; must stay strictly
  positive (affinity keeps a tenant's blocks on one replica instead of
  recomputing the prefix once per replica).
* ``prefill_imbalance`` -- max/mean per-replica
  ``prefill_tokens_computed`` under ``least_queue``; bounded.
* ``rejected`` / ``rejected_below_cap`` -- uncapped runs shed nothing;
  the capped run sheds only with zero queue headroom left.
* ``determinism_ok``    -- greedy streams bitwise identical between 1
  and 2 replicas under deterministic routing.

CPU-scale shapes; counters track the routing/admission logic, not
hardware throughput (wall time is informational).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import emit
from benchmarks.serve_bench import make_trace

#: bound gated on least_queue per-replica prefill-compute imbalance
IMBALANCE_BOUND = 1.5


def _fleet_serve(cfg, params, trace, *, n_replicas, router, batch,
                 max_len, block_size, prefill_chunk, seed, num_blocks,
                 queue_cap=None):
    from repro.serving import Request
    from repro.serving.fleet import AdmissionConfig, FleetServer

    fleet = FleetServer(
        cfg, params, n_replicas, batch, max_len, router=router,
        admission=AdmissionConfig(queue_cap=queue_cap), seed=seed,
        block_size=block_size, prefill_chunk=prefill_chunk,
        num_blocks=num_blocks, prefix_cache=True)
    arrivals = [(tr.arrival_wave, tr.tenant,
                 Request(rid=tr.rid, prompt=tr.prompt.copy(),
                         max_new_tokens=tr.max_new))
                for tr in trace]
    t0 = time.time()
    results, _rejections = fleet.run_trace(arrivals)
    wall = time.time() - t0
    snap = fleet.snapshot()
    counters = {
        "tokens_out": snap.tokens_out,
        "wall_s": wall,
        "waves": snap.waves,
        "decode_steps": sum(r.decode_steps for r in snap.replicas),
        "preemptions": sum(r.preemptions for r in snap.replicas),
        "prefill_tokens_computed": snap.prefill_tokens_computed,
        "cached_prefix_tokens": snap.cached_prefix_tokens,
        "cached_token_fraction": snap.cached_token_fraction,
        "prefix_evictions": sum(r.prefix_evictions for r in snap.replicas),
        "rejected": snap.rejected,
        "rejected_below_cap": snap.rejected_below_cap,
        "per_replica": {
            f"replica_{i}": {
                "routed": snap.routed[i],
                "prefill_tokens_computed":
                    snap.replicas[i].prefill_tokens_computed,
                "queue_depth_max": snap.queue_depth_max[i],
            } for i in range(n_replicas)},
    }
    return results, counters, fleet


def run(arch: str = "minicpm-2b", replicas: int = 2, batch: int = 4,
        requests: int = 24, n_prompts: int = 4, sys_len: int = 48,
        user_len: int = 12, new_tokens: int = 12, block_size: int = 16,
        prefill_chunk: int = 16, queue_cap: int = 6, seed: int = 0):
    import jax
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_len = sys_len + user_len + new_tokens + block_size
    # per-replica pool sized like serve_bench: tight enough that the
    # evictable LRU works for a living, roomy enough to never deadlock
    blocks_per_seq = -(-max_len // block_size)
    num_blocks = int(2.5 * blocks_per_seq) + 1
    trace, shared_frac = make_trace(
        np.random.default_rng(seed), requests, cfg.vocab_size,
        n_prompts=n_prompts, sys_len=sys_len, user_len=user_len,
        new_tokens=new_tokens, arrival="bursty", arrival_rate=2.0,
        arrival_seed=seed + 1)
    kw = dict(batch=batch, max_len=max_len, block_size=block_size,
              prefill_chunk=prefill_chunk, seed=seed,
              num_blocks=num_blocks)

    policies = {}
    results_by_policy = {}
    for policy in ("round_robin", "least_queue", "cost",
                   "prefix_affinity"):
        res, counters, fleet = _fleet_serve(
            cfg, params, trace, n_replicas=replicas, router=policy, **kw)
        policies[policy] = counters
        results_by_policy[policy] = res
        if policy == "prefix_affinity":
            affinity_fleet = fleet

    # determinism: the same greedy trace on one replica must emit the
    # same streams the 2-replica fleet does under every policy
    res_single, single, _ = _fleet_serve(
        cfg, params, trace, n_replicas=1, router="round_robin", **kw)
    determinism_ok = int(all(res == res_single
                             for res in results_by_policy.values()))

    # admission: burst into a tight fleet queue cap
    _res_cap, capped, _ = _fleet_serve(
        cfg, params, trace, n_replicas=replicas, router="round_robin",
        queue_cap=queue_cap, **kw)

    rr = policies["round_robin"]
    lq = policies["least_queue"]
    per_prefill = [v["prefill_tokens_computed"]
                   for v in lq["per_replica"].values()]
    imbalance = (max(per_prefill) / (sum(per_prefill) / len(per_prefill))
                 if sum(per_prefill) else 1.0)
    gain = (policies["prefix_affinity"]["cached_token_fraction"]
            - rr["cached_token_fraction"])

    from repro.obs.registry import MetricsRegistry
    from repro.serving.fleet import export_fleet_stats
    reg = MetricsRegistry()
    export_fleet_stats(affinity_fleet, reg)
    return {
        "metrics": reg.export_json(),
        "arch": arch,
        "replicas": replicas,
        "requests": requests,
        "n_prompts": n_prompts,
        "queue_cap": queue_cap,
        "shared_token_fraction": shared_frac,
        "policies": policies,
        "capped": capped,
        "single": {k: single[k] for k in ("tokens_out",
                                          "prefill_tokens_computed",
                                          "cached_token_fraction")},
        "affinity_gain": round(gain, 6),
        "prefill_imbalance": round(imbalance, 6),
        "determinism_ok": determinism_ok,
    }


def check(res) -> None:
    """The fleet acceptance contract on the seeded bursty trace."""
    pol = res["policies"]
    # prefix affinity strictly beats replica-oblivious routing on
    # fleet-wide cached-token fraction
    assert res["affinity_gain"] > 0, (
        f"prefix_affinity fraction "
        f"{pol['prefix_affinity']['cached_token_fraction']:.3f} did not "
        f"beat round_robin {pol['round_robin']['cached_token_fraction']:.3f}")
    # least_queue keeps per-replica prefill compute balanced
    assert res["prefill_imbalance"] <= IMBALANCE_BOUND, (
        f"least_queue prefill imbalance {res['prefill_imbalance']:.3f} "
        f"exceeds {IMBALANCE_BOUND}")
    # zero rejects below the cap: uncapped runs shed nothing...
    for name, counters in pol.items():
        assert counters["rejected"] == 0, (name, counters["rejected"])
        assert counters["rejected_below_cap"] == 0
    # ...the capped run sheds, and only with zero queue headroom left
    assert res["capped"]["rejected"] > 0, "burst never hit the cap"
    assert res["capped"]["rejected_below_cap"] == 0, (
        f"{res['capped']['rejected_below_cap']} rejects below the cap")
    # greedy streams bitwise identical across fleet sizes
    assert res["determinism_ok"] == 1, (
        "fleet routing changed greedy token streams")
    # every admitted request generated tokens under every policy
    assert all(c["tokens_out"] > 0 for c in pol.values())


def main(out_path: str = "BENCH_fleet.json"):
    res = run()
    with open(out_path, "w") as f:
        json.dump(res, f, indent=2)
    emit("fleet/affinity_gain", 0.0, f"{res['affinity_gain']:.3f}")
    emit("fleet/cached_frac_affinity", 0.0,
         f"{res['policies']['prefix_affinity']['cached_token_fraction']:.2f}")
    emit("fleet/cached_frac_round_robin", 0.0,
         f"{res['policies']['round_robin']['cached_token_fraction']:.2f}")
    emit("fleet/prefill_imbalance", 0.0,
         f"{res['prefill_imbalance']:.2f}")
    emit("fleet/capped_rejected", 0.0, str(res["capped"]["rejected"]))
    emit("fleet/determinism_ok", 0.0, str(res["determinism_ok"]))
    print(f"# wrote {os.path.abspath(out_path)}")
    check(res)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args()
    main(args.out)
