"""Chunk-pipelined vs phase-sequential plan execution: the A/B harness.

For each fabric config (uniform ICI vs the heterogeneous ``pod=slow``
4x-slower cross-pod link) and each bucket size, compiles the gradient
AllReduce over the 8-device (pod=2 x data=4) debug mesh three ways --
the serial ``hierarchical`` composition, the forced
``hierarchical_pipelined`` variant, and ``auto`` -- and records the
deterministic counters from per-device HLO: collective bytes/device and
collective op count.  The bucket-size sweep doubles as the chunk-count
sweep: the planner's closed form picks ``n_chunks`` per size (1 below
the launch-overhead cutoff, rising with the payload), reported per
point in the ``model`` section alongside the per-shape predictions,
per-axis modeled wire bytes, the overlap-aware lower bound, and the
modeled overlap savings.

``check()`` asserts the acceptance ordering: on ``pod=slow`` at
>= 1 MiB the argmin is a pipelined plan strictly below the best
phase-sequential candidate and still >= ``lower_bound_multi``; on the
compiled counters, ``auto`` executes exactly the argmin's byte/op
profile, pipelining multiplies the phase count by ``n_chunks`` without
inflating wire bytes (measured phase fan-out vs the modeled chunk
count), and tiny buckets fall back to the serial plan.

Emits ``BENCH_pipeline.json``.  Runs itself in a subprocess so the
XLA_FLAGS device-count override never leaks into the parent.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import emit

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, functools
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.collectives.api import allreduce_multi_inside
from repro.launch.roofline import parse_collective_bytes, collective_total

FABRIC_SPEC = %(fabric_spec)r
if FABRIC_SPEC:
    from repro.launch.train import install_fabric_topology
    install_fabric_topology(FABRIC_SPEC)

mesh = jax.make_mesh((2, 4), ("pod", "data"))
AXES = ("pod", "data")

results = {}
for nbytes in %(bucket_sizes)s:
    n = nbytes // 4
    per = {}
    for name in %(variants)s:
        fn = shard_map(functools.partial(allreduce_multi_inside,
                                         axes=AXES, algorithm=name),
                       mesh=mesh, in_specs=P(), out_specs=P(),
                       check_rep=False)
        with mesh:
            compiled = jax.jit(fn).lower(
                jax.ShapeDtypeStruct((n,), jnp.float32)).compile()
        coll = parse_collective_bytes(compiled.as_text())
        per[name] = {
            "bytes_per_dev": collective_total(coll),
            "ops": int(sum(v["count"] for v in coll.values())),
        }
    results[str(nbytes)] = per
print("JSON" + json.dumps(results))
"""

BUCKET_SIZES = (1 << 14, 1 << 20, 4 << 20)
VARIANTS = ("hierarchical", "hierarchical_pipelined", "auto")
FABRIC_CONFIGS = (("uniform", None), ("pod_slow", "pod=slow"))


def _base(shape: str) -> str:
    suffix = "_pipelined"
    return shape[:-len(suffix)] if shape.endswith(suffix) else shape


def _model_plans(bucket_sizes, fabric_spec: str | None):
    """Planner-side view per bucket size: the argmin plan, its chunk
    count, modeled overlap savings, and every candidate's price (no
    devices needed)."""
    from repro.collectives.engine import CollectiveEngine

    if fabric_spec:
        from repro.core.model import parse_fabric_topology
        eng = CollectiveEngine(fabric=parse_fabric_topology(fabric_spec),
                               persist=False)
    else:
        eng = CollectiveEngine(persist=False)
    out = {}
    for nbytes in bucket_sizes:
        plan = eng.plan_multi("allreduce", ("pod", "data"), (2, 4),
                              nbytes)
        entry = plan.cost_terms.get(plan.shape, {})
        out[str(nbytes)] = {
            "plan": plan.describe(),
            "n_chunks": plan.n_chunks,
            "overlap_saved": entry.get("overlap_saved", 0.0),
            "predictions": plan.predictions,
            "lower_bound": plan.lower_bound,
            "axis_bytes": {shape: e["axis_bytes"]
                           for shape, e in plan.cost_terms.items()},
        }
    return out


def run(verbose: bool = True):
    results = {"mesh": {"pod": 2, "data": 4}}
    for tag, fabric_spec in FABRIC_CONFIGS:
        child = _CHILD % {"bucket_sizes": list(BUCKET_SIZES),
                          "variants": list(VARIANTS),
                          "fabric_spec": fabric_spec}
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__),
                                         "..", "src")
        # gated counters must not depend on a machine-local
        # calibration: the child prices with the declared constants
        env["REPRO_RESTORE_TOPOLOGY"] = "0"
        proc = subprocess.run([sys.executable, "-c", child], env=env,
                              capture_output=True, text=True,
                              timeout=1500)
        if proc.returncode != 0:
            raise RuntimeError(proc.stderr[-2000:])
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("JSON")][-1]
        compiled = json.loads(line[4:])
        compiled["model"] = _model_plans(BUCKET_SIZES, fabric_spec)
        compiled["fabric_spec"] = fabric_spec
        results[tag] = compiled
        if verbose:
            for nbytes in BUCKET_SIZES:
                per = compiled[str(nbytes)]
                model = compiled["model"][str(nbytes)]
                for name, r in per.items():
                    emit(f"pipeline/{tag}/{nbytes}/{name}", 0.0,
                         f"{r['bytes_per_dev'] / 1e6:.2f}MB/dev,"
                         f"{r['ops']}ops")
                emit(f"pipeline/{tag}/{nbytes}/plan", 0.0,
                     f"{model['plan']} saved={model['overlap_saved']:g}")
    return results


def check(results):
    """The acceptance ordering, on model prices and compiled counters."""
    for tag, _ in FABRIC_CONFIGS:
        part = results[tag]
        for nbytes_s, model in part["model"].items():
            nbytes = int(nbytes_s)
            per = part[nbytes_s]
            preds = model["predictions"]
            best = min(preds, key=preds.get)
            # nothing undercuts the overlap-aware lower bound
            assert all(t >= model["lower_bound"] - 1e-6
                       for t in preds.values()), (tag, nbytes)
            # pipelining conserves wire volume: the chunked plan ships
            # the same compiled bytes as its serial base (pow2 buckets
            # split evenly, so no padding slack either)
            assert (per["hierarchical_pipelined"]["bytes_per_dev"]
                    == per["hierarchical"]["bytes_per_dev"]), (tag,
                                                               nbytes)
            # `auto` executes exactly the argmin's compiled profile
            if best in per:
                assert per["auto"] == per[best], (tag, nbytes, best)
            if tag == "pod_slow" and nbytes >= 1 << 20:
                # the argmin is pipelined, strictly below the best
                # phase-sequential candidate
                assert best.endswith("_pipelined"), (nbytes, preds)
                serial_best = min(t for s, t in preds.items()
                                  if not s.endswith("_pipelined"))
                assert preds[best] < serial_best, (nbytes, preds)
                assert model["n_chunks"] >= 2
                assert model["overlap_saved"] > 0.0
                # measured phase fan-out matches the modeled chunks
                assert (per["hierarchical_pipelined"]["ops"]
                        > per["hierarchical"]["ops"]), nbytes
            if nbytes < 1 << 16:
                # launch overhead: tiny buckets fall back to serial
                assert model["n_chunks"] == 1, (tag, nbytes, model)
                assert not best.endswith("_pipelined"), (tag, nbytes)


def main(out_path: str = "BENCH_pipeline.json"):
    results = run()
    check(results)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("pipeline/json", 0.0, out_path)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_pipeline.json")
    args = ap.parse_args()
    main(out_path=args.out)
