"""Expert-parallel MoE dispatch microbenchmark: the model-priced
AllToAll subsystem against the bare-lax single-shot, on the 8-device
("pod", "data") expert mesh.  Emits ``BENCH_moe_ep.json``.

Two measurement layers, both from compiled per-device HLO:

* **a2a sweep** -- one dispatch-shaped exchange per payload size and
  backend (``lax`` single-shot, the planner shapes ``flat`` /
  ``sequential`` / ``hierarchical`` plus their chunk-pipelined
  variants, and ``auto``): collective bytes/device + op count
  (sequential-depth proxy).
* **moe_forward** -- a full ``moe_ffn_ep`` forward (dispatch + combine)
  under the bare-lax and engine paths.

The ``model`` section reports, per payload size, the planner's joint
predictions, the Theta(B*(P-1)/P) lower bound, and the modeled per-axis
wire bytes from ``CollectivePlan.cost_terms`` -- modeled vs compiled
bytes per dispatch, side by side.  ``check()`` asserts the acceptance
properties: every candidate >= the lower bound, hierarchical moves
strictly fewer modeled cross-pod bytes than the flat single-shot, and
``auto`` compiles to the argmin's byte profile.  With ``--fabric
pod=slow`` the slow cross-pod link must drive the argmin to the
hierarchical 2-phase decomposition.

Runs itself in a subprocess so the XLA_FLAGS device-count override
never leaks into the parent.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import emit

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, functools
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.collectives.api import all_to_all_multi_inside, get_engine
from repro.launch.roofline import parse_collective_bytes, collective_total

FABRIC_SPEC = %(fabric_spec)r
if FABRIC_SPEC:
    from repro.launch.train import install_fabric_topology
    install_fabric_topology(FABRIC_SPEC)

mesh = jax.make_mesh((2, 4), ("pod", "data"))
AXES = ("pod", "data")
P_WORLD = 8

def compiled_counters(fn, x):
    smfn = shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)
    with mesh:
        compiled = jax.jit(smfn).lower(
            jax.ShapeDtypeStruct(x.shape, x.dtype)).compile()
    coll = parse_collective_bytes(compiled.as_text())
    return {"bytes_per_dev": collective_total(coll),
            "ops": int(sum(v["count"] for v in coll.values()))}

results = {}
for nbytes in %(payload_sizes)s:
    n = nbytes // 4
    n -= n %% P_WORLD
    x = jax.ShapeDtypeStruct((n,), jnp.float32)
    per = {}
    for name in ("lax", "flat", "sequential", "hierarchical",
                 "sequential_pipelined", "hierarchical_pipelined",
                 "auto"):
        per[name] = compiled_counters(
            functools.partial(all_to_all_multi_inside, axes=AXES,
                              algorithm=name), x)
    results[str(nbytes)] = per

# full EP forward: dispatch + combine through one MoE layer
from repro.models.moe_ep import moe_ffn_ep
G, gs, D, E, F, K = 8, 32, 64, 8, 128, 2
key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 5)
args = (jax.random.normal(ks[0], (G, gs, D), jnp.float32),
        jax.random.normal(ks[1], (D, E)) * 0.5,
        jax.random.normal(ks[2], (E, D, F)) * 0.1,
        jax.random.normal(ks[3], (E, D, F)) * 0.1,
        jax.random.normal(ks[4], (E, F, D)) * 0.1)
fwd = {}
for name in ("lax", "auto"):
    with mesh:
        compiled = jax.jit(functools.partial(
            moe_ffn_ep, top_k=K, algorithm=name)).lower(*args).compile()
    coll = parse_collective_bytes(compiled.as_text())
    fwd[name] = {"bytes_per_dev": collective_total(coll),
                 "ops": int(sum(v["count"] for v in coll.values()))}
results["moe_forward"] = fwd
print("JSON" + json.dumps(results))
"""


def _model_plans(payload_sizes, fabric_spec: str | None = None):
    """Planner-side view: per-size joint predictions, the lower bound,
    and modeled per-axis wire bytes (no devices needed)."""
    from repro.collectives.engine import CollectiveEngine

    if fabric_spec:
        from repro.core.model import parse_fabric_topology
        eng = CollectiveEngine(fabric=parse_fabric_topology(fabric_spec),
                               persist=False)
    else:
        eng = CollectiveEngine(persist=False)
    out = {}
    for nbytes in payload_sizes:
        plan = eng.plan_multi("all_to_all", ("pod", "data"), (2, 4),
                              nbytes)
        out[str(nbytes)] = {
            "plan": plan.describe(),
            "n_chunks": plan.n_chunks,
            "predictions": plan.predictions,
            "lower_bound": plan.lower_bound,
            "axis_bytes": {shape: entry["axis_bytes"]
                           for shape, entry in plan.cost_terms.items()},
        }
    return out


def run(verbose: bool = True, fabric_spec: str | None = None):
    payload_sizes = (1 << 16, 1 << 20, 4 << 20)
    child = _CHILD % {"payload_sizes": list(payload_sizes),
                      "fabric_spec": fabric_spec}
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    # gated counters must not depend on a machine-local calibration:
    # the child prices with the declared constants only, matching the
    # stock-fabric engine _model_plans compares against
    env["REPRO_RESTORE_TOPOLOGY"] = "0"
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True, timeout=1500)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("JSON")][-1]
    results = json.loads(line[4:])
    results["mesh"] = {"pod": 2, "data": 4}
    results["fabric_spec"] = fabric_spec
    results["model"] = _model_plans(payload_sizes, fabric_spec)
    if verbose:
        for nbytes in payload_sizes:
            per = results[str(nbytes)]
            for name, r in per.items():
                emit(f"moe_ep/{nbytes}/{name}", 0.0,
                     f"{r['bytes_per_dev'] / 1e6:.2f}MB/dev,{r['ops']}ops")
            emit(f"moe_ep/{nbytes}/plan", 0.0,
                 results["model"][str(nbytes)]["plan"])
        for name, r in results["moe_forward"].items():
            emit(f"moe_ep/forward/{name}", 0.0,
                 f"{r['bytes_per_dev'] / 1e6:.2f}MB/dev,{r['ops']}ops")
    return results


def check(results):
    """Invariants the perf trajectory must keep."""
    hetero = bool(results.get("fabric_spec"))
    for nbytes, model in results["model"].items():
        per = results[nbytes]
        # no shape beats the Theta(B*(P-1)/P) bound
        assert all(t >= model["lower_bound"] - 1e-6
                   for t in model["predictions"].values()), nbytes
        # the 2-phase decomposition moves strictly fewer modeled
        # cross-pod bytes than the flat single-shot exchange
        ab = model["axis_bytes"]
        assert ab["hierarchical"]["pod"] < ab["flat"]["pod"], nbytes
        # `auto` executes the modeled argmin's compiled byte profile
        best = min(model["predictions"], key=model["predictions"].get)
        assert (per["auto"]["bytes_per_dev"]
                == per[best]["bytes_per_dev"]), (nbytes, best)
        # a slow cross-pod link must keep the argmin on the
        # hierarchical intra-pod/inter-pod decomposition
        # (chunk-pipelined or not)
        if hetero:
            base = (best[:-len("_pipelined")]
                    if best.endswith("_pipelined") else best)
            assert base == "hierarchical", (nbytes, best)
    # the engine forward exchanges no more wire bytes than bare lax
    # (same B per device; the engine path may add ops, not volume);
    # generous 2x headroom keeps CPU-backend HLO layout noise out
    fwd = results["moe_forward"]
    assert fwd["auto"]["bytes_per_dev"] <= 2 * fwd["lax"]["bytes_per_dev"], fwd


def main(out_path: str = "BENCH_moe_ep.json",
         fabric_spec: str | None = None):
    results = run(fabric_spec=fabric_spec)
    check(results)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("moe_ep/json", 0.0, out_path)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fabric", default=None, metavar="SPEC",
                    help="heterogeneous topology spec "
                         "('pod=slow,data=fast' or a JSON path)")
    ap.add_argument("--out", default="BENCH_moe_ep.json")
    args = ap.parse_args()
    main(out_path=args.out, fabric_spec=args.fabric)
