"""HLO diagnosis tool for the perf hillclimb.

Compiles a reduced-depth unrolled variant of one cell and prints the
top-K collectives and top-K tensors by bytes, each attributed to its
source op (op_name metadata) -- the "profile" of the dry-run world.

Usage:
  PYTHONPATH=src python -m benchmarks.hlo_diag --arch yi-34b \
      --shape train_4k --mesh pod --units 1 [--top 15]
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import defaultdict


def diagnose(arch: str, shape_name: str, mesh_kind: str = "pod",
             units: int = 1, top: int = 15, microbatches: int = 1,
             fsdp: bool = True, remat: bool = True):
    from repro.configs import get_config, SHAPES, base
    from repro.launch.dryrun import _lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import (_SHAPE_RE, _shape_bytes,
                                       COLLECTIVE_OPS)
    from repro.sharding import rules
    from repro.models import layers as model_layers

    cfg = base.with_layer_units(get_config(arch), units)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    policy = rules.for_mesh(mesh, fsdp=fsdp)
    model_layers.set_inner_unroll(True)
    try:
        with mesh:
            compiled = _lower_cell(cfg, shape, mesh, policy, microbatches,
                                   remat, unroll=True).compile()
    finally:
        model_layers.set_inner_unroll(False)
    text = compiled.as_text()

    meta_re = re.compile(r'op_name="([^"]*)"')

    colls, tensors = [], []
    by_source = defaultdict(float)
    for line in text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, _, rhs = s.partition("=")
        rhs = rhs.strip()
        meta = meta_re.search(s)
        op_name = meta.group(1) if meta else "?"
        matched = False
        for op in COLLECTIVE_OPS:
            m = re.match(r"^(\(?[\w\[\],{}\s/#*]*?\)?)\s*%?" + op
                         + r"(-start)?\(", rhs)
            if m:
                b = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(m.group(1)))
                colls.append((b, op, op_name, rhs[:90]))
                by_source[_short(op_name)] += b
                matched = True
                break
        if not matched:
            m = re.match(r"^(\w+)\[([\d,]*)\]", rhs)
            if m:
                b = _shape_bytes(m.group(1), m.group(2))
                if b > 1e8:
                    tensors.append((b, op_name, rhs[:90]))

    colls.sort(reverse=True)
    tensors.sort(reverse=True)
    total = sum(b for b, *_ in colls)
    print(f"=== {arch} x {shape_name} ({mesh_kind}, {units} units) ===")
    print(f"collective bytes/device: {total / 1e9:.2f} GB "
          f"({len(colls)} ops)\n")
    print("--- top collectives ---")
    for b, op, name, desc in colls[:top]:
        print(f"{b / 1e9:8.2f} GB {op:18s} {_short(name)}")
    print("\n--- collective bytes by source op ---")
    for name, b in sorted(by_source.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{b / 1e9:8.2f} GB {name}")
    print("\n--- largest tensors (>100MB) ---")
    seen = set()
    for b, name, desc in tensors[: top * 2]:
        key = (round(b / 1e7), _short(name))
        if key in seen:
            continue
        seen.add(key)
        print(f"{b / 1e9:8.2f} GB {_short(name)}  {desc[:60]}")
    return colls, tensors


def _short(op_name: str) -> str:
    # keep the trailing, human-meaningful part of the op_name path
    parts = op_name.split("/")
    return "/".join(parts[-3:]) if len(parts) > 3 else op_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--units", type=int, default=1)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args()
    diagnose(args.arch, args.shape, args.mesh, args.units, args.top,
             fsdp=not args.no_fsdp, remat=not args.no_remat)


if __name__ == "__main__":
    main()
