"""Fig. 13: 2D collectives on grids up to 512 x 512 -- X-Y patterns vs
the snake, Reduce and AllReduce, model vs simulator."""

from __future__ import annotations

import numpy as np

from repro.core.autogen import compute_tables
from repro.simulator.runner import compare_allreduce_2d, compare_reduce_2d
from benchmarks.common import cycles_to_us, emit

SIDE = 512
B_VALUES = [2 ** k for k in range(0, 17, 4)]
SIDES = [4, 8, 16, 32, 64, 128, 256, 512]
PATTERNS = ("star", "chain", "tree", "two_phase", "autogen", "snake")


def run(verbose: bool = True):
    tables = compute_tables(SIDE)
    out = {"scaling_B": {}, "scaling_P": {}}
    for pattern in PATTERNS:
        out["scaling_B"][pattern] = [
            compare_reduce_2d(pattern, SIDE, SIDE, b, tables=tables)
            for b in B_VALUES]
        out["scaling_P"][pattern] = [
            compare_reduce_2d(pattern, s, s, 256, tables=tables)
            for s in SIDES]
    out["allreduce_B"] = {
        pattern: [compare_allreduce_2d(pattern, SIDE, SIDE, b,
                                       tables=tables) for b in B_VALUES]
        for pattern in PATTERNS}
    if verbose:
        for pattern in PATTERNS:
            sims = out["scaling_B"][pattern]
            err = float(np.mean([c.rel_error for c in sims]))
            emit(f"fig13a/reduce2d/{pattern}",
                 cycles_to_us(sims[-1].sim_cycles), f"err={err:.3f}")
    return out


def main():
    out = run()
    # snake is terrible at 512x512 (depth ~ 262k; Sec. 8.7) ...
    sb = out["scaling_B"]
    assert sb["snake"][0].sim_cycles > 10 * sb["two_phase"][0].sim_cycles
    # ... but best on tiny grids with large vectors (bandwidth-bound)
    sp = out["scaling_P"]
    assert sp["snake"][0].sim_cycles <= min(
        sp[k][0].sim_cycles for k in ("star", "chain", "tree", "two_phase"))
    # snake model error small (paper: <= 10%)
    snake_err = max(c.rel_error for c in sb["snake"])
    assert snake_err <= 0.10, snake_err


if __name__ == "__main__":
    main()
