"""Model-accuracy table: Eq. (1) predictions vs the wavelet-level fabric
simulator (small instances, exact) and vs the flow simulator (512-PE
scale) -- the reproduction analogue of the paper's <4%-35% error claims.
"""

from __future__ import annotations

import numpy as np

from repro.core import patterns as pat
from repro.core.autogen import compute_tables
from repro.core.schedule import (binary_tree, chain_tree, star_tree,
                                 two_phase_tree)
from repro.simulator.fabric import simulate_reduce_fabric
from repro.simulator.runner import compare_reduce
from benchmarks.common import emit

FAB_PS = (4, 8, 16)
FAB_BS = (8, 64, 256)
FLOW_BS = [2 ** k for k in range(0, 17, 2)]


def run(verbose: bool = True):
    res = {}
    # fabric (wavelet-level) vs model, small scale
    makers = {"chain": (chain_tree, pat.t_chain),
              "tree": (binary_tree, pat.t_tree),
              "two_phase": (two_phase_tree, pat.t_two_phase),
              "star": (star_tree, pat.t_star)}
    for name, (mk, model_fn) in makers.items():
        errs = []
        for p in FAB_PS:
            for b in FAB_BS:
                fab = simulate_reduce_fabric(mk(p), b).cycles
                errs.append(abs(model_fn(p, b) - fab) / fab)
        res[f"fabric/{name}"] = float(np.mean(errs))

    # flow vs model at P=512
    tables = compute_tables(512)
    for pattern in ("star", "chain", "tree", "two_phase", "autogen"):
        errs = [compare_reduce(pattern, 512, b, tables=tables).rel_error
                for b in FLOW_BS]
        res[f"flow512/{pattern}"] = float(np.mean(errs))

    if verbose:
        for name, err in sorted(res.items()):
            emit(f"model_error/{name}", 0.0, f"{err:.3f}")
    return res


def main():
    res = run()
    # paper range: per-pattern mean relative error 12-35%; ours must stay
    # under the top of that band (pipelined patterns are far tighter)
    for k, v in res.items():
        if "star" in k:
            assert v <= 0.50, (k, v)   # star overhead: paper's Sec 8.5 outlier
        else:
            assert v <= 0.35, (k, v)


if __name__ == "__main__":
    main()
