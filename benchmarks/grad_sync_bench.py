"""Gradient-synchronization microbenchmark: the paper's model-driven
reduction scheduling applied to DP gradient AllReduce, now with the
topology planner's joint multi-axis plans.

Two tiers, both emitting ``BENCH_grad_sync.json``:

* **big** (default): the 512-chip multi-pod mesh
  (pod=2 x data=16 x model=16).  Compares, from compiled HLO:

    psum_flat   -- XLA-native AllReduce over the flattened (pod, data)
    psum_hier   -- XLA AllReduce over 'data' then 'pod'
    two_phase / ring / tree -- per-axis ppermute ladders
    sequential / hierarchical / flat -- the planner's joint shapes
    auto        -- the planner's argmin for the topology

* **small** (``--small``; CI): the 8-device (pod=2 x data=4) debug
  mesh, sweeping every plan shape (incl. 2d_xy / 2d_snake) across
  bucket sizes -- the per-bucket heatmap of the multi-axis selector.

``--fabric 'pod=slow,data=fast'`` (or a JSON topology file) prices the
mesh with heterogeneous per-axis link constants; ``check()`` then also
asserts the slow cross-pod link drives every bandwidth-bound bucket to
the hierarchical composition.

Metrics per variant: collective bytes/device from the per-device HLO,
collective op count (sequential depth proxy), plus the spatial model's
per-shape predictions and per-axis modeled wire bytes from
``CollectivePlan.cost_terms``.  Runs itself in a subprocess so the
XLA_FLAGS device-count override never leaks into the parent.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import emit

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(devices)d"
import json, functools
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.collectives.api import (allreduce_inside, allreduce_multi_inside,
                                   select_algorithm)
from repro.launch.roofline import parse_collective_bytes, collective_total

FABRIC_SPEC = %(fabric_spec)r
if FABRIC_SPEC:
    from repro.launch.train import install_fabric_topology
    install_fabric_topology(FABRIC_SPEC)

mesh = jax.make_mesh(%(mesh_shape)s, %(mesh_axes)s)
AXES = ("pod", "data")
PLAN_SHAPES = %(plan_shapes)s

def variant(name, nbytes):
    if name == "psum_flat":
        return lambda g: jax.lax.psum(g, AXES)
    if name == "psum_hier":
        def f(g):
            return jax.lax.psum(jax.lax.psum(g, "data"), "pod")
        return f
    if name == "auto" or name in PLAN_SHAPES:
        return functools.partial(allreduce_multi_inside, axes=AXES,
                                 algorithm=name)
    def f(g):   # legacy per-axis ladder with a fixed 1D backend
        g = allreduce_inside(g, "data", algorithm=name)
        return allreduce_inside(g, "pod", algorithm=name)
    return f

results = {}
spec = P()   # gradient replicated over all axes (pure-DP layout)
for nbytes in %(bucket_sizes)s:
    n = nbytes // 4
    per_size = {}
    for name in %(variants)s:
        fn = shard_map(variant(name, nbytes), mesh=mesh, in_specs=spec,
                       out_specs=spec, check_rep=False)
        with mesh:
            compiled = jax.jit(fn).lower(
                jax.ShapeDtypeStruct((n,), jnp.float32)).compile()
        coll = parse_collective_bytes(compiled.as_text())
        per_size[name] = {
            "bytes_per_dev": collective_total(coll),
            "ops": int(sum(v["count"] for v in coll.values())),
        }
    results[str(nbytes)] = per_size
results["selector_choice"] = {
    "data_axis": select_algorithm(1 << 26, mesh.shape["data"]),
    "pod_axis": select_algorithm(1 << 26, mesh.shape["pod"]),
}
print("JSON" + json.dumps(results))
"""

BIG_VARIANTS = ("psum_flat", "psum_hier", "two_phase", "ring", "tree",
                "sequential", "hierarchical", "hierarchical_pipelined",
                "flat", "auto")
SMALL_VARIANTS = ("psum_flat", "sequential", "hierarchical",
                  "sequential_pipelined", "hierarchical_pipelined",
                  "2d_xy", "2d_snake", "flat", "auto")

PLAN_SHAPES = ("sequential", "hierarchical", "2d_xy", "2d_snake",
               "flat", "sequential_pipelined", "hierarchical_pipelined")


def _base(shape: str) -> str:
    suffix = "_pipelined"
    return shape[:-len(suffix)] if shape.endswith(suffix) else shape


def _model_plans(pod: int, data: int, bucket_sizes,
                 fabric_spec: str | None = None):
    """Planner-side view: per-bucket joint predictions + per-axis
    modeled wire bytes (no devices needed)."""
    from repro.collectives.engine import CollectiveEngine

    if fabric_spec:
        from repro.core.model import parse_fabric_topology
        eng = CollectiveEngine(fabric=parse_fabric_topology(fabric_spec),
                              persist=False)
    else:
        eng = CollectiveEngine(persist=False)
    out = {}
    for nbytes in bucket_sizes:
        plan = eng.plan_multi("allreduce", ("pod", "data"), (pod, data),
                              nbytes)
        out[str(nbytes)] = {
            "plan": plan.describe(),
            "n_chunks": plan.n_chunks,
            "predictions": plan.predictions,
            "lower_bound": plan.lower_bound,
            "axis_bytes": {shape: entry["axis_bytes"]
                           for shape, entry in plan.cost_terms.items()},
        }
    return out


def run(small: bool = False, verbose: bool = True,
        fabric_spec: str | None = None):
    if small:
        devices, mesh_shape, mesh_axes = 8, (2, 4), ("pod", "data")
        bucket_sizes = (1 << 16, 1 << 20, 16 << 20)
        variants = SMALL_VARIANTS
    else:
        devices, mesh_shape = 512, (2, 16, 16)
        mesh_axes = ("pod", "data", "model")
        bucket_sizes = (64 << 20,)
        variants = BIG_VARIANTS
    child = _CHILD % {
        "devices": devices, "mesh_shape": mesh_shape,
        "mesh_axes": mesh_axes, "bucket_sizes": list(bucket_sizes),
        "variants": list(variants), "fabric_spec": fabric_spec,
        "plan_shapes": list(PLAN_SHAPES),
    }
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    # gated counters must not depend on a machine-local calibration:
    # the child prices with the declared constants only
    env["REPRO_RESTORE_TOPOLOGY"] = "0"
    proc = subprocess.run([sys.executable, "-c", child], env=env,
                          capture_output=True, text=True, timeout=1500)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("JSON")][-1]
    results = json.loads(line[4:])
    pod, data = mesh_shape[0], mesh_shape[1]
    results["mesh"] = {"pod": pod, "data": data}
    results["fabric_spec"] = fabric_spec
    results["model"] = _model_plans(pod, data, bucket_sizes, fabric_spec)
    if verbose:
        for nbytes in bucket_sizes:
            per = results[str(nbytes)]
            for name, r in per.items():
                emit(f"grad_sync/{nbytes}/{name}", 0.0,
                     f"{r['bytes_per_dev'] / 1e6:.1f}MB/dev,{r['ops']}ops")
            emit(f"grad_sync/{nbytes}/plan", 0.0,
                 results["model"][str(nbytes)]["plan"])
    return results


def check(results):
    """Invariants the perf trajectory must keep."""
    hetero = bool(results.get("fabric_spec"))
    for nbytes, model in results["model"].items():
        per = results[nbytes]
        # hierarchical moves strictly fewer modeled cross-pod bytes
        # than the sequential per-axis path
        ab = model["axis_bytes"]
        assert ab["hierarchical"]["pod"] < ab["sequential"]["pod"], nbytes
        # ... and than the flat folded schedule
        assert ab["hierarchical"]["pod"] < ab["flat"]["pod"], nbytes
        # no shape beats the 2D lower bound
        assert all(t >= model["lower_bound"] - 1e-6
                   for t in model["predictions"].values()), nbytes
        # in the bandwidth-bound region (>= 1 MiB buckets: every phase
        # rides ring) the hierarchical composition also compiles to
        # strictly fewer wire bytes per device than the sequential
        # per-axis ladder; below that, latency-optimal per-phase picks
        # make raw byte counts incomparable
        if int(nbytes) >= 1 << 20:
            assert (per["hierarchical"]["bytes_per_dev"]
                    < per["sequential"]["bytes_per_dev"]), nbytes
        # `auto` executes the modeled argmin's byte profile
        best = min(model["predictions"], key=model["predictions"].get)
        assert (per["auto"]["bytes_per_dev"]
                == per[best]["bytes_per_dev"]), (nbytes, best)
        # a slow cross-pod link must drive the joint argmin to the
        # hierarchical composition (chunk-pipelined or not) at
        # bandwidth-bound bucket sizes
        if hetero and int(nbytes) >= 1 << 20:
            assert _base(best) == "hierarchical", (nbytes, best)
    if not hetero:
        assert results["selector_choice"]["data_axis"] == "ring"


def main(out_path: str = "BENCH_grad_sync.json", small: bool = False,
         fabric_spec: str | None = None):
    results = run(small=small, fabric_spec=fabric_spec)
    check(results)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    emit("grad_sync/json", 0.0, out_path)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="8-device debug mesh, full shape sweep (CI)")
    ap.add_argument("--fabric", default=None, metavar="SPEC",
                    help="heterogeneous topology spec "
                         "('pod=slow,data=fast' or a JSON path)")
    ap.add_argument("--out", default="BENCH_grad_sync.json")
    args = ap.parse_args()
    main(out_path=args.out, small=args.small, fabric_spec=args.fabric)
