"""Gradient-synchronization microbenchmark on the 512-chip multi-pod
mesh: the paper's technique (model-driven reduction scheduling) applied
to DP gradient AllReduce.

Compares, from compiled HLO at 512 devices (pod=2 x data=16 x model=16):

  psum_flat   -- XLA-native AllReduce over the flattened (pod, data) axes
  psum_hier   -- XLA AllReduce over 'data' then 'pod'
  two_phase   -- the paper's Two-Phase as ppermute chains: intra-pod
                 chain over 'data', inter-pod chain over 'pod'
  ring        -- reduce-scatter + all-gather rings per axis
  tree        -- recursive halving + doubling per axis
  auto        -- the Eq.(1)-with-ICI-constants selector's pick

Metrics per variant: collective bytes/device from the per-device HLO,
collective op count (sequential depth proxy), and the spatial model's
predicted time on the ICI fabric.  Runs itself in a subprocess so the
512-device XLA_FLAGS never leaks into the parent.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, functools
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.collectives.api import allreduce_inside, select_algorithm
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import parse_collective_bytes, collective_total

NBYTES = 64 << 20                      # one 64 MiB f32 gradient bucket
N = NBYTES // 4
mesh = make_production_mesh(multi_pod=True)

def variant(name):
    if name == "psum_flat":
        def f(g):
            return jax.lax.psum(g, ("pod", "data"))
    elif name == "psum_hier":
        def f(g):
            return jax.lax.psum(jax.lax.psum(g, "data"), "pod")
    else:
        def f(g):
            algo = name
            if name == "auto":
                a_data = select_algorithm(NBYTES, 16)
                a_pod = select_algorithm(NBYTES, 2)
                g = allreduce_inside(g, "data", algorithm=a_data)
                return allreduce_inside(g, "pod", algorithm=a_pod)
            g = allreduce_inside(g, "data", algorithm=algo)
            return allreduce_inside(g, "pod", algorithm=algo)
    return f

results = {}
spec = P()   # gradient replicated over all axes (pure-DP layout)
for name in ("psum_flat", "psum_hier", "two_phase", "ring", "tree",
             "auto"):
    fn = shard_map(variant(name), mesh=mesh, in_specs=spec,
                   out_specs=spec, check_rep=False)
    with mesh:
        compiled = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((N,), jnp.float32)).compile()
    coll = parse_collective_bytes(compiled.as_text())
    results[name] = {
        "bytes_per_dev": collective_total(coll),
        "ops": int(sum(v["count"] for v in coll.values())),
        "breakdown": {k: v for k, v in coll.items() if v["count"]},
    }
results["selector_choice"] = {
    "data_axis": select_algorithm(NBYTES, 16),
    "pod_axis": select_algorithm(NBYTES, 2),
}
print("JSON" + json.dumps(results))
"""


def run(verbose: bool = True):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=1500)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("JSON")][-1]
    results = json.loads(line[4:])
    if verbose:
        for name, r in results.items():
            if name == "selector_choice":
                emit("grad_sync/selector", 0.0,
                     f"data={r['data_axis']} pod={r['pod_axis']}")
                continue
            emit(f"grad_sync/{name}", 0.0,
                 f"{r['bytes_per_dev'] / 1e6:.1f}MB/dev,{r['ops']}ops")
    return results


def main():
    res = run()
    # NOTE: psum rows are opaque XLA all-reduce ops (result bytes, not
    # wire bytes); only the explicit ppermute ladders are byte-comparable
    # among themselves.  At 64 MiB the model picks ring on both axes and
    # the measured HLO byte ordering agrees: ring < tree < chain-based
    # two-phase (bandwidth-optimality, Fig. 8's large-B region on ICI).
    assert res["selector_choice"]["data_axis"] == "ring"
    assert (res["ring"]["bytes_per_dev"]
            < res["tree"]["bytes_per_dev"]
            < res["two_phase"]["bytes_per_dev"])
    assert res["auto"]["bytes_per_dev"] == res["ring"]["bytes_per_dev"]
    # the paper's two-phase structure compiles to a valid 512-chip plan
    assert res["two_phase"]["bytes_per_dev"] > 0


if __name__ == "__main__":
    main()
