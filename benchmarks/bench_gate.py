"""Aggregate perf gate: diff every BENCH artifact against its committed
baseline, report every regression, exit nonzero once at the end.

The previous CI step chained ``bench_diff.py`` invocations in one shell
block, so the first failing diff skipped the remaining ones and a PR
author saw only a fraction of the regressions.  This driver runs the
whole manifest unconditionally::

    python benchmarks/bench_gate.py              # gate (CI)
    python benchmarks/bench_gate.py --refresh    # rewrite baselines

Gate semantics per pair mirror ``bench_diff``: exit 1 if any baseline
regressed, exit 2 if any pair was broken (missing files / no gated
counters) -- regressions win when both occur.  A missing *current*
BENCH file fails the gate: a bench that silently stopped running is a
trajectory going dark, exactly what the gate exists to catch.

``--refresh`` copies each existing current file over its baseline and
prints a per-pair summary of gated-counter changes (used by the
``baseline-refresh`` workflow, which uploads the result as an
artifact); missing current files are reported and skipped, and the exit
code stays 0 unless nothing at all was refreshed.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
from typing import List, Tuple

from benchmarks import bench_diff

#: (current BENCH artifact, committed baseline) pairs the gate covers.
PAIRS: Tuple[Tuple[str, str], ...] = (
    ("BENCH_grad_sync.json", "benchmarks/baselines/grad_sync_small.json"),
    ("BENCH_moe_ep.json", "benchmarks/baselines/moe_ep_small.json"),
    ("BENCH_serve.json", "benchmarks/baselines/serve.json"),
    ("BENCH_pipeline.json", "benchmarks/baselines/pipeline_small.json"),
    ("BENCH_decode.json", "benchmarks/baselines/decode_small.json"),
    ("BENCH_fleet.json", "benchmarks/baselines/fleet_small.json"),
)


def _check_metrics_section(current: str) -> List[str]:
    """Schema-validate the registry export a BENCH artifact embeds
    under ``"metrics"`` (absent section = nothing to check: older
    benches have not migrated yet)."""
    with open(current) as f:
        blob = json.load(f)
    metrics = blob.get("metrics") if isinstance(blob, dict) else None
    if metrics is None:
        return []
    from repro.obs.registry import validate_export
    return validate_export(metrics)


def _gate(pairs, tolerance: float) -> int:
    codes: List[Tuple[str, int]] = []
    for current, baseline in pairs:
        print(f"== bench_gate: {current} vs {baseline}")
        if not os.path.exists(current):
            print(f"bench_gate: {current} missing -- the bench did not "
                  f"run", file=sys.stderr)
            codes.append((current, 2))
            continue
        if not os.path.exists(baseline):
            print(f"bench_gate: {baseline} missing -- commit one (run "
                  f"with --refresh) to gate {current}", file=sys.stderr)
            codes.append((current, 2))
            continue
        problems = _check_metrics_section(current)
        if problems:
            for p in problems:
                print(f"bench_gate: {current} metrics section invalid: "
                      f"{p}", file=sys.stderr)
            codes.append((current, 2))
            continue
        rc = bench_diff.main([current, "--baseline", baseline,
                              "--tolerance", str(tolerance)])
        codes.append((current, rc))
    failed = [(c, rc) for c, rc in codes if rc != 0]
    print(f"== bench_gate: {len(codes) - len(failed)}/{len(codes)} "
          f"pairs clean")
    for current, rc in failed:
        kind = "regressed" if rc == 1 else "broken"
        print(f"==   {kind}: {current}", file=sys.stderr)
    if any(rc == 1 for _, rc in failed):
        return 1
    return 2 if failed else 0


def _count_gated(blob) -> int:
    return sum(1 for _ in bench_diff._walk(blob, blob))


def _refresh(pairs) -> int:
    refreshed = 0
    for current, baseline in pairs:
        if not os.path.exists(current):
            print(f"# skip {baseline}: {current} not present")
            continue
        with open(current) as f:
            cur = json.load(f)
        old_n, regressions = 0, []
        if os.path.exists(baseline):
            with open(baseline) as f:
                old = json.load(f)
            old_n = _count_gated(old)
            regressions, _ = bench_diff.diff(old, cur, tolerance=0.0)
        news = list(bench_diff.new_metrics(
            old if old_n else {}, cur))
        shutil.copyfile(current, baseline)
        refreshed += 1
        print(f"# refreshed {baseline}: {_count_gated(cur)} gated "
              f"counters ({old_n} before, {len(news)} new, "
              f"{len(regressions)} moved)")
        for msg in regressions:
            print(f"#   moved {msg}")
        for path in news:
            print(f"#   new {path}")
    if refreshed == 0:
        print("bench_gate --refresh: nothing refreshed", file=sys.stderr)
        return 2
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="relative regression allowed (default 0.10)")
    ap.add_argument("--refresh", action="store_true",
                    help="copy current BENCH files over the baselines "
                         "and print a diff summary instead of gating")
    args = ap.parse_args(argv)
    if args.refresh:
        return _refresh(PAIRS)
    return _gate(PAIRS, args.tolerance)


if __name__ == "__main__":
    sys.exit(main())
